"""Benchmark: 2-D (data × model) mesh vs the 1-D data mesh round time.

The PR-4 tentpole claims the round executor generalizes to a 2-D
``(data, model)`` mesh with the 1-D path as a special case; this entry
keeps that claim measured. A subprocess with 4 forced host devices
(``--xla_force_host_platform_device_count``, the mesh cannot be built in
the already-initialized parent) times one compiled round of the static
executor at the framework-comparison scale (m=5 groups, K=50 clients) on

  * a (4,)      1-D "data" mesh          (the PR-2 path), and
  * a (2, 2)    (data, model) mesh       (the tentpole path),

interleaved (bench_io.interleaved_best) so the watched ratio
``mesh2d_ratio`` = 1-D time / 2-D time does not inherit host-load drift.
Metrics are appended to BENCH_round_exec.json (same file as the fused-vs-
serial trajectory — one place for all round-executor perf); the >2x
regression gate in benchmarks/run.py watches ``mesh2d_ratio``
(docs/benchmarks.md documents the schema and the gate semantics).

On a CPU host the model axis buys nothing (emulated collectives), so the
ratio is expected near or below 1; the gate only guards against the 2-D
lowering becoming catastrophically slower (a >2x drop from the committed
best), not for speedups that need real hardware.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.bench_io import record_run

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_DRIVER = r"""
import json
import jax
import jax.numpy as jnp
import numpy as np
from benchmarks.bench_io import interleaved_best
from repro.fed import parallel as fp
from repro.fed import rounds
from repro.launch.mesh import make_fed_mesh
from repro.models.paper_models import mclr

m, K, dim, max_n, epochs, batch, reps = (
    json.loads(__import__("sys").argv[1]))
model = mclr(dim, 10)
key = jax.random.PRNGKey(0)
params = model.init(key)
ks = jax.random.split(key, 4)
gp = jax.tree_util.tree_map(
    lambda l: jnp.stack([l + 0.01 * j for j in range(m)]), params)
X = jax.random.normal(ks[0], (K, max_n, dim))
Y = jax.random.randint(ks[1], (K, max_n), 0, 10)
n = jnp.full((K,), max_n, jnp.int32)
mem = jnp.asarray(np.arange(K) % m, jnp.int32)
keys = jax.random.split(ks[2], K)
fn = rounds.make_round_executor(model, epochs=epochs, batch_size=batch,
                                lr=0.05, mu=0.0, n_groups=m,
                                max_samples=max_n)
ex1 = fp.make_sharded_executor(fn, make_fed_mesh(4, 1))
ex2 = fp.make_sharded_executor(fn, make_fed_mesh(2, 2))
us1, us2 = interleaved_best(
    [lambda: jax.block_until_ready(ex1(gp, mem, X, Y, n, keys).group_params),
     lambda: jax.block_until_ready(ex2(gp, mem, X, Y, n, keys).group_params)],
    reps=reps)
print(json.dumps({"devices": jax.device_count(),
                  "mesh1d_us": us1, "mesh2d_us": us2}))
"""


def main(quick: bool = False, *, m: int = 5, K: int = 50):
    reps = 5 if quick else 10
    args = json.dumps([m, K, 32, 20, 2, 10, reps])
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, os.path.join(_REPO, "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-c", _DRIVER, args], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh2d driver failed: {proc.stderr[-1500:]}")
    timed = json.loads(proc.stdout.strip().splitlines()[-1])

    metrics = {"quick": quick, "m": m, "K": K,
               "mesh1d_us": timed["mesh1d_us"],
               "mesh2d_us": timed["mesh2d_us"],
               "mesh2d_ratio": timed["mesh1d_us"] /
               max(timed["mesh2d_us"], 1e-9)}
    print(f"\n# 2-D mesh (m={m}, K={K}, 4 forced host devices): "
          f"1-D (4,1) {metrics['mesh1d_us']:.0f}us vs "
          f"2-D (2,2) {metrics['mesh2d_us']:.0f}us -> "
          f"mesh2d_ratio={metrics['mesh2d_ratio']:.2f}x")
    regression, details = record_run(
        "BENCH_round_exec.json", metrics, watch=[("mesh2d_ratio", "min")])
    if regression:
        print("REGRESSION:", "; ".join(details),
              "(gate semantics: docs/benchmarks.md)")
    return {"mesh2d_ratio": round(metrics["mesh2d_ratio"], 2),
            "regression": regression, "regression_details": details,
            **metrics}


if __name__ == "__main__":
    sys.exit(0 if not main(quick="--quick" in sys.argv).get("regression")
             else 1)

"""Benchmark: paper Table 1 — FedAvg under varying statistical heterogeneity.

Reproduces the motivation study: #classes/client in {1, 3, 5, 10(IID)};
reports discrepancy mean/variance, max/median accuracy, rounds to target.
"""
from __future__ import annotations

import time

import numpy as np

from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.models.paper_models import mclr


def run(n_rounds: int = 20, n_clients: int = 200, dim: int = 128,
        target: float = 0.70, seed: int = 0):
    rows = []
    for cpc in (1, 3, 5, 10):
        t0 = time.time()
        data = mnist_like(seed=seed, n_clients=n_clients,
                          classes_per_client=cpc, total_train=12000, dim=dim)
        cfg = FedConfig(n_rounds=n_rounds, clients_per_round=20,
                        local_epochs=10, batch_size=10, lr=0.05, seed=seed)
        tr = FedAvgTrainer(mclr(dim, 10), data, cfg)
        h = tr.run()
        accs = [r.weighted_acc for r in h.rounds]
        discs = [r.discrepancy for r in h.rounds]
        rows.append({
            "classes_per_client": cpc,
            "disc_mean": float(np.mean(discs)),
            "disc_var": float(np.var(discs)),
            "acc_max": float(np.max(accs)),
            "acc_median": float(np.median(accs)),
            "rounds_to_target": h.rounds_to_reach(target),
            "wall_s": time.time() - t0,
        })
    return rows


def main(quick: bool = False):
    rows = run(n_rounds=8 if quick else 15,
               n_clients=100 if quick else 150)
    print("\n# Table 1 — FedAvg vs heterogeneity (#classes/client)")
    print(f"{'cpc':>4} {'disc_mean':>10} {'disc_var':>10} {'acc_max':>8} "
          f"{'acc_med':>8} {'rounds>=t':>9}")
    for r in rows:
        print(f"{r['classes_per_client']:>4} {r['disc_mean']:>10.3f} "
              f"{r['disc_var']:>10.4f} {r['acc_max']:>8.3f} "
              f"{r['acc_median']:>8.3f} {str(r['rounds_to_target']):>9}")
    # paper claims: discrepancy variance shrinks and max acc grows with cpc
    return rows


if __name__ == "__main__":
    main()

"""Benchmark: the fault-tolerant runtime's overhead and efficacy.

Three robustness metrics, persisted to BENCH_robustness.json (>2x
regression gate in benchmarks/run.py, always included under --quick):

  * ``checkpoint_overhead``: wall ratio of training WITH per-round atomic
    checkpoints (``FedConfig.checkpoint_every=1``) vs without, interleaved
    per-segment minima — how much the crash insurance costs when nothing
    crashes (watched "max": regression when the overhead grows).
    ``recovery_ms`` additionally records a single cold
    ``load_checkpoint`` (latest-ckpt discovery + strict restore).
  * ``quarantine_efficacy``: final weighted accuracy of a FedGroup run
    whose cohorts carry injected NaN payloads under the in-program update
    quarantine, relative to a clean run — ~1.0 means the screen fully
    contains the poison (watched "min"; without the screen the group
    params go NaN and accuracy collapses).
  * ``deadline_saving``: injected straggle wall-time over the actual
    degraded-round cohort wait under ``PopulationConfig.deadline`` — how
    much of a straggling cohort's delay the deadline path recovers by
    proceeding with the staged prefix (watched "min").

Schema + gate semantics: docs/benchmarks.md.
"""
from __future__ import annotations

import tempfile
import time

from benchmarks.bench_io import interleaved_best, record_run
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.population import (FaultConfig, FaultSpec, Population,
                                  PopulationConfig)
from repro.fed.store import ArrayClientStore
from repro.models.paper_models import mclr


def _cfg(**kw) -> FedConfig:
    base = dict(clients_per_round=8, local_epochs=2, batch_size=5, lr=0.05,
                n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


def _checkpoint_overhead(model, data, ckpt_dir: str, reps: int):
    """Interleaved 'run 2 more rounds' segments, checkpointing every round
    vs never — both trainers keep training forward, so every timed segment
    is real work on warm compiled executors."""
    plain = FedAvgTrainer(model, data, _cfg())
    ck = FedAvgTrainer(model, data, _cfg(checkpoint_every=1,
                                         checkpoint_dir=ckpt_dir))
    t_plain, t_ck = interleaved_best(
        [lambda: plain.run(2), lambda: ck.run(2)], reps=reps)
    overhead = t_ck / max(t_plain, 1e-9)

    fresh = FedAvgTrainer(model, data, _cfg(checkpoint_every=1,
                                            checkpoint_dir=ckpt_dir))
    t0 = time.perf_counter()
    fresh.load_checkpoint(ckpt_dir)
    recovery_ms = (time.perf_counter() - t0) * 1e3
    return overhead, recovery_ms


def _quarantine_efficacy(model, data, rounds: int):
    from repro.core.fedgroup import FedGroupTrainer
    faults = FaultConfig(rounds={t: FaultSpec(corrupt=3, corrupt_mode="nan")
                                 for t in range(1, rounds, 2)})

    def final_acc(fault_cfg):
        pop = Population(ArrayClientStore(data),
                         PopulationConfig(faults=fault_cfg))
        tr = FedGroupTrainer(model, None, _cfg(quarantine=True),
                             population=pop)
        h = tr.run(rounds)
        tr.close()
        return h.rounds[-1].weighted_acc, h.total_quarantined

    acc_faulted, quarantined = final_acc(faults)
    acc_clean, _ = final_acc(None)
    return acc_faulted / max(acc_clean, 1e-9), quarantined


def _deadline_saving(model, data, straggle: float, deadline: float):
    """Wall time of one deadline-degraded cohort fetch vs the injected
    straggle it refuses to wait out (prefetch=0: the fetch is synchronous,
    so the measurement is exactly the degraded gather)."""
    pop = Population(ArrayClientStore(data), PopulationConfig(
        faults=FaultConfig(rounds={0: FaultSpec(straggle=straggle)}),
        prefetch=0, deadline=deadline, stage_chunks=8))
    tr = FedAvgTrainer(model, None, _cfg(), population=pop)
    t0 = time.perf_counter()
    pop.next_cohort()
    degraded_s = time.perf_counter() - t0
    tr.close()
    assert pop.stats["deadline_rounds"] == 1
    return straggle / max(degraded_s, 1e-9), degraded_s


def main(quick: bool = False):
    model, data = mclr(16, 10), _data()
    reps = 3 if quick else 6
    rounds = 5 if quick else 9
    straggle = 1.5 if quick else 3.0

    with tempfile.TemporaryDirectory() as td:
        overhead, recovery_ms = _checkpoint_overhead(model, data, td, reps)
    efficacy, quarantined = _quarantine_efficacy(model, data, rounds)
    saving, degraded_s = _deadline_saving(model, data, straggle,
                                          deadline=0.25)

    metrics = {"quick": quick, "rounds": rounds,
               "checkpoint_overhead": overhead,
               "recovery_ms": recovery_ms,
               "quarantine_efficacy": efficacy,
               "quarantined_clients": int(quarantined),
               "straggle_s": straggle,
               "degraded_cohort_s": degraded_s,
               "deadline_saving": saving}
    regression, details = record_run(
        "BENCH_robustness.json", metrics,
        watch=[("checkpoint_overhead", "max"),
               ("quarantine_efficacy", "min"),
               ("deadline_saving", "min")])
    return {"checkpoint_overhead": round(overhead, 3),
            "quarantine_efficacy": round(efficacy, 3),
            "deadline_saving": round(saving, 2),
            "recovery_ms": round(recovery_ms, 1),
            "regression": regression, "regression_details": details}

"""Roofline analysis per (arch × shape × mesh) — deliverable (g).

Three terms, in seconds, per training/serving step:

  compute_s    = FLOPs            / (chips × 197 TFLOP/s bf16)
  memory_s     = HBM bytes        / (chips × 819 GB/s)
  collective_s = collective bytes /  (50 GB/s per-chip ICI link)

METHODOLOGY NOTE (verified empirically in this repo): XLA's
``compiled.cost_analysis()`` counts a ``lax.scan`` (while-loop) body ONCE,
not ×trip-count — a 61-layer scanned model reports ~1/61 of its real FLOPs.
All our models scan over layers, so the compute/memory terms here come from
an ANALYTIC model (below), cross-checked against cost_analysis on unrolled
reduced variants. The collective term reads the dry-run JSON, whose parser
multiplies collectives inside while-body computations by the layer trip
count.

Analytic model (documented assumptions):
  * matmul FLOPs = 2 × (active matmul params) × tokens; backward ×3 total.
    Active params from jax.eval_shape — exact; MoE expert tensors scaled by
    top_k·capacity_factor/E; embedding excluded unless tied (gather ≠ matmul).
  * attention: 4·L·B·S·S_eff·H·hd fwd (causal ⇒ ×0.5), S_eff=min(S,window);
    MLA uses (qk_nope+qk_rope+v)/2·hd-equivalent per head.
  * SSD: intra-chunk 4·B·S·Q·H·(N+P) + state path 4·B·S·H·P·N.
  * mLSTM ≈ 6·B·S·H·P² (matrix-memory update + readout); sLSTM ≈ 16·B·S·D·dh.
  * HBM traffic: train = 28 B/param (fp32 w,m,v read+write + grad) +
    3 × activation bytes; prefill/decode = 2 B/param (bf16 read) + cache r/w
    + activation bytes. Uniform sharding over chips is assumed for the
    per-chip division (the specs shard every large tensor).
"""
from __future__ import annotations

import json
import math
import os
import sys

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import registry, shapes as shp                      # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16          # noqa: E402
from repro.models import zoo                                          # noqa: E402

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


# ---------------------------------------------------------------------------
# Parameter census
# ---------------------------------------------------------------------------

def param_census(cfg: zoo.ArchConfig):
    """(total_params, active_matmul_params, embed_params) from eval_shape."""
    params = jax.eval_shape(lambda: zoo.init_params(jax.random.PRNGKey(0), cfg))
    total = active = embed = 0
    moe_scale = 1.0
    if cfg.n_experts:
        moe_scale = min(1.0, cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        n = int(np.prod(leaf.shape))
        total += n
        if "embed" in names:
            embed += n
            if cfg.tie_embeddings:
                active += n        # tied: also the output matmul
            continue
        if leaf.ndim < 2 or (names and "blocks" in names and leaf.ndim < 3
                             and "moe" not in names):
            continue               # 1-D norms/biases: no matmul flops
        if "moe" in names and leaf.ndim == 4:      # stacked (L,E,D,F)
            active += int(n * moe_scale)
        else:
            active += n
    return total, active, embed


# ---------------------------------------------------------------------------
# Analytic FLOPs / bytes
# ---------------------------------------------------------------------------

def mixer_flops_fwd(cfg: zoo.ArchConfig, B: int, S: int, ctx: int | None = None):
    """Sequence-mixing FLOPs (attention scores/AV or SSM state path), fwd."""
    L = cfg.n_layers
    if ctx is None:
        ctx = S
    s_eff = min(ctx, cfg.window) if cfg.window else ctx
    causal_half = 0.5 if (cfg.causal and S > 1) else 1.0

    if cfg.family in ("dense", "vlm", "audio"):
        return 4.0 * L * B * S * s_eff * cfg.n_heads * cfg.hd * causal_half
    if cfg.family == "moe":
        if cfg.mla:
            per_head = cfg.qk_nope + cfg.qk_rope + cfg.v_head_dim
            return 2.0 * L * B * S * s_eff * cfg.n_heads * per_head * causal_half
        return 4.0 * L * B * S * s_eff * cfg.n_heads * cfg.hd * causal_half
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        H, P, N = di // cfg.ssm_head_dim, cfg.ssm_head_dim, cfg.ssm_state
        Q = cfg.ssd_chunk
        ssd = L * B * S * (4.0 * Q * H * (N + P) * 0.5 + 4.0 * H * P * N) \
            if S > 1 else L * B * 4.0 * H * P * N
        n_shared = L // cfg.shared_attn_period if cfg.shared_attn_period else 0
        attn = 4.0 * n_shared * B * S * s_eff * cfg.n_heads * cfg.hd * causal_half
        return ssd + attn
    if cfg.family == "ssm":                       # xLSTM
        di = cfg.mlstm_proj_factor * cfg.d_model
        P = di // cfg.n_heads
        n_m = sum(1 for k in cfg.xlstm_pattern if k == "m")
        n_s = len(cfg.xlstm_pattern) - n_m
        dh = cfg.d_model // cfg.n_heads
        return (6.0 * n_m * B * S * cfg.n_heads * P * P
                + 16.0 * n_s * B * S * cfg.d_model * dh)
    raise ValueError(cfg.family)


def activation_bytes_fwd(cfg: zoo.ArchConfig, B: int, S: int) -> float:
    """Rough per-step activation traffic (bf16), ~12 tensor r/w per layer."""
    return 12.0 * cfg.n_layers * B * S * cfg.d_model * 2.0


def analytic_terms(cfg: zoo.ArchConfig, shape: shp.InputShape, chips: int):
    B, S = shape.global_batch, shape.seq_len
    total, active, embed = param_census(cfg)
    if shape.kind == "train":
        tokens = B * S
        flops = 3.0 * (2.0 * active * tokens + mixer_flops_fwd(cfg, B, S))
        bytes_ = 28.0 * total + 3.0 * activation_bytes_fwd(cfg, B, S)
        model_flops = 6.0 * active * tokens
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * active * tokens + mixer_flops_fwd(cfg, B, S)
        bytes_ = 2.0 * total + activation_bytes_fwd(cfg, B, S)
        model_flops = 2.0 * active * tokens
    else:  # decode: ONE token, context = S
        tokens = B
        flops = 2.0 * active * tokens + mixer_flops_fwd(cfg, B, 1, ctx=S)
        cache = cache_bytes(cfg, B, S)
        bytes_ = 2.0 * total + 2.0 * cache + activation_bytes_fwd(cfg, B, 1)
        model_flops = 2.0 * active * tokens
    return {
        "flops": flops, "bytes": bytes_, "model_flops": model_flops,
        "params_total": total, "params_active": active,
        "compute_s": flops / (chips * PEAK_FLOPS_BF16),
        "memory_s": bytes_ / (chips * HBM_BW),
    }


def cache_bytes(cfg: zoo.ArchConfig, B: int, S: int) -> float:
    eff = min(S, cfg.window) if cfg.window else S
    if cfg.family in ("dense", "vlm"):
        return 2.0 * cfg.n_layers * B * eff * cfg.n_kv_heads * cfg.hd * 2
    if cfg.family == "moe":
        if cfg.mla:
            return cfg.n_layers * B * eff * (cfg.kv_rank + cfg.qk_rope) * 2
        return 2.0 * cfg.n_layers * B * eff * cfg.n_kv_heads * cfg.hd * 2
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        H, P, N = di // cfg.ssm_head_dim, cfg.ssm_head_dim, cfg.ssm_state
        ssm = cfg.n_layers * B * H * P * N * 2
        n_shared = cfg.n_layers // cfg.shared_attn_period if cfg.shared_attn_period else 0
        attn = 2.0 * n_shared * B * eff * cfg.n_kv_heads * cfg.hd * 2
        return ssm + attn
    if cfg.family == "ssm":
        di = cfg.mlstm_proj_factor * cfg.d_model
        P = di // cfg.n_heads
        return cfg.n_layers * B * cfg.n_heads * P * P * 4
    return 0.0


# ---------------------------------------------------------------------------
# Assemble the table from dry-run JSONs
# ---------------------------------------------------------------------------

def load_dryrun(arch: str, shape: str, mesh: str, suffix: str = ""):
    p = os.path.join(DRYRUN_DIR, f"{arch}_{shape}_{mesh}{suffix}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def row_for(arch: str, shape_name: str, mesh: str = "16x16",
            suffix: str = ""):
    base = registry.get(arch)
    shape = shp.SHAPES[shape_name]
    ok, why = shp.supported(base, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": why}
    cfg = shp.config_for(base, shape)
    chips = int(np.prod([int(x) for x in mesh.split("x")]))
    terms = analytic_terms(cfg, shape, chips)
    rec = load_dryrun(arch, shape_name, mesh, suffix)
    coll_bytes = rec["collective_bytes_total"] if rec else 0.0
    collective_s = coll_bytes / ICI_BW
    dom = max(("compute", terms["compute_s"]), ("memory", terms["memory_s"]),
              ("collective", collective_s), key=lambda kv: kv[1])
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh, "status": "ok",
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": collective_s, "dominant": dom[0],
        "model_flops": terms["model_flops"], "hlo_flops_analytic": terms["flops"],
        "useful_ratio": terms["model_flops"] / max(terms["flops"], 1),
        "params_total": terms["params_total"],
        "params_active": terms["params_active"],
        "dryrun": bool(rec),
        "mem_gib_args": (rec or {}).get("memory_analysis", {}).get(
            "argument_size_in_bytes", 0) / 2**30,
        "mem_gib_temp": (rec or {}).get("memory_analysis", {}).get(
            "temp_size_in_bytes", 0) / 2**30,
    }


def full_table(mesh: str = "16x16"):
    rows = []
    for arch in registry.ARCHS:
        for shape_name in shp.SHAPES:
            rows.append(row_for(arch, shape_name, mesh))
    return rows


def print_table(rows):
    print(f"\n# Roofline — per (arch × shape), terms in ms/step "
          f"(chips on mesh share the work)")
    hdr = (f"{'arch':>22} {'shape':>11} {'compute':>9} {'memory':>9} "
           f"{'collect':>9} {'dominant':>10} {'useful%':>8} "
           f"{'argGiB':>7} {'tmpGiB':>7}")
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:>22} {r['shape']:>11} "
                  f"{'— skip: ' + r['reason']}")
            continue
        print(f"{r['arch']:>22} {r['shape']:>11} "
              f"{r['compute_s']*1e3:>9.2f} {r['memory_s']*1e3:>9.2f} "
              f"{r['collective_s']*1e3:>9.2f} {r['dominant']:>10} "
              f"{100*r['useful_ratio']:>7.1f}% "
              f"{r['mem_gib_args']:>7.1f} {r['mem_gib_temp']:>7.1f}")


def main(quick: bool = False):
    rows = full_table("16x16")
    print_table(rows)
    out = os.path.join(DRYRUN_DIR, "..", "roofline_16x16.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print(f"\nwrote {os.path.abspath(out)}")
    if not quick:
        rows2 = full_table("2x16x16")
        print("\n## multi-pod (2x16x16, 512 chips)")
        print_table(rows2)
        out2 = os.path.join(DRYRUN_DIR, "..", "roofline_2x16x16.json")
        with open(out2, "w") as f:
            json.dump(rows2, f, indent=1, default=float)
    return rows


if __name__ == "__main__":
    main()

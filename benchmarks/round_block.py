"""Benchmark: scan-fused round blocks vs the per-round dispatch path.

The PR-5 tentpole claims that at paper scale the wall clock of the
per-round driver is dominated by dispatch + host-sync overhead (one
executor dispatch, two scalar ``float()`` fetches, and an eval dispatch
per round), not by the round math — and that staging B rounds into ONE
``jax.lax.scan`` dispatch with a donated carry
(``fed.rounds.make_block_executor``) removes it. This entry keeps that
claim measured: a FedGroup trainer (m=5, K=50, every client pre-trained so
no cold-start host events break the blocks) runs B=16 rounds

  * per round  (``block_size=1`` — the PR-2 fused round, B dispatches +
    B metric syncs + B grouped-eval dispatches), and
  * blocked    (``block_size=16`` — one dispatch, metrics fetched once),

interleaved (bench_io.interleaved_best), both through identical round
math (tests/test_round_block.py proves bit-identity). The watched ratio
``block_speedup`` = per-round time / blocked time (amortized per round;
the acceptance floor is blocked <= 0.6x per-round, i.e. speedup >= 1.67).
The donation win is recorded as ``steady_live_growth`` — the number of
device buffers a steady-state block leaves behind (the carry updates in
place instead of reallocating every round) — plus ``carry_mb``, the
donated carry's size. Metrics append to BENCH_round_exec.json (one file
for all round-executor perf); the >2x gate in benchmarks/run.py watches
``block_speedup`` (schema + semantics: docs/benchmarks.md).
"""
from __future__ import annotations

import sys

import jax

from benchmarks.bench_io import interleaved_best, record_run
from repro.core.fedgroup import FedGroupTrainer
from repro.data import generators as gen
from repro.fed.engine import FedConfig
from repro.models.paper_models import mclr


def _make_trainer(data, dim, base, **kw):
    return FedGroupTrainer(mclr(dim, 10), data, FedConfig(**base, **kw))


def main(quick: bool = False, *, m: int = 5, K: int = 50, B: int = 16):
    dim = 16
    n_clients = 60 if quick else 100
    # capped per-client sizes (the virtual generator's max_size) keep the
    # padded solver loop at the paper-scale ~ms round the tentpole targets;
    # mnist_like's power-law tail pads every client to its 400-sample max
    # and the compute would drown the dispatch overhead this entry watches
    data = gen.virtual_mnist_like(
        seed=0, n_clients=n_clients, dim=dim, mean_size=15, min_size=8,
        max_size=20).materialize()
    # pre-train the whole population: membership is fully assigned after
    # the group cold start, so no eq.-9 host events break the blocks and
    # the timed region is pure round execution on both paths
    base = dict(clients_per_round=K, local_epochs=1, batch_size=10,
                lr=0.05, n_groups=m,
                pretrain_scale=(n_clients + m - 1) // m, seed=0)
    blocked = _make_trainer(data, dim, base, block_size=B)
    per_round = _make_trainer(data, dim, base)
    # warm-up: group cold start + both compiled programs
    blocked.run(B)
    per_round.run(B)

    reps = 3 if quick else 6
    block_us, round_us = interleaved_best(
        [lambda: blocked.run(B), lambda: per_round.run(B)], reps=reps)

    # donation win: a steady-state block must not grow the live-buffer set
    # (the carry is donated and updated in place; without donation every
    # block would leak a full copy of the m-stacked group state)
    blocked.run(B)
    live0 = len(jax.live_arrays())
    blocked.run(B)
    steady_live_growth = len(jax.live_arrays()) - live0
    carry_mb = sum(l.nbytes for l in jax.tree_util.tree_leaves(
        blocked._carry_in())) / 2**20

    metrics = {"quick": quick, "m": m, "K": K, "B": B,
               "n_clients": n_clients,
               "block_us_per_round": block_us / B,
               "per_round_us": round_us / B,
               "block_speedup": round_us / max(block_us, 1e-9),
               "steady_live_growth": steady_live_growth,
               "carry_mb": round(carry_mb, 3)}
    print(f"\n# Round blocks (m={m}, K={K}, B={B}): one scan dispatch vs "
          f"{B} per-round dispatches")
    print(f"  amortized per round: blocked "
          f"{metrics['block_us_per_round']:.0f}us vs per-round "
          f"{metrics['per_round_us']:.0f}us -> "
          f"block_speedup={metrics['block_speedup']:.2f}x")
    print(f"  donation: steady-state live-buffer growth "
          f"{steady_live_growth:+d} arrays over a {carry_mb:.2f} MiB "
          f"donated carry")
    regression, details = record_run(
        "BENCH_round_exec.json", metrics, watch=[("block_speedup", "min")])
    if regression:
        print("REGRESSION:", "; ".join(details),
              "(gate semantics: docs/benchmarks.md)")
    return {"block_speedup": round(metrics["block_speedup"], 2),
            "steady_live_growth": steady_live_growth,
            "regression": regression, "regression_details": details,
            **metrics}


if __name__ == "__main__":
    sys.exit(0 if not main(quick="--quick" in sys.argv).get("regression")
             else 1)

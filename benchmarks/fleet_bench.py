"""Benchmark: the coordinator/worker control plane's overhead and
recovery latency.

Two fleet metrics, persisted to BENCH_fleet.json (>2x regression gate in
benchmarks/run.py, always included under --quick):

  * ``coordinator_overhead``: wall ratio of training through a
    fleet-size-1 in-process coordinator (every dispatch a routed lease:
    transport + heartbeats + job/result messages) vs calling
    ``engine.run()`` directly, interleaved per-segment minima — what the
    control plane costs when nothing fails (watched "max"). The routed
    run is bit-identical to the direct one, so this is pure plumbing
    overhead.
  * ``kill_recovery_s``: wall time a 2-worker fleet needs to finish a
    round whose lease holder is hard-killed mid-dispatch — heartbeat-miss
    detection + lease requeue + re-dispatch on the survivor (recorded,
    not watched: it is dominated by the configured heartbeat window).
    ``detect_window_s`` records that configured window for context.

Schema + gate semantics: docs/benchmarks.md.
"""
from __future__ import annotations

import time

from benchmarks.bench_io import interleaved_best, record_run
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.population import FaultConfig, FaultSpec
from repro.launch.coordinator import Coordinator, FleetConfig
from repro.models.paper_models import mclr


def _cfg(**kw) -> FedConfig:
    base = dict(clients_per_round=8, local_epochs=2, batch_size=5, lr=0.05,
                n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


def _coordinator_overhead(model, data, reps: int):
    """Interleaved 'run 2 more rounds' segments, routed through a
    fleet-of-1 coordinator vs direct — both trainers keep training
    forward on warm compiled executors, so the ratio isolates the
    lease/transport/heartbeat plumbing."""
    plain = FedAvgTrainer(model, data, _cfg())
    routed_tr = FedAvgTrainer(model, data, _cfg())
    coord = Coordinator(routed_tr, FleetConfig(n_workers=1))
    t_plain, t_routed = interleaved_best(
        [lambda: plain.run(2), lambda: coord.run(2)], reps=reps)
    plain.close()
    coord.close()
    return t_routed / max(t_plain, 1e-9)


def _kill_recovery(model, data, interval: float, miss: int):
    """One hard-killed lease holder: time from the chaos round's dispatch
    to its (re-dispatched) completion on the surviving worker."""
    faults = FaultConfig(rounds={1: FaultSpec(worker_kill=True)})
    tr = FedAvgTrainer(model, data, _cfg())
    coord = Coordinator(tr, FleetConfig(
        n_workers=2, faults=faults, heartbeat_interval=interval,
        heartbeat_miss=miss, backoff=0.005, backoff_cap=0.02))
    coord.run(1)                        # warm: round 0 compiles everywhere
    t0 = time.perf_counter()
    coord.run(1)                        # round 1: holder killed mid-lease
    recovery_s = time.perf_counter() - t0
    deaths = tr.obs.registry.get("fleet.worker_deaths")
    requeues = tr.obs.registry.get("fleet.requeues")
    coord.close()
    assert deaths == 1 and requeues >= 1
    return recovery_s


def main(quick: bool = False):
    model, data = mclr(16, 10), _data()
    reps = 3 if quick else 6
    interval, miss = 0.02, 15           # 0.3s detection window

    overhead = _coordinator_overhead(model, data, reps)
    recovery_s = _kill_recovery(model, data, interval, miss)

    metrics = {"quick": quick,
               "coordinator_overhead": overhead,
               "kill_recovery_s": recovery_s,
               "detect_window_s": interval * miss}
    regression, details = record_run(
        "BENCH_fleet.json", metrics,
        watch=[("coordinator_overhead", "max")])
    return {"coordinator_overhead": round(overhead, 3),
            "kill_recovery_s": round(recovery_s, 3),
            "detect_window_s": interval * miss,
            "regression": regression, "regression_details": details}


if __name__ == "__main__":
    print(main())

"""Benchmark: clustering-measure cost — the paper's efficiency claim.

Pairwise-cosine/MADC cost O(n² d_w) vs EDC O(m² d_w) (+randomized SVD).
Measures wall time for growing d_w at fixed n (pre-training clients) and
reports the derived FLOP counts. Also times the MADC dispatch
(``measures.madc(use_kernel=True)`` — blocked Pallas kernel at or above the
measured crossover size, automatic fallback to the reference below it) and
the raw kernel in interpret mode (correctness path; on-TPU numbers come
from the roofline) vs the O(n³)-broadcast reference, with the analytic
peak-memory model showing the kernel's working set is tile-sized while the
reference grows as n³.

Results (including the crossover and both kernel trajectories) persist to
BENCH_clustering.json; a >2x drop of the dispatch's relative speed vs the
committed baseline flags a regression (exit gate in benchmarks/run.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_io import interleaved_best, record_run
from repro.core import measures
from repro.core.svd import randomized_truncated_svd
from repro.kernels.madc import madc_tiles
from repro.kernels.ops import madc_crossover_n


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us




def _madc_memory_model(n: int) -> dict:
    """Peak transient bytes (fp32): the reference materializes the (n, n, n)
    |M_iz − M_jz| cube; the blocked kernel holds two (bn, bz) tiles, a
    (bn, bn) accumulator, and a (sub, bn, bz) broadcast chunk — tile-sized
    (madc_tiles picks (bn, bz) from n, capped at (128, 512))."""
    ref = 4 * n * n * n
    bn, bz = madc_tiles(n)
    sub = min(8, bn)
    kern = 4 * (2 * bn * bz + bn * bn + sub * bn * bz)
    return {"n": n, "ref_peak_bytes": ref, "kernel_tile_bytes": kern}


def main(quick: bool = False):
    n, m = 60, 3
    dims = [2048, 16384] if quick else [2048, 16384, 131072, 1048576]
    print("\n# Clustering measure cost (n=60 pretrain clients, m=3 groups)")
    print(f"{'d_w':>9} {'pairwise_us':>12} {'madc_us':>10} {'edc_us':>10} "
          f"{'pairwise_flops':>14} {'edc_flops':>11}")
    rows = []
    key = jax.random.PRNGKey(0)
    madc_j = jax.jit(lambda W: measures.madc(measures.cosine_similarity_matrix(W)))
    pair_j = jax.jit(measures.cosine_similarity_matrix)

    def edc_fn(W):
        V = randomized_truncated_svd(W.T, m)
        return measures.cosine_similarity_matrix(W, V.T)
    edc_j = jax.jit(edc_fn)

    for d in dims:
        W = jax.random.normal(key, (n, d))
        t_pair = _time(pair_j, W)
        t_madc = _time(madc_j, W)
        t_edc = _time(edc_j, W)
        f_pair = 2 * n * n * d
        f_edc = 2 * n * m * d + 4 * (m + 8) ** 2 * d   # embed + rsvd passes
        print(f"{d:>9} {t_pair:>12.0f} {t_madc:>10.0f} {t_edc:>10.0f} "
              f"{f_pair:>14.2e} {f_edc:>11.2e}")
        rows.append({"d_w": d, "pairwise_us": t_pair, "madc_us": t_madc,
                     "edc_us": t_edc})

    # -- MADC dispatch (kernel above crossover, reference below) vs ref ----
    # madc(use_kernel=True) falls back to the reference below the measured
    # crossover, so at the benchmarked (sub-crossover) sizes the dispatch
    # must never lose to the reference: rel_speed ≈ 1.0 is the contract the
    # gate watches. The raw kernel (crossover forced to 0) is timed
    # separately to keep the tile-work trajectory (tiles now sized from n).
    sizes = [32, 64] if quick else [32, 64, 96, 128]
    crossover = madc_crossover_n()
    print(f"\n# MADC: dispatch (crossover n={crossover}) and raw blocked "
          f"kernel (interpret) vs (n,n,n) reference")
    print(f"{'n':>5} {'ref_us':>10} {'dispatch_us':>12} {'kernel_us':>10} "
          f"{'ref_peak_bytes':>15} {'kernel_tile_bytes':>18}")
    kern_rows = []
    ref_j = jax.jit(measures.madc)
    disp_j = jax.jit(lambda M: measures.madc(M, use_kernel=True))
    kern_j = lambda M: measures.madc(M, use_kernel=True, min_kernel_n=0)
    for nn in sizes:
        W = jax.random.normal(jax.random.fold_in(key, nn), (nn, 256))
        M = jax.block_until_ready(measures.cosine_similarity_matrix(W))
        t_ref, t_disp, t_kern = interleaved_best(
            [lambda f=f: jax.block_until_ready(f(M))
             for f in (ref_j, disp_j, kern_j)],
            reps=10 if quick else 20)
        mem = _madc_memory_model(nn)
        print(f"{nn:>5} {t_ref:>10.0f} {t_disp:>12.0f} {t_kern:>10.0f} "
              f"{mem['ref_peak_bytes']:>15} {mem['kernel_tile_bytes']:>18}")
        kern_rows.append({**mem, "ref_us": t_ref, "dispatch_us": t_disp,
                          "kernel_us": t_kern})
    # kernel_tile_bytes comes from the analytic model — the measured
    # counterpart is the on-TPU roofline's job; the ref column is exact
    # (jnp really allocates the (n, n, n) cube)
    tile_bytes = kern_rows[-1]["kernel_tile_bytes"]

    # relative speed is machine-stable; raw interpret-mode wall time is not.
    # The watched metric is the user-facing dispatch at the largest size.
    largest = kern_rows[-1]
    rel = largest["ref_us"] / max(largest["dispatch_us"], 1e-9)
    rel_raw = largest["ref_us"] / max(largest["kernel_us"], 1e-9)
    metrics = {
        "quick": quick,
        "measure_cost": rows,
        "madc_kernel": kern_rows,
        "madc_kernel_rel_speed": rel,
        "madc_raw_kernel_rel_speed": rel_raw,
        "madc_kernel_crossover_n": crossover,
        "kernel_tile_bytes": tile_bytes,
    }
    # Below the crossover the dispatch IS the reference, so this ratio is
    # ≈1.0 by construction and only jitters with host load; the behavioral
    # fallback is unit-tested (test_kernels), and this gate is a coarse
    # wall-clock backstop — hence the wider factor than the default 2x.
    regression, details = record_run(
        "BENCH_clustering.json", metrics,
        watch=[("madc_kernel_rel_speed", "min")], factor=3.0)
    if regression:
        print("REGRESSION:", "; ".join(details))
    return {"rows": len(rows), "madc_rel_speed": round(rel, 3),
            "madc_raw_rel_speed": round(rel_raw, 3),
            "crossover_n": crossover,
            "regression": regression, "regression_details": details}


if __name__ == "__main__":
    main()

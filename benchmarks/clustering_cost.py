"""Benchmark: clustering-measure cost — the paper's efficiency claim.

Pairwise-cosine/MADC cost O(n² d_w) vs EDC O(m² d_w) (+randomized SVD).
Measures wall time for growing d_w at fixed n (pre-training clients) and
reports the derived FLOP counts. Also times the fused Pallas cosine kernel
in interpret mode (correctness path; on-TPU numbers come from the roofline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures
from repro.core.svd import randomized_truncated_svd


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def main(quick: bool = False):
    n, m = 60, 3
    dims = [2048, 16384] if quick else [2048, 16384, 131072, 1048576]
    print("\n# Clustering measure cost (n=60 pretrain clients, m=3 groups)")
    print(f"{'d_w':>9} {'pairwise_us':>12} {'madc_us':>10} {'edc_us':>10} "
          f"{'pairwise_flops':>14} {'edc_flops':>11}")
    rows = []
    key = jax.random.PRNGKey(0)
    madc_j = jax.jit(lambda W: measures.madc(measures.cosine_similarity_matrix(W)))
    pair_j = jax.jit(measures.cosine_similarity_matrix)

    def edc_fn(W):
        V = randomized_truncated_svd(W.T, m)
        return measures.cosine_similarity_matrix(W, V.T)
    edc_j = jax.jit(edc_fn)

    for d in dims:
        W = jax.random.normal(key, (n, d))
        t_pair = _time(pair_j, W)
        t_madc = _time(madc_j, W)
        t_edc = _time(edc_j, W)
        f_pair = 2 * n * n * d
        f_edc = 2 * n * m * d + 4 * (m + 8) ** 2 * d   # embed + rsvd passes
        print(f"{d:>9} {t_pair:>12.0f} {t_madc:>10.0f} {t_edc:>10.0f} "
              f"{f_pair:>14.2e} {f_edc:>11.2e}")
        rows.append({"d_w": d, "pairwise_us": t_pair, "madc_us": t_madc,
                     "edc_us": t_edc})
    return rows


if __name__ == "__main__":
    main()

"""Benchmark: paper Table 3 — framework comparison.

FedAvg / FedProx / IFCA / FeSEM / FedGroup(EDC|MADC) / FedGrouProx /
ablations (RCC, RAC) on the synthetic stand-ins for the paper's datasets.
Reports max ("early-stopping") weighted accuracy, as in §5.1.

``round_executor_bench`` (its own "round_exec" entry in benchmarks/run.py,
always included under --quick) times the single-dispatch round executor
against the retired per-group loops at the framework-comparison scale
(m=5 groups, K=50 clients) — static membership plus the fused IFCA/FeSEM
assignment stages vs their serial oracles — and persists the trajectory to
BENCH_round_exec.json; a >2x speedup loss vs the committed baseline flags
a regression (exit gate in benchmarks/run.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_io import interleaved_best, record_run
from repro.core.fedgroup import FedGrouProxTrainer, FedGroupTrainer
from repro.data import generators as gen
from repro.fed import client as client_lib
from repro.fed import rounds
from repro.fed.engine import FedAvgTrainer, FedConfig, FedProxTrainer
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer
from repro.models.paper_models import lstm_classifier, mclr, mlp


def _datasets(quick: bool):
    scale = 0.4 if quick else 0.7
    n = lambda x: max(20, int(x * scale))
    return {
        "mnist_mclr": (gen.mnist_like(0, n_clients=n(200),
                                      classes_per_client=2,
                                      total_train=n(12000), dim=128),
                       lambda: mclr(128, 10), 3),
        "femnist_mlp": (gen.femnist_like(0, n_clients=n(100),
                                         total_train=n(8000), dim=128),
                        lambda: mlp(128, 64, 62), 5),
        "synthetic11_mclr": (gen.synthetic(1.0, 1.0, 0, n_clients=n(100)),
                             lambda: mclr(60, 10), 5),
        "sent140_lstm": (gen.sent140_like(0, n_clients=n(150),
                                          total_train=n(6000), vocab=400),
                         lambda: lstm_classifier(400, 16, 32), 5),
    }


def _frameworks(m: int):
    base = dict(clients_per_round=20, local_epochs=10, batch_size=10,
                lr=0.05, n_groups=m, pretrain_scale=10, seed=0)
    return {
        "fedavg": (FedAvgTrainer, FedConfig(**base)),
        "fedprox": (FedProxTrainer, FedConfig(**base, mu=0.01)),
        "ifca": (IFCATrainer, FedConfig(**base)),
        "fesem": (FeSEMTrainer, FedConfig(**base)),
        "fg_edc": (FedGroupTrainer, FedConfig(**base)),
        "fg_madc": (FedGroupTrainer, FedConfig(**base, measure="madc")),
        "fgp_edc": (FedGrouProxTrainer, FedConfig(**base, mu=0.01)),
        "fg_rcc": (FedGroupTrainer, FedConfig(**base, rcc=True)),
        "fg_rac": (FedGroupTrainer, FedConfig(**base, rac=True)),
    }


def _time_pair(run_fused, run_serial, reps: int):
    """Interleaved per-call minima (bench_io) — the fused/serial ratio is a
    gated metric and must not inherit host-load drift between two
    back-to-back timing loops."""
    fused_us, serial_us = interleaved_best([run_fused, run_serial],
                                           reps=reps)
    return fused_us, serial_us


def round_executor_bench(quick: bool = False, *, m: int = 5, K: int = 50):
    """Single fused dispatch vs the retired per-group loops, same keys/data:
    static membership (FedGroup-style), IFCA's argmin-loss estimation, and
    FeSEM's ℓ2 E-step — the latter two with the assignment stage fused into
    the same compiled round."""
    from repro.fed.fesem import fesem_state_update, make_fesem_assign
    from repro.fed.ifca import make_ifca_assign
    from repro.models.modules import flatten_updates

    dim, max_n, epochs, batch = 32, 20, 2, 10
    model = mclr(dim, 10)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    ks = jax.random.split(key, m + 3)
    # well-separated group models + labels drawn from group (i % m)'s model,
    # so IFCA's argmin-loss spreads clients over all m clusters and the
    # serial baseline really pays m solver launches (the honest comparison)
    gp_list = [jax.tree_util.tree_map(
        lambda l, k=ks[j]: l + 0.3 * jax.random.normal(k, l.shape), params)
        for j in range(m)]
    X = jax.random.normal(ks[m], (K, max_n, dim))
    Y = jnp.stack([jnp.argmax(model.apply(gp_list[i % m], X[i]), -1)
                   for i in range(K)])
    n = jnp.full((K,), max_n, jnp.int32)
    membership = np.arange(K) % m
    keys = jax.random.split(ks[m + 1], K)

    exec_kw = dict(epochs=epochs, batch_size=batch, lr=0.05, mu=0.0,
                   n_groups=m, max_samples=max_n)
    solver = client_lib.make_batch_solver(
        model, epochs=epochs, batch_size=batch, lr=0.05, mu=0.0,
        max_samples=max_n)
    gp = rounds.stack_trees(gp_list)
    reps = 5 if quick else 10
    metrics = {"quick": quick, "m": m, "K": K, "epochs": epochs}

    # -- static membership (FedGroup/FedAvg executor) ----------------------
    fused = jax.jit(rounds.make_round_executor(model, **exec_kw))
    mem_j = jnp.asarray(membership, jnp.int32)
    f_us, s_us = _time_pair(
        lambda: jax.block_until_ready(
            fused(gp, mem_j, X, Y, n, keys).group_params),
        lambda: jax.block_until_ready(rounds.serial_reference_round(
            solver, gp_list, membership, X, Y, n, keys)[2]),
        reps)
    metrics.update(fused_us=f_us, serial_us=s_us,
                   speedup=s_us / max(f_us, 1e-9))

    # -- IFCA: in-program argmin-loss vs estimate-then-loop ----------------
    loss_fn = client_lib.make_loss_eval_fn(model)
    fused_ifca = jax.jit(rounds.make_round_executor(
        model, assign_fn=make_ifca_assign(model), **exec_kw))
    f_us, s_us = _time_pair(
        lambda: jax.block_until_ready(
            fused_ifca(gp, None, X, Y, n, keys).group_params),
        lambda: jax.block_until_ready(jax.tree_util.tree_leaves(
            rounds.serial_ifca_round(
                solver, loss_fn, gp_list, X, Y, n, keys)[0])[0]),
        reps)
    metrics.update(ifca_fused_us=f_us, ifca_serial_us=s_us,
                   ifca_speedup=s_us / max(f_us, 1e-9))

    # -- FeSEM: in-program ℓ2 E-step + scatter vs host numpy rebuild -------
    centers = np.stack([np.asarray(flatten_updates(p)) for p in gp_list])
    local_flat = np.stack([centers[i % m] for i in range(K)])
    fused_fesem = jax.jit(rounds.make_round_executor(
        model, assign_fn=make_fesem_assign(),
        state_update_fn=fesem_state_update, **exec_kw))
    state = {"local_flat": jnp.asarray(local_flat),
             "idx": jnp.arange(K, dtype=jnp.int32)}
    f_us, s_us = _time_pair(
        lambda: jax.block_until_ready(
            fused_fesem(gp, state, X, Y, n, keys)
            .assign_state["local_flat"]),
        lambda: rounds.serial_fesem_round(
            solver, gp_list, local_flat, X, Y, n, keys)[2],
        reps)
    metrics.update(fesem_fused_us=f_us, fesem_serial_us=s_us,
                   fesem_speedup=s_us / max(f_us, 1e-9))

    print(f"\n# Round executor (m={m}, K={K}, E={epochs}): "
          f"single-dispatch vs retired per-group loop")
    for tag, label in (("", "static"), ("ifca_", "ifca"),
                       ("fesem_", "fesem")):
        print(f"  {label:>7}: fused {metrics[tag + 'fused_us']:.0f}us vs "
              f"serial {metrics[tag + 'serial_us']:.0f}us -> "
              f"{metrics[tag + 'speedup']:.1f}x")
    regression, details = record_run(
        "BENCH_round_exec.json", metrics,
        watch=[("speedup", "min"), ("ifca_speedup", "min"),
               ("fesem_speedup", "min")])
    if regression:
        print("REGRESSION:", "; ".join(details))
    return {"speedup": round(metrics["speedup"], 2),
            "ifca_speedup": round(metrics["ifca_speedup"], 2),
            "fesem_speedup": round(metrics["fesem_speedup"], 2),
            "regression": regression, "regression_details": details,
            **metrics}


def main(quick: bool = False, n_rounds: int | None = None):
    n_rounds = n_rounds or (6 if quick else 12)
    results = {}
    for dname, (data, model_fn, m) in _datasets(quick).items():
        row = {}
        for fname, (cls, cfg) in _frameworks(m).items():
            t0 = time.time()
            tr = cls(model_fn(), data, cfg)
            h = tr.run(n_rounds)
            row[fname] = (h.max_acc, time.time() - t0, tr.comm_params)
        results[dname] = row

    print("\n# Table 3 — max weighted accuracy (early stopping)")
    frameworks = list(_frameworks(3))
    header = f"{'dataset':>18} " + " ".join(f"{f:>8}" for f in frameworks)
    print(header)
    for dname, row in results.items():
        accs = " ".join(f"{row[f][0]:>8.3f}" for f in frameworks)
        print(f"{dname:>18} {accs}")
    print("\n(improvement of fg_edc over fesem, percentage points)")
    for dname, row in results.items():
        print(f"  {dname}: {100 * (row['fg_edc'][0] - row['fesem'][0]):+.1f}")
    print("\n# communication (cumulative params transferred, relative to fedavg)")
    for dname, row in results.items():
        base = max(row['fedavg'][2], 1)
        rel = " ".join(f"{f}={row[f][2]/base:.2f}x" for f in
                       ("fedavg", "ifca", "fesem", "fg_edc"))
        print(f"  {dname}: {rel}")

    return {"datasets": len(results), "frameworks": len(_frameworks(3)),
            "table3": results}


if __name__ == "__main__":
    main()

"""Benchmark: paper Table 3 — framework comparison.

FedAvg / FedProx / IFCA / FeSEM / FedGroup(EDC|MADC) / FedGrouProx /
ablations (RCC, RAC) on the synthetic stand-ins for the paper's datasets.
Reports max ("early-stopping") weighted accuracy, as in §5.1.

Also times the single-dispatch round executor against the seed per-group
loop (m=5 groups, K=50 clients — the framework-comparison scale) and
persists the trajectory to BENCH_round_exec.json; a >2x speedup loss vs the
committed baseline flags a regression (exit gate in benchmarks/run.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_io import record_run
from repro.core.fedgroup import FedGrouProxTrainer, FedGroupTrainer
from repro.data import generators as gen
from repro.fed import client as client_lib
from repro.fed import rounds
from repro.fed.engine import FedAvgTrainer, FedConfig, FedProxTrainer
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer
from repro.models.paper_models import lstm_classifier, mclr, mlp


def _datasets(quick: bool):
    scale = 0.4 if quick else 0.7
    n = lambda x: max(20, int(x * scale))
    return {
        "mnist_mclr": (gen.mnist_like(0, n_clients=n(200),
                                      classes_per_client=2,
                                      total_train=n(12000), dim=128),
                       lambda: mclr(128, 10), 3),
        "femnist_mlp": (gen.femnist_like(0, n_clients=n(100),
                                         total_train=n(8000), dim=128),
                        lambda: mlp(128, 64, 62), 5),
        "synthetic11_mclr": (gen.synthetic(1.0, 1.0, 0, n_clients=n(100)),
                             lambda: mclr(60, 10), 5),
        "sent140_lstm": (gen.sent140_like(0, n_clients=n(150),
                                          total_train=n(6000), vocab=400),
                         lambda: lstm_classifier(400, 16, 32), 5),
    }


def _frameworks(m: int):
    base = dict(clients_per_round=20, local_epochs=10, batch_size=10,
                lr=0.05, n_groups=m, pretrain_scale=10, seed=0)
    return {
        "fedavg": (FedAvgTrainer, FedConfig(**base)),
        "fedprox": (FedProxTrainer, FedConfig(**base, mu=0.01)),
        "ifca": (IFCATrainer, FedConfig(**base)),
        "fesem": (FeSEMTrainer, FedConfig(**base)),
        "fg_edc": (FedGroupTrainer, FedConfig(**base)),
        "fg_madc": (FedGroupTrainer, FedConfig(**base, measure="madc")),
        "fgp_edc": (FedGrouProxTrainer, FedConfig(**base, mu=0.01)),
        "fg_rcc": (FedGroupTrainer, FedConfig(**base, rcc=True)),
        "fg_rac": (FedGroupTrainer, FedConfig(**base, rac=True)),
    }


def round_executor_bench(quick: bool = False, *, m: int = 5, K: int = 50):
    """Single fused dispatch vs the seed per-group loop, same keys/data."""
    dim, max_n, epochs, batch = 32, 20, 2, 10
    model = mclr(dim, 10)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    gp_list = [jax.tree_util.tree_map(lambda l, j=j: l + 0.01 * j, params)
               for j in range(m)]
    ks = jax.random.split(key, 3)
    X = jax.random.normal(ks[0], (K, max_n, dim))
    Y = jax.random.randint(ks[1], (K, max_n), 0, 10)
    n = jnp.full((K,), max_n, jnp.int32)
    membership = np.arange(K) % m
    keys = jax.random.split(ks[2], K)

    fused = jax.jit(rounds.make_round_executor(
        model, epochs=epochs, batch_size=batch, lr=0.05, mu=0.0, n_groups=m,
        max_samples=max_n, eta_g=0.0))
    solver = client_lib.make_batch_solver(
        model, epochs=epochs, batch_size=batch, lr=0.05, mu=0.0,
        max_samples=max_n)
    gp = rounds.stack_trees(gp_list)
    mem_j = jnp.asarray(membership, jnp.int32)

    def run_fused():
        jax.block_until_ready(
            fused(gp, mem_j, X, Y, n, keys).group_params)

    def run_serial():
        out = rounds.serial_reference_round(
            solver, gp_list, membership, X, Y, n, keys)
        jax.block_until_ready(out[2])

    run_fused(), run_serial()                           # compile both paths
    reps = 3 if quick else 10
    t0 = time.perf_counter()
    for _ in range(reps):
        run_fused()
    fused_us = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        run_serial()
    serial_us = (time.perf_counter() - t0) / reps * 1e6

    speedup = serial_us / max(fused_us, 1e-9)
    print(f"\n# Round executor (m={m}, K={K}, E={epochs}): "
          f"single-dispatch {fused_us:.0f}us vs seed loop {serial_us:.0f}us "
          f"-> {speedup:.1f}x")
    metrics = {"quick": quick, "m": m, "K": K, "epochs": epochs,
               "fused_us": fused_us, "serial_us": serial_us,
               "speedup": speedup}
    regression, details = record_run(
        "BENCH_round_exec.json", metrics, watch=[("speedup", "min")])
    if regression:
        print("REGRESSION:", "; ".join(details))
    return {**metrics, "regression": regression}


def main(quick: bool = False, n_rounds: int | None = None):
    n_rounds = n_rounds or (6 if quick else 12)
    results = {}
    for dname, (data, model_fn, m) in _datasets(quick).items():
        row = {}
        for fname, (cls, cfg) in _frameworks(m).items():
            t0 = time.time()
            tr = cls(model_fn(), data, cfg)
            h = tr.run(n_rounds)
            row[fname] = (h.max_acc, time.time() - t0, tr.comm_params)
        results[dname] = row

    print("\n# Table 3 — max weighted accuracy (early stopping)")
    frameworks = list(_frameworks(3))
    header = f"{'dataset':>18} " + " ".join(f"{f:>8}" for f in frameworks)
    print(header)
    for dname, row in results.items():
        accs = " ".join(f"{row[f][0]:>8.3f}" for f in frameworks)
        print(f"{dname:>18} {accs}")
    print("\n(improvement of fg_edc over fesem, percentage points)")
    for dname, row in results.items():
        print(f"  {dname}: {100 * (row['fg_edc'][0] - row['fesem'][0]):+.1f}")
    print("\n# communication (cumulative params transferred, relative to fedavg)")
    for dname, row in results.items():
        base = max(row['fedavg'][2], 1)
        rel = " ".join(f"{f}={row[f][2]/base:.2f}x" for f in
                       ("fedavg", "ifca", "fesem", "fg_edc"))
        print(f"  {dname}: {rel}")

    exec_bench = round_executor_bench(quick)
    return {"round_exec_speedup": round(exec_bench["speedup"], 2),
            "regression": exec_bench["regression"],
            "table3": results, "round_exec": exec_bench}


if __name__ == "__main__":
    main()

"""Benchmark: paper Table 3 — framework comparison.

FedAvg / FedProx / IFCA / FeSEM / FedGroup(EDC|MADC) / FedGrouProx /
ablations (RCC, RAC) on the synthetic stand-ins for the paper's datasets.
Reports max ("early-stopping") weighted accuracy, as in §5.1.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.fedgroup import FedGrouProxTrainer, FedGroupTrainer
from repro.data import generators as gen
from repro.fed.engine import FedAvgTrainer, FedConfig, FedProxTrainer
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer
from repro.models.paper_models import lstm_classifier, mclr, mlp


def _datasets(quick: bool):
    scale = 0.4 if quick else 0.7
    n = lambda x: max(20, int(x * scale))
    return {
        "mnist_mclr": (gen.mnist_like(0, n_clients=n(200),
                                      classes_per_client=2,
                                      total_train=n(12000), dim=128),
                       lambda: mclr(128, 10), 3),
        "femnist_mlp": (gen.femnist_like(0, n_clients=n(100),
                                         total_train=n(8000), dim=128),
                        lambda: mlp(128, 64, 62), 5),
        "synthetic11_mclr": (gen.synthetic(1.0, 1.0, 0, n_clients=n(100)),
                             lambda: mclr(60, 10), 5),
        "sent140_lstm": (gen.sent140_like(0, n_clients=n(150),
                                          total_train=n(6000), vocab=400),
                         lambda: lstm_classifier(400, 16, 32), 5),
    }


def _frameworks(m: int):
    base = dict(clients_per_round=20, local_epochs=10, batch_size=10,
                lr=0.05, n_groups=m, pretrain_scale=10, seed=0)
    return {
        "fedavg": (FedAvgTrainer, FedConfig(**base)),
        "fedprox": (FedProxTrainer, FedConfig(**base, mu=0.01)),
        "ifca": (IFCATrainer, FedConfig(**base)),
        "fesem": (FeSEMTrainer, FedConfig(**base)),
        "fg_edc": (FedGroupTrainer, FedConfig(**base)),
        "fg_madc": (FedGroupTrainer, FedConfig(**base, measure="madc")),
        "fgp_edc": (FedGrouProxTrainer, FedConfig(**base, mu=0.01)),
        "fg_rcc": (FedGroupTrainer, FedConfig(**base, rcc=True)),
        "fg_rac": (FedGroupTrainer, FedConfig(**base, rac=True)),
    }


def main(quick: bool = False, n_rounds: int | None = None):
    n_rounds = n_rounds or (6 if quick else 12)
    results = {}
    for dname, (data, model_fn, m) in _datasets(quick).items():
        row = {}
        for fname, (cls, cfg) in _frameworks(m).items():
            t0 = time.time()
            tr = cls(model_fn(), data, cfg)
            h = tr.run(n_rounds)
            row[fname] = (h.max_acc, time.time() - t0, tr.comm_params)
        results[dname] = row

    print("\n# Table 3 — max weighted accuracy (early stopping)")
    frameworks = list(_frameworks(3))
    header = f"{'dataset':>18} " + " ".join(f"{f:>8}" for f in frameworks)
    print(header)
    for dname, row in results.items():
        accs = " ".join(f"{row[f][0]:>8.3f}" for f in frameworks)
        print(f"{dname:>18} {accs}")
    print("\n(improvement of fg_edc over fesem, percentage points)")
    for dname, row in results.items():
        print(f"  {dname}: {100 * (row['fg_edc'][0] - row['fesem'][0]):+.1f}")
    print("\n# communication (cumulative params transferred, relative to fedavg)")
    for dname, row in results.items():
        base = max(row['fedavg'][2], 1)
        rel = " ".join(f"{f}={row[f][2]/base:.2f}x" for f in
                       ("fedavg", "ifca", "fesem", "fg_edc"))
        print(f"  {dname}: {rel}")
    return results


if __name__ == "__main__":
    main()

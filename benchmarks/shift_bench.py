"""Benchmark: distribution-shift migration vs static clustering.

Three same-seed streamed runs against one scripted label-swap scenario
(half the population swaps every class at round ``at``), persisted to
BENCH_shift.json (>2x regression gate in benchmarks/run.py, always
included under --quick):

  * FedGroup-static — eq.-9 cold-start assignment, never revisited: the
    paper's baseline, which keeps training swapped clients inside their
    now-wrong groups;
  * FedGroup-migrate — the same trainer with the shift detector enabled
    (``FedConfig.shift_threshold``): drifted clients are re-probed,
    their cached directions invalidated, and eq. 9 re-assigns them;
  * IFCA — re-estimates every client every round (the adaptive upper
    reference that needs no detector but pays the m-model broadcast).

Watched metrics:

  * ``migration_vs_static`` (min): mean post-shift weighted accuracy of
    the migrating run over the static run — the detector's raison
    d'etre; < 1 would mean migration is hurting.
  * ``recovery_rounds``: rounds after the swap until the migrating run
    first matches the static run's same-round accuracy (the acceptance
    bar is <= 10; 0 = never behind).

Schema + gate semantics: docs/benchmarks.md.
"""
from __future__ import annotations

import numpy as np

from benchmarks.bench_io import record_run
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed.engine import FedConfig
from repro.fed.ifca import IFCATrainer
from repro.fed.population import (Population, PopulationConfig, ShiftConfig,
                                  ShiftSpec)
from repro.fed.store import ArrayClientStore
from repro.models.paper_models import mclr


def _cfg(**kw) -> FedConfig:
    base = dict(clients_per_round=10, local_epochs=2, batch_size=5, lr=0.05,
                n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _run(cls, model, data, rounds, shift, **cfg_kw):
    pop = Population(ArrayClientStore(data), PopulationConfig(shift=shift))
    tr = cls(model, None, _cfg(**cfg_kw), population=pop)
    h = tr.run(rounds)
    tr.close()
    accs = np.asarray([r.weighted_acc for r in h.rounds])
    return tr, accs


def _recovery_rounds(acc_mig, acc_static, at):
    """First k >= 1 with migrating acc >= static acc at round at+k
    (0 when the migrating run never falls behind; -1 = no recovery)."""
    behind = False
    for k in range(1, len(acc_mig) - at):
        if acc_mig[at + k] >= acc_static[at + k]:
            if behind:
                return k
        else:
            behind = True
    return 0 if not behind else -1


def main(quick: bool = False):
    model = mclr(16, 10)
    data = mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)
    at = 4 if quick else 6
    post = 6 if quick else 10
    rounds = at + post
    shift = ShiftConfig([ShiftSpec(at=at, frac=0.5)])

    tr_static, acc_static = _run(FedGroupTrainer, model, data, rounds, shift)
    tr_mig, acc_mig = _run(FedGroupTrainer, model, data, rounds, shift,
                           shift_threshold=0.35)
    _, acc_ifca = _run(IFCATrainer, model, data, rounds, shift)

    post_static = float(acc_static[at:].mean())
    post_mig = float(acc_mig[at:].mean())
    migrations = int(tr_mig.obs.registry.get("rounds.migrations"))
    checks = int(tr_mig.obs.registry.get("rounds.shift_checks"))

    metrics = {"quick": quick, "rounds": rounds, "shift_at": at,
               "migrations": migrations, "shift_checks": checks,
               "post_shift_acc_static": post_static,
               "post_shift_acc_migrate": post_mig,
               "post_shift_acc_ifca": float(acc_ifca[at:].mean()),
               "final_acc_static": float(acc_static[-1]),
               "final_acc_migrate": float(acc_mig[-1]),
               "migration_vs_static": post_mig / max(post_static, 1e-9),
               "recovery_rounds": _recovery_rounds(acc_mig, acc_static, at)}
    regression, details = record_run(
        "BENCH_shift.json", metrics,
        watch=[("migration_vs_static", "min")])
    return {"migration_vs_static": round(metrics["migration_vs_static"], 3),
            "recovery_rounds": metrics["recovery_rounds"],
            "migrations": migrations,
            "post_shift_acc_migrate": round(post_mig, 3),
            "post_shift_acc_static": round(post_static, 3),
            "regression": regression, "regression_details": details}

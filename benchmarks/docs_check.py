"""Docs freshness gate: doctests + referenced-path existence.

Two checks, both run as the "docs" entry of benchmarks/run.py (always
included under ``--quick``, so stale docs fail the same CI gate as perf
regressions — see docs/benchmarks.md):

  * every doctest in the documented modules (``fed.store``,
    ``fed.population``, ``fed.parallel``, ``sharding.specs``) must pass —
    the examples embedded in the module docstrings are executable and
    therefore cannot silently rot;
  * every repo path referenced from README.md and docs/*.md must exist:
    markdown link targets plus inline-code tokens that look like repo
    paths (a known file extension, or a ``src``-style module path). A
    deleted or renamed file referenced by the docs turns the gate red.

tests/test_docs.py runs the same checks under pytest (tier-1), so a stale
doc fails locally before it fails the gate.
"""
from __future__ import annotations

import doctest
import glob
import os
import re

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOCUMENTED_MODULES = ("repro.fed.store", "repro.fed.population",
                      "repro.fed.parallel", "repro.fed.strategies",
                      "repro.sharding.specs", "repro.obs.trace",
                      "repro.obs.metrics")
DOC_FILES = ("README.md", "docs/architecture.md", "docs/scaling.md",
             "docs/benchmarks.md", "docs/observability.md")

# inline-code tokens that count as repo path references: plain path chars
# only (rules out prose like `m=5/K=50`), and either a known file
# extension or a multi-segment path starting at a repo top-level dir.
_PATH_TOKEN = re.compile(r"^[A-Za-z0-9_.*/-]+$")
_KNOWN_EXT = (".py", ".md", ".json")
_TOP_DIRS = ("src", "docs", "tests", "benchmarks", "examples")


def run_doctests() -> dict:
    """-> {module: attempted}; raises on any doctest failure."""
    import importlib
    out = {}
    for name in DOCUMENTED_MODULES:
        mod = importlib.import_module(name)
        res = doctest.testmod(mod, verbose=False)
        if res.failed:
            raise RuntimeError(
                f"{res.failed} doctest failure(s) in {name} — the module "
                f"docstring examples are stale (docs/benchmarks.md)")
        out[name] = res.attempted
    return out


def referenced_paths(md_text: str):
    """Candidate repo paths referenced by one markdown document."""
    refs = set()
    for target in re.findall(r"\]\(([^)#]+)\)", md_text):
        target = target.strip()
        if not target or target.startswith(("http://", "https://")):
            continue
        refs.add(target)
    for token in re.findall(r"`([^`\n]+)`", md_text):
        token = token.strip().rstrip("/")
        if not token or not _PATH_TOKEN.match(token):
            continue
        multi = "/" in token
        if token.endswith(_KNOWN_EXT) or \
                (multi and token.split("/")[0] in _TOP_DIRS):
            refs.add(token)
    return refs


def _exists(path: str, doc_dir: str = "") -> bool:
    """Resolve relative to the repo root, the referencing doc's own
    directory (docs/*.md link ``../BENCH_*.json``), and ``src/repro``
    (module-style references like ``fed/store.py``)."""
    candidates = (path, os.path.join(doc_dir, path),
                  os.path.join("src", "repro", path))
    for base in candidates:
        full = os.path.normpath(os.path.join(_REPO, base))
        if "*" in base:
            if glob.glob(full):
                return True
        elif os.path.exists(full):
            return True
    return False


def check_doc_links() -> dict:
    """-> {"files": n_docs, "refs": n_refs}; raises listing missing paths."""
    missing, n_refs, n_docs = [], 0, 0
    for doc in DOC_FILES:
        full = os.path.join(_REPO, doc)
        if not os.path.exists(full):
            missing.append(f"{doc} (the doc itself)")
            continue
        n_docs += 1
        with open(full) as f:
            refs = referenced_paths(f.read())
        n_refs += len(refs)
        missing.extend(f"{doc} -> {r}" for r in sorted(refs)
                       if not _exists(r, os.path.dirname(doc)))
    if missing:
        raise RuntimeError(
            "stale docs — referenced paths do not exist: " +
            "; ".join(missing) + " (gate semantics: docs/benchmarks.md)")
    return {"files": n_docs, "refs": n_refs}


def main(quick: bool = False):
    tested = run_doctests()
    links = check_doc_links()
    print(f"\n# Docs check: {sum(tested.values())} doctests over "
          f"{len(tested)} modules, {links['refs']} path references over "
          f"{links['files']} documents — all fresh")
    return {"doctests": sum(tested.values()), "doc_files": links["files"],
            "path_refs": links["refs"]}


if __name__ == "__main__":
    main()

"""Benchmark harness — one entry per paper table/figure + the roofline.

  table1     FedAvg vs heterogeneity           (paper Table 1)
  table3     framework comparison + ablations  (paper Table 3)
  round_exec fused round executor vs the retired per-group loops
             (static + IFCA/FeSEM dynamic assignment, m=5/K=50)
  round_block scan-fused B=16 round blocks (donated carry, one metrics
             fetch per block) vs the per-round dispatch path, appended
             to BENCH_round_exec.json
  mesh2d     2-D (data, model) mesh vs the 1-D data mesh round time
             (m=5/K=50, 4 forced host devices, appended to
             BENCH_round_exec.json)
  population streamed ClientStore cohorts vs the pinned stacks +
             double-buffered prefetch overlap (N=10^4-10^5 virtual clients)
  robustness fault-tolerant runtime: checkpoint overhead + cold recovery,
             quarantine efficacy under injected NaN payloads, straggler
             deadline saving (BENCH_robustness.json)
  async      staleness-aware async runtime: async-vs-sync throughput
             under a straggler trace + the D=1 equivalence mode's
             overhead (BENCH_async.json)
  shift      distribution-shift migration: FedGroup static vs
             shift-detector migration vs IFCA under a scripted label
             swap (BENCH_shift.json)
  obs        telemetry layer: enabled-vs-disabled overhead on the fused
             round + schema self-lint of the bench's own telemetry dir
             via launch/inspect.py --check (BENCH_obs.json)
  fleet      coordinator/worker control plane: fleet-of-1 routed-lease
             overhead vs engine.run() + hard-killed-worker recovery
             latency (BENCH_fleet.json)
  docs       docs freshness: module doctests + README/docs path existence
  fig5       EDC vs MADC linearity             (paper Fig. 5)
  cost       clustering-measure cost           (paper §3.3 complexity claim)
  roofline   per-(arch×shape) roofline terms   (deliverable g)

``python -m benchmarks.run``          — full run
``python -m benchmarks.run --quick``  — reduced scales (CI-sized)
``python -m benchmarks.run --only table3,fig5``
``python -m benchmarks.run --json out.json``  — machine-readable results

Exit status is nonzero when a bench fails OR when a bench reports a perf
regression >2x against its committed BENCH_*.json baseline (cost watches
the MADC dispatch's relative speed; round_exec the static/IFCA/FeSEM
executor speedups; round_block the blocked-vs-per-round speedup; mesh2d
the 2-D/1-D round-time ratio; population the streamed-vs-pinned
round-time ratio and the prefetch-overlap speedup; robustness the
checkpoint overhead, quarantine efficacy and deadline saving; async the
async-vs-sync throughput and the D=1 equivalence-mode overhead; obs the
enabled-vs-disabled telemetry overhead on the fused round; shift the
migration-vs-static post-swap accuracy ratio; fleet the fleet-of-1
coordinator overhead) — docs/benchmarks.md documents the BENCH_*.json
schema and the gate semantics. Gate failures print a per-entry diff —
which bench, crash vs watched-metric regression, best recorded ->
measured — before the nonzero exit. ``--quick`` always includes the
round_exec, round_block, mesh2d, population, robustness, shift, fleet
and docs suites, even under ``--only``:

``python -m benchmarks.run --quick --only cost,table3``  — the CI perf gate
(effectively cost,table3,round_exec,round_block,mesh2d,population,
robustness,async,obs,shift,fleet,docs)

The harness installs a process-default telemetry (``repro.obs``), so the
``--json`` report carries per-bench per-stage span attribution under each
entry's ``"stages"`` key — the run inspector's breakdown, per bench.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (async_bench, clustering_cost, docs_check,
                        eta_g_sweep, fig5_edc_madc, fleet_bench, mesh2d,
                        obs_bench, population_bench, robustness_bench,
                        roofline, round_block, shift_bench,
                        table1_heterogeneity, table3_frameworks)
from repro.obs import telemetry as obs_telemetry

BENCHES = {
    "table1": table1_heterogeneity.main,
    "table3": table3_frameworks.main,
    "round_exec": table3_frameworks.round_executor_bench,
    "round_block": round_block.main,
    "mesh2d": mesh2d.main,
    "population": population_bench.main,
    "robustness": robustness_bench.main,
    "async": async_bench.main,
    "obs": obs_bench.main,
    "shift": shift_bench.main,
    "fleet": fleet_bench.main,
    "docs": docs_check.main,
    "fig5": fig5_edc_madc.main,
    "cost": clustering_cost.main,
    "eta_g": eta_g_sweep.main,
    "roofline": roofline.main,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        epilog="BENCH_*.json schema and the >2x regression-gate semantics "
               "are documented in docs/benchmarks.md.")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write every bench's derived metrics to PATH")
    args = ap.parse_args(argv)

    names = list(BENCHES) if not args.only else args.only.split(",")
    if args.quick:
        # the CI gate must always exercise the round-executor, round-block,
        # 2-D mesh, population (streamed cohort), robustness (faults /
        # checkpoint / deadline), async (staleness runtime), obs
        # (telemetry overhead), shift (migration efficacy) and fleet
        # (coordinator overhead / kill recovery) suites + the docs check
        for required in ("round_exec", "round_block", "mesh2d",
                         "population", "robustness", "async", "obs",
                         "shift", "fleet", "docs"):
            if required not in names:
                names.append(required)
    # process-default telemetry: trainers/populations the benches build
    # share this tracer (never its registry — repro.obs.from_config), so
    # the report gets the inspector's per-stage breakdown PER BENCH
    tel = obs_telemetry.Telemetry(enabled=True)
    obs_telemetry.set_default(tel)
    print("name,us_per_call,derived")
    rc = 0
    report = {}
    failures = []
    for name in names:
        t0 = time.perf_counter()
        tel.tracer.clear()
        try:
            derived = BENCHES[name](quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name},FAILED,{type(e).__name__}: {e}")
            report[name] = {"error": f"{type(e).__name__}: {e}"}
            failures.append((name, "crash", [f"{type(e).__name__}: {e}"]))
            rc = 1
            continue
        us = (time.perf_counter() - t0) * 1e6
        short = ""
        if isinstance(derived, dict):
            short = ";".join(f"{k}={v}" for k, v in list(derived.items())[:3])
            if derived.get("regression"):
                short = "REGRESSION;" + short
                failures.append((name, "perf regression",
                                 derived.get("regression_details")
                                 or ["regression (no details recorded)"]))
                rc = 1
        elif isinstance(derived, list):
            short = f"rows={len(derived)}"
        report[name] = {"us_per_call": us, "derived": derived,
                        "stages": tel.tracer.stage_totals()}
        print(f"{name},{us:.0f},{short}")
    obs_telemetry.set_default(None)
    if failures:
        # per-entry diff instead of a bare nonzero exit: which bench, crash
        # vs watched-metric regression, best recorded value -> measured
        print("\n# GATE FAILURES (schema + gate semantics: "
              "docs/benchmarks.md)")
        for name, kind, details in failures:
            for d in details:
                print(f"  {name} [{kind}]: {d}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, default=str)
            f.write("\n")
        print(f"# wrote {args.json}")
    return rc


if __name__ == "__main__":
    sys.exit(main())

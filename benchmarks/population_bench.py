"""Benchmark: streamed population engine vs the pinned path.

Two operating points, both persisted to BENCH_population.json (>2x
regression gate in benchmarks/run.py, included under --quick):

  * N_pin (10^4 full / 3·10^3 quick): the largest scale where pinning the
    whole padded population on device is still practical — round time of
    the streamed ClientStore cohort path vs the pinned path, same compiled
    executor. Watched ratio ``streamed_vs_pinned`` (pinned/streamed; 1.0 =
    streaming is free, <1 = streaming overhead).
  * N_stream (10^5 full / 2·10^4 quick): population pinned paths cannot
    materialize on device — streamed rounds with double-buffered prefetch
    vs the same store with prefetch disabled (synchronous select+gather+H2D
    inside the round). Watched ratio ``prefetch_speedup`` (no-prefetch /
    prefetch round time; >1 = the cohort transfer hides behind compute).

Ratios are interleaved per-call minima (bench_io.interleaved_best), so the
gate is stable across host load.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.bench_io import interleaved_best, record_run
from repro.data.generators import virtual_synthetic
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.population import Population, PopulationConfig
from repro.models.paper_models import mclr


def _cfg(quick: bool, seed: int = 0) -> FedConfig:
    return FedConfig(clients_per_round=50, local_epochs=2 if quick else 4,
                     batch_size=10, lr=0.05, seed=seed)


def _streamed_trainer(store, cfg, *, prefetch: int, seed_shift: int = 0):
    pop = Population(store, PopulationConfig(
        prefetch=prefetch, eval_clients=64, eval_batch=64))
    return FedAvgTrainer(mclr(60, 10), None,
                         _cfg_replace(cfg, seed_shift), population=pop)


def _cfg_replace(cfg, seed_shift):
    import dataclasses
    return dataclasses.replace(cfg, seed=cfg.seed + seed_shift)


def _round_thunk(tr):
    """One communication round minus evaluation — select + feed (the only
    part the two modes differ in) + the compiled executor. Evaluation is
    excluded because the pinned path evaluates all N clients while the
    streamed path subsamples; timing it would bias the watched ratio."""
    def thunk():
        idx = tr._select()
        x, y, n = tr._client_batch(idx)
        tr.key, sk = jax.random.split(tr.key)
        keys = jax.random.split(sk, len(idx))
        out = tr._round_executor()(
            jax.tree_util.tree_map(lambda p: p[None], tr.params),
            jnp.zeros(len(idx), jnp.int32), x, y, n, keys)
        tr.params = out.global_params
        jax.block_until_ready(
            jax.tree_util.tree_leaves(out.global_params)[0])
    return thunk


def main(quick: bool = False):
    N_pin = 3_000 if quick else 10_000
    N_stream = 20_000 if quick else 100_000
    reps = 4 if quick else 8
    cfg = _cfg(quick)
    metrics = {"quick": quick, "N_pin": N_pin, "N_stream": N_stream,
               "K": cfg.clients_per_round, "epochs": cfg.local_epochs}

    # -- streamed vs pinned at the largest pinnable scale ------------------
    store = virtual_synthetic(n_clients=N_pin, mean_size=30, max_size=60)
    data = store.materialize()          # the allocation streaming avoids
    pinned = FedAvgTrainer(mclr(60, 10), data, cfg)
    streamed = _streamed_trainer(data.store(), cfg, prefetch=2)
    pin_us, str_us = interleaved_best(
        [_round_thunk(pinned), _round_thunk(streamed)], reps=reps)
    streamed.close()
    metrics.update(pinned_round_us=pin_us, streamed_round_us=str_us,
                   streamed_vs_pinned=pin_us / max(str_us, 1e-9))

    # -- prefetch overlap at the beyond-pinnable scale ---------------------
    # two independent virtual stores so the lazy client caches do not
    # interact; same seed -> identical populations and cohort streams
    s0 = virtual_synthetic(n_clients=N_stream, mean_size=30, max_size=60)
    s2 = virtual_synthetic(n_clients=N_stream, mean_size=30, max_size=60)
    nobuf = _streamed_trainer(s0, cfg, prefetch=0)
    buffered = _streamed_trainer(s2, cfg, prefetch=2)
    no_us, pre_us = interleaved_best(
        [_round_thunk(nobuf), _round_thunk(buffered)], reps=reps)
    nobuf.close()
    buffered.close()
    metrics.update(noprefetch_round_us=no_us, prefetch_round_us=pre_us,
                   prefetch_speedup=no_us / max(pre_us, 1e-9),
                   stream_clients_generated=s2.generated_clients)

    print(f"\n# Population engine (K={cfg.clients_per_round}, "
          f"E={cfg.local_epochs})")
    print(f"  pinned vs streamed @N={N_pin}: {pin_us:.0f}us vs "
          f"{str_us:.0f}us per round -> streamed_vs_pinned="
          f"{metrics['streamed_vs_pinned']:.2f}x")
    print(f"  prefetch overlap  @N={N_stream}: sync {no_us:.0f}us vs "
          f"double-buffered {pre_us:.0f}us -> "
          f"{metrics['prefetch_speedup']:.2f}x "
          f"({s2.generated_clients} of {N_stream} clients ever generated)")

    regression, details = record_run(
        "BENCH_population.json", metrics,
        watch=[("streamed_vs_pinned", "min"), ("prefetch_speedup", "min")])
    if regression:
        print("REGRESSION:", "; ".join(details))
    return {"streamed_vs_pinned": round(metrics["streamed_vs_pinned"], 2),
            "prefetch_speedup": round(metrics["prefetch_speedup"], 2),
            "regression": regression, "regression_details": details,
            **metrics}


if __name__ == "__main__":
    import sys
    sys.exit(0 if not main(quick="--quick" in sys.argv).get("regression")
             else 1)

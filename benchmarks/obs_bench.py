"""Benchmark: telemetry-layer overhead + schema self-lint.

Two obs metrics, persisted to BENCH_obs.json (>2x regression gate in
benchmarks/run.py, always included under --quick):

  * ``obs_overhead``: interleaved wall ratio of the fused round with FULL
    telemetry (span tracer + JSONL round stream into a telemetry dir)
    over the same round with telemetry disabled (watched "max" — the
    acceptance budget is ~1.05x; spans cost two ``perf_counter_ns`` calls
    and a ring append, the stream one small ``write`` per round).
  * ``schema_violations``: ``launch/inspect.py --check`` run against the
    bench's OWN telemetry output (trace.json + metrics.jsonl +
    run_summary.json) — the bench lints what it just produced, so a
    schema drift in the emitters trips the gate here before any consumer
    sees it. Must be 0.

The disabled path is additionally asserted to be a structural no-op:
``Telemetry().span(...)`` returns the shared ``NULL_SPAN`` singleton and
the ring buffer stays empty — "telemetry off" costs one attribute check
per span site, not a record.

Schema + gate semantics: docs/benchmarks.md; span/metric inventory:
docs/observability.md.
"""
from __future__ import annotations

import shutil
import tempfile

from benchmarks.bench_io import interleaved_best, record_run
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.launch.inspect import check_dir
from repro.models.paper_models import mclr
from repro.obs import NULL_SPAN, Telemetry
from repro.obs import telemetry as obs_telemetry


def _cfg(**kw) -> FedConfig:
    base = dict(clients_per_round=8, local_epochs=2, batch_size=5, lr=0.05,
                n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


def _assert_disabled_noop():
    tel = Telemetry()                       # fresh, disabled
    assert tel.span("stage", t=0) is NULL_SPAN, \
        "disabled Telemetry.span must return the NULL_SPAN singleton"
    with tel.span("stage", t=0):
        pass
    assert tel.tracer.records() == [], \
        "disabled tracer must not record spans"
    assert not tel.recording, "no sink configured => not recording"


def main(quick: bool = False):
    model, data = mclr(16, 10), _data()
    reps = 4 if quick else 10
    _assert_disabled_noop()

    tdir = tempfile.mkdtemp(prefix="bench_obs_")
    # the harness (benchmarks/run.py) installs a process-default telemetry
    # whose tracer would leak into the "disabled" trainer via from_config
    # — suspend it so the off-path really is off
    saved = obs_telemetry.get_default()
    obs_telemetry.set_default(None)
    try:
        off = FedAvgTrainer(model, data, _cfg())
        on = FedAvgTrainer(model, data, _cfg(telemetry_dir=tdir))
        assert not off.obs.enabled and on.obs.enabled and on.obs.recording
        t_off, t_on = interleaved_best(
            [lambda: off.run(2), lambda: on.run(2)], reps=reps)
        overhead = t_on / max(t_off, 1e-9)
        kinds = sorted({r.kind for r in on.obs.tracer.records()})
        on.close()                          # writes trace.json + summary
        off.close()
        errors = check_dir(tdir)            # lint our own telemetry output
        if errors:
            raise AssertionError(
                "telemetry schema violations in bench output: " +
                "; ".join(errors))
    finally:
        obs_telemetry.set_default(saved)
        shutil.rmtree(tdir, ignore_errors=True)

    metrics = {"quick": quick, "reps": reps,
               "t_off_us": t_off, "t_on_us": t_on,
               "obs_overhead": overhead,
               "span_kinds": kinds,
               "schema_violations": len(errors)}
    regression, details = record_run(
        "BENCH_obs.json", metrics, watch=[("obs_overhead", "max")])
    return {"obs_overhead": round(overhead, 3),
            "span_kinds": ",".join(kinds),
            "schema_violations": len(errors),
            "regression": regression, "regression_details": details}

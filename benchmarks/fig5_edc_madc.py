"""Benchmark: paper Fig. 5 — the MADC -> EDC mapping is ~linear.

Generates pre-training updates from a real federated cold start, computes
both measures for all client pairs, and fits EDC = a*MADC + b; reports R².
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import measures
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed.engine import FedConfig
from repro.models.modules import flatten_updates
from repro.models.paper_models import mclr


def main(quick: bool = False):
    dim = 64 if quick else 256
    data = mnist_like(0, n_clients=80, classes_per_client=2,
                      total_train=5000, dim=dim)
    cfg = FedConfig(clients_per_round=20, local_epochs=10, batch_size=10,
                    lr=0.05, n_groups=3, pretrain_scale=20, seed=0)
    tr = FedGroupTrainer(mclr(dim, 10), data, cfg)
    pre_idx = tr.rng.choice(data.n_clients, 60, replace=False)
    deltas, _, _ = tr._solve(tr.params, pre_idx)
    dW = jax.vmap(flatten_updates)(deltas)

    M = measures.cosine_similarity_matrix(dW)
    madc_d = np.asarray(measures.madc(M))
    edc_d = np.asarray(measures.edc(dW, m=cfg.n_groups))

    iu = np.triu_indices(len(pre_idx), 1)
    x, y = madc_d[iu], edc_d[iu]
    A = np.stack([x, np.ones_like(x)], 1)
    coef, res, *_ = np.linalg.lstsq(A, y, rcond=None)
    ss_res = float(((A @ coef - y) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    r2 = 1 - ss_res / max(ss_tot, 1e-12)

    print("\n# Fig. 5 — EDC vs MADC linearity")
    print(f"  pairs={len(x)} d_w={dW.shape[1]} slope={coef[0]:.3f} "
          f"intercept={coef[1]:.4f} R^2={r2:.3f}")
    return {"r2": r2, "slope": float(coef[0]), "n_pairs": int(len(x))}


if __name__ == "__main__":
    main()

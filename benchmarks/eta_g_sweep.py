"""Benchmark: §5.2 semi-pluralistic exploration — inter-group aggregation
rate η_G sweep, plus the paper's stated future work (gate-network group
combination, core/gating.py) evaluated at several temperatures."""
from __future__ import annotations

import numpy as np

from repro.core import gating
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed.engine import FedConfig
from repro.models.paper_models import mclr


def main(quick: bool = False):
    dim = 64 if quick else 128
    rounds = 5 if quick else 10
    data = mnist_like(0, n_clients=120, classes_per_client=2,
                      total_train=8000, dim=dim)
    model = mclr(dim, 10)
    base = dict(n_rounds=rounds, clients_per_round=20, local_epochs=10,
                batch_size=10, lr=0.05, n_groups=3, pretrain_scale=10, seed=0)

    print("\n# eta_G sweep (semi-pluralistic inter-group aggregation, §5.2)")
    print(f"{'eta_g':>7} {'max_acc':>8} {'rounds>=0.6':>11}")
    results = {}
    trainers = {}
    for eta in (0.0, 0.005, 0.02, 0.1):
        tr = FedGroupTrainer(model, data, FedConfig(**base, eta_g=eta))
        h = tr.run()
        results[eta] = h.max_acc
        trainers[eta] = tr
        print(f"{eta:>7} {h.max_acc:>8.3f} {str(h.rounds_to_reach(0.6)):>11}")

    print("\n# gate-network group combination (paper future work)")
    tr = trainers[0.0]
    hard = tr.evaluate_groups()
    print(f"{'temperature':>12} {'gated_acc':>10}   (hard assignment: {hard:.3f})")
    gated = {}
    for tau in (0.05, 0.2, 1.0):
        acc = gating.evaluate_gated(tr, temperature=tau)
        gated[tau] = acc
        print(f"{tau:>12} {acc:>10.3f}")
    return {"eta_sweep": results, "hard_acc": hard, "gated": gated}


if __name__ == "__main__":
    main()

"""Machine-readable benchmark persistence + regression gating.

Each bench entry that tracks a perf trajectory appends its metrics to a
BENCH_*.json file at the repo root:

    {"runs": [{..metrics.., "timestamp": ...}, ...]}

``record_run`` compares the fresh metrics against the committed trajectory
and flags a regression when a watched metric moved more than ``factor``× in
the bad direction. Two properties keep the gate honest:

  * the reference is the BEST recorded value of each watched metric (within
    the kept window), not merely the previous run — so a slow drift of
    <factor per run still trips once it compounds past factor overall;
  * regressed runs are NOT appended — the committed baseline stays
    authoritative and a red CI run stays red on retry instead of comparing
    the regression against itself.

Only runs from the same mode (``quick`` flag) are compared, since reduced
scales measure different operating points. Ratio-style metrics (speedups)
are preferred for the watched keys because they are stable across machines,
unlike raw wall times. To accept an intentional perf change, delete the
stale runs from the BENCH file (or the file itself) and re-run.
"""
from __future__ import annotations

import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_KEEP_RUNS = 20


def interleaved_best(thunks, reps: int = 15):
    """Per-call minima with the candidates interleaved, in µs per thunk.

    Sub-ms wall times on a contended host swing >2x call to call; the
    minimum estimates the uncontended time, and interleaving keeps ratios
    of the thunks from inheriting load drift between back-to-back timing
    loops. Every watched (gated) timing ratio should come through here.
    Thunks must synchronize internally (block_until_ready); the first call
    of each doubles as compile warm-up and is not timed.
    """
    for t in thunks:
        t()
    times = [[] for _ in thunks]
    for _ in range(reps):
        for i, t in enumerate(thunks):
            t0 = time.perf_counter()
            t()
            times[i].append(time.perf_counter() - t0)
    return [min(ts) * 1e6 for ts in times]


def _load(path):
    try:
        with open(path) as f:
            data = json.load(f)
        if isinstance(data, dict) and isinstance(data.get("runs"), list):
            return data
    except (OSError, ValueError):
        pass
    return {"runs": []}


def record_run(filename: str, metrics: dict, *, watch=(), factor: float = 2.0):
    """Record ``metrics`` in BENCH file ``filename`` (repo root).

    watch: iterable of (key, direction) with direction "min" (regression when
    the value shrank by > factor, e.g. a speedup) or "max" (regression when
    it grew by > factor, e.g. a wall time). The reference value per key is
    the best same-mode recorded value; the run is appended only when it does
    not regress. Returns (regression, details).

    Two suites may share one BENCH file (round_exec and mesh2d both append
    to BENCH_round_exec.json); runs are distinguished by which watched keys
    they carry, and the keep-window applies to the appending suite's own
    runs only (those carrying any of its watched keys) — appending never
    evicts another suite's history (and with it, its gate baseline) from
    the file, and the file's chronological order is preserved. A call with
    no watched keys falls back to the whole-file window. See
    docs/benchmarks.md.
    """
    path = os.path.join(REPO_ROOT, filename)
    data = _load(path)
    same_mode = [r for r in data["runs"]
                 if r.get("quick") == metrics.get("quick")]

    regression, details = False, []
    for key, direction in watch:
        b = metrics.get(key)
        history = [r[key] for r in same_mode
                   if isinstance(r.get(key), (int, float)) and r[key] > 0]
        if not (history and isinstance(b, (int, float)) and b > 0):
            continue
        a = max(history) if direction == "min" else min(history)
        bad = (b < a / factor) if direction == "min" else (b > a * factor)
        if bad:
            regression = True
            details.append(f"{key}: best {a:.3g} -> {b:.3g} "
                           f"(>{factor}x {direction}-regression)")
    if not regression:
        new_entry = {**metrics, "timestamp": round(time.time(), 1)}
        watch_keys = [k for k, _ in watch]
        if watch_keys:
            # trim only THIS suite's runs (those carrying any of its
            # watched keys), oldest first, in place — other suites' runs
            # and the file's chronological order are untouched
            mine = lambda r: any(k in r for k in watch_keys)
            drop = max(0, sum(map(mine, data["runs"])) + 1 - _KEEP_RUNS)
            kept = []
            for r in data["runs"]:
                if drop > 0 and mine(r):
                    drop -= 1
                    continue
                kept.append(r)
            data["runs"] = kept + [new_entry]
        else:
            data["runs"] = (data["runs"] + [new_entry])[-_KEEP_RUNS:]
        with open(path, "w") as f:
            json.dump(data, f, indent=1)
            f.write("\n")
    return regression, details

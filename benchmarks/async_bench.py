"""Benchmark: staleness-aware async runtime throughput + equivalence cost.

Two async metrics, persisted to BENCH_async.json (>2x regression gate in
benchmarks/run.py, always included under --quick):

  * ``async_speedup``: wall ratio of the synchronous runtime (inline
    staging, blocking per-round adoption: ``prefetch=0, async_depth=0``)
    over the async runtime (``async_depth=D`` bounded in-flight dispatches
    fed by a prefetching population) training the same rounds under the
    same scripted straggler trace. The synchronous loop pays every
    cohort's staging straggle AND the device round-trip serially; the
    async loop hides staging behind in-flight device compute and folds
    without barriering the next dispatch (watched "min" — the acceptance
    floor is >= 1.5x under the trace). ``sync_wall_s`` / ``async_wall_s``
    record the raw walls, ``staleness_hist`` / ``max_in_flight`` the
    async run's degradation record.
  * ``equivalence_overhead``: interleaved wall ratio of the D=1
    equivalence mode (weight-1.0 bitwise-passthrough folds, same results
    as sync) over the synchronous per-round path at the same prefetch —
    what the async machinery costs when its semantics are pinned to the
    synchronous ones (watched "max").

The full straggler-trace matrix across frameworks x depths lives in
tests/test_async.py behind the ``slow`` marker (REPRO_SLOW=1); this bench
keeps the CI gate to the two load-bearing ratios.

Schema + gate semantics: docs/benchmarks.md.
"""
from __future__ import annotations

import time

from benchmarks.bench_io import interleaved_best, record_run
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.population import (FaultConfig, FaultSpec, Population,
                                  PopulationConfig)
from repro.fed.store import ArrayClientStore
from repro.models.paper_models import mclr


def _cfg(**kw) -> FedConfig:
    base = dict(clients_per_round=8, local_epochs=2, batch_size=5, lr=0.05,
                n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


def _straggle_trace(rounds: int, straggle: float) -> FaultConfig:
    # round 0 is the untimed compile warmup; every timed round straggles
    return FaultConfig(rounds={t: FaultSpec(straggle=straggle)
                               for t in range(1, rounds + 1)})


def _timed_run(model, data, *, rounds: int, straggle: float, depth: int,
               prefetch: int):
    """Wall time of ``rounds`` rounds under the straggler trace, after one
    untimed warmup round (compiles the dispatch/fold programs)."""
    pop = Population(ArrayClientStore(data), PopulationConfig(
        initial_active=40, arrival_rate=0.0, prefetch=prefetch,
        faults=_straggle_trace(rounds, straggle)))
    tr = FedAvgTrainer(model, None, _cfg(async_depth=depth),
                       population=pop)
    tr.run(1)                                   # warmup: clean round 0
    t0 = time.perf_counter()
    h = tr.run(rounds)
    wall = time.perf_counter() - t0
    tr.close()
    return wall, dict(h.async_stats)


def _equivalence_overhead(model, data, reps: int) -> float:
    """Interleaved 'run 2 more rounds' segments: the D=1 equivalence mode
    vs the synchronous per-round path, same prefetch, no faults — both
    keep training forward on warm executors."""
    def fresh(depth):
        pop = Population(ArrayClientStore(data), PopulationConfig(
            initial_active=40, arrival_rate=0.0, prefetch=2))
        return FedAvgTrainer(model, None, _cfg(async_depth=depth),
                             population=pop)

    sync, asy = fresh(0), fresh(1)
    t_sync, t_asy = interleaved_best(
        [lambda: sync.run(2), lambda: asy.run(2)], reps=reps)
    sync.close()
    asy.close()
    return t_asy / max(t_sync, 1e-9)


def main(quick: bool = False):
    model, data = mclr(16, 10), _data()
    rounds = 6 if quick else 10
    straggle = 0.08 if quick else 0.15
    depth = 4
    reps = 3 if quick else 6

    sync_wall, _ = _timed_run(model, data, rounds=rounds,
                              straggle=straggle, depth=0, prefetch=0)
    async_wall, st = _timed_run(model, data, rounds=rounds,
                                straggle=straggle, depth=depth,
                                prefetch=depth)
    speedup = sync_wall / max(async_wall, 1e-9)
    overhead = _equivalence_overhead(model, data, reps)

    metrics = {"quick": quick, "rounds": rounds, "straggle_s": straggle,
               "async_depth": depth,
               "sync_wall_s": sync_wall, "async_wall_s": async_wall,
               "async_speedup": speedup,
               "max_in_flight": int(st.get("max_in_flight", 0)),
               "staleness_hist": st.get("staleness_hist", {}),
               "equivalence_overhead": overhead}
    regression, details = record_run(
        "BENCH_async.json", metrics,
        watch=[("async_speedup", "min"),
               ("equivalence_overhead", "max")])
    return {"async_speedup": round(speedup, 2),
            "equivalence_overhead": round(overhead, 3),
            "sync_wall_s": round(sync_wall, 3),
            "async_wall_s": round(async_wall, 3),
            "regression": regression, "regression_details": details}

"""Quickstart: FedGroup in ~30 lines.

Cluster 100 label-skewed clients into 3 groups with the EDC measure and
train 10 communication rounds, comparing against FedAvg.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.models.paper_models import mclr


def main():
    # 100 clients, each holding only 2 of 10 classes (high heterogeneity)
    data = mnist_like(seed=0, n_clients=100, classes_per_client=2,
                      total_train=8000, dim=64)
    model = mclr(64, 10)
    cfg = FedConfig(n_rounds=10, clients_per_round=20, local_epochs=10,
                    batch_size=10, lr=0.05, n_groups=3, pretrain_scale=10)

    fedavg = FedAvgTrainer(model, data, cfg)
    fedgroup = FedGroupTrainer(model, data, cfg)

    print("round |  FedAvg | FedGroup")
    for t in range(cfg.n_rounds):
        a = fedavg.round(t)
        g = fedgroup.round(t)
        print(f"{t:5d} | {a.weighted_acc:7.3f} | {g.weighted_acc:8.3f}")

    print(f"\nmax accuracy: FedAvg {fedavg.history.max_acc:.3f} "
          f"vs FedGroup {fedgroup.history.max_acc:.3f} "
          f"(+{100*(fedgroup.history.max_acc - fedavg.history.max_acc):.1f}pp)")


if __name__ == "__main__":
    main()

"""Substrate driver: train a reduced zoo architecture for a few hundred
steps on CPU and decode from it — exercises the same train_step/serve_step
the production dry-run lowers on the 16x16 mesh.

  PYTHONPATH=src python examples/zoo_train.py --arch zamba2-1.2b --steps 200
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    print("=== train (smoke config, synthetic tokens) ===")
    train.main(["--mode", "lm", "--arch", args.arch, "--smoke",
                "--steps", str(args.steps), "--batch", "8", "--seq", "64"])
    print("\n=== serve (batched decode) ===")
    serve.main(["--arch", args.arch, "--smoke", "--batch", "4",
                "--prompt-len", "16", "--gen", "16"])


if __name__ == "__main__":
    main()

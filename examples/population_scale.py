"""Population-scale FedGroup: 50k synthetic clients streamed through the
ClientStore cohort path — nothing population-sized ever reaches the device.

The population starts with 60% of its clients active; every round a
Poisson batch of newcomers arrives (FlexCFL's framework stress test) and a
diurnal availability trace gates who can participate. Newcomers are routed
by the paper's eq.-9 client cold start the round they first show up, so
the cold-start path runs *continuously*, not once — watch the per-round
cohort / newcomer / cold-start counts.

  PYTHONPATH=src python examples/population_scale.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import virtual_synthetic
from repro.fed.engine import FedConfig
from repro.fed.population import Population, PopulationConfig
from repro.models.paper_models import mclr

N = 50_000
ROUNDS = 12


def main():
    store = virtual_synthetic(n_clients=N, mean_size=30, max_size=60)
    pop = Population(store, PopulationConfig(
        sampler="size",                 # busy devices report more data
        availability="diurnal", period=12, duty=0.5,
        initial_active=int(0.6 * N), arrival_rate=15.0,
        prefetch=2))
    cfg = FedConfig(n_rounds=ROUNDS, clients_per_round=60, local_epochs=4,
                    batch_size=10, lr=0.05, n_groups=5, pretrain_scale=10,
                    seed=0)
    tr = FedGroupTrainer(mclr(60, 10), None, cfg, population=pop)

    print(f"population: {N} clients ({pop.cfg.initial_active} initially "
          f"active), diurnal period {pop.cfg.period}, "
          f"~{pop.cfg.arrival_rate:.0f} arrivals/round")
    print(f"{'round':>5} {'cohort':>6} {'new':>5} {'cold':>5} "
          f"{'assigned':>8} {'acc':>6} {'loss':>6}  s/round")
    t_prev = time.time()
    for t in range(ROUNDS):
        m = tr.round(t)
        dt, t_prev = time.time() - t_prev, time.time()
        # per-cohort arrival count travels on the Cohort itself — the
        # scheduler has already prefetched ahead of the consumed round
        print(f"{t:>5} {len(pop._cohort.idx):>6} "
              f"{pop._cohort.n_new:>5} {tr.last_cold:>5} "
              f"{int((tr.membership >= 0).sum()):>8} "
              f"{m.weighted_acc:>6.3f} {m.mean_loss:>6.3f}  {dt:.2f}")
    tr.close()

    touched = store.generated_clients
    print(f"\nclients ever materialized: {touched} / {N} "
          f"({100 * touched / N:.2f}% — the stacked arrays the pinned path "
          f"would have uploaded never exist)")
    print(f"state-table rows held: {pop.state.touched_rows()} "
          f"(pre-training direction cache)")
    still_cold = int((tr.membership < 0).sum())
    print(f"cold (never sampled or not yet arrived): {still_cold}")


if __name__ == "__main__":
    main()

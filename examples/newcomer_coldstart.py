"""Newcomer cold start (paper §3.4, eq. 9): train FedGroup on a subset of
clients, then have unseen devices join mid-training. Shows that newcomers
are routed to the group whose optimization direction matches theirs —
validated against the latent structure of the data generator.

  PYTHONPATH=src python examples/newcomer_coldstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import femnist_like
from repro.fed.engine import FedConfig
from repro.models.paper_models import mlp


def main():
    # femnist_like has latent writer "styles" — the ground-truth clusters
    data = femnist_like(seed=0, n_clients=120, total_train=9000, dim=128,
                        n_styles=3)
    styles = data.meta["style_of"]
    cfg = FedConfig(n_rounds=8, clients_per_round=20, local_epochs=10,
                    batch_size=10, lr=0.05, n_groups=3, pretrain_scale=10,
                    seed=0)
    tr = FedGroupTrainer(mlp(128, 128, 62), data, cfg)

    for t in range(8):
        m = tr.round(t)
        print(f"round {t}: acc={m.weighted_acc:.3f}")

    # newcomers: clients never seen so far
    cold = np.where(tr.membership < 0)[0][:30]
    print(f"\n{len(cold)} newcomers join -> client cold start (eq. 9)")
    tr.client_cold_start(cold)

    # do assigned groups align with the latent style clusters?
    groups = tr.membership[cold]
    agreement = 0
    for g in np.unique(groups):
        members = cold[groups == g]
        if len(members) == 0:
            continue
        dominant_style = np.bincount(styles[members]).argmax()
        agreement += (styles[members] == dominant_style).sum()
    print(f"style purity of newcomer assignment: {agreement}/{len(cold)} "
          f"({100*agreement/len(cold):.0f}% — random would be ~33%)")

    for t in range(8, 10):
        m = tr.round(t)
        print(f"round {t}: acc={m.weighted_acc:.3f} "
              f"(newcomers now contribute)")


if __name__ == "__main__":
    main()

"""Multi-pod dry-run walkthrough: lower ONE (arch x shape) on the production
mesh and print its roofline row — the smallest end-to-end tour of
deliverables (e)+(g).

  PYTHONPATH=src python examples/multipod_dryrun_demo.py \
      --arch gemma-2b --shape train_4k [--multi-pod]

NOTE: must run as its own process (the dry-run claims 512 placeholder
devices before jax initializes).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS on import)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    rec = dryrun.run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                         save=False)
    if rec["status"] != "ok":
        print(f"{rec['status']}: {rec.get('reason', rec.get('error'))}")
        return

    from benchmarks import roofline
    row = roofline.row_for(args.arch, args.shape,
                           mesh=rec["mesh"])
    print("\nroofline row:")
    for k in ("compute_s", "memory_s", "collective_s"):
        print(f"  {k:14s} {row[k]*1e3:10.3f} ms")
    print(f"  dominant       {row['dominant']}")
    print(f"  useful FLOPs   {100*row['useful_ratio']:.1f}% "
          f"(MODEL_FLOPS / analytic total)")
    print(f"  params         {row['params_total']/1e9:.2f}B total, "
          f"{row['params_active']/1e9:.2f}B active")


if __name__ == "__main__":
    main()

"""End-to-end driver: the paper's FEMNIST experiment (Table 3 row) at
reduced scale — trains FedAvg, FedProx, FeSEM, IFCA, FedGroup-EDC and
FedGroup-MADC for a few hundred rounds' worth of optimization (scaled), with
checkpointing and a JSON metrics report.

  PYTHONPATH=src python examples/femnist_fedgroup.py --rounds 25
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint.io import save_pytree
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import femnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig, FedProxTrainer
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer
from repro.models.paper_models import mlp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--out", default="experiments/femnist_run")
    args = ap.parse_args()

    data = femnist_like(seed=0, n_clients=200, total_train=15000, dim=128)
    model_fn = lambda: mlp(128, 128, 62)
    base = dict(n_rounds=args.rounds, clients_per_round=20, local_epochs=10,
                batch_size=10, lr=0.05, n_groups=5, pretrain_scale=10, seed=0)

    runs = {
        "fedavg": (FedAvgTrainer, FedConfig(**base)),
        "fedprox": (FedProxTrainer, FedConfig(**base, mu=0.01)),
        "fesem": (FeSEMTrainer, FedConfig(**base)),
        "ifca": (IFCATrainer, FedConfig(**base)),
        "fedgroup_edc": (FedGroupTrainer, FedConfig(**base)),
        "fedgroup_madc": (FedGroupTrainer, FedConfig(**base, measure="madc")),
    }
    os.makedirs(args.out, exist_ok=True)
    report = {}
    for name, (cls, cfg) in runs.items():
        t0 = time.time()
        tr = cls(model_fn(), data, cfg)
        h = tr.run()
        report[name] = {
            "max_acc": h.max_acc,
            "final_acc": h.rounds[-1].weighted_acc,
            "rounds_to_60": h.rounds_to_reach(0.60),
            "wall_s": round(time.time() - t0, 1),
        }
        print(f"{name:>15}: max_acc={h.max_acc:.3f} "
              f"({report[name]['wall_s']}s)")
        from repro.fed.server import tree_index
        params = (tree_index(tr.group_params, 0)
                  if hasattr(tr, "group_params") else tr.params)
        save_pytree(os.path.join(args.out, f"{name}.npz"), params,
                    {"framework": name, "max_acc": h.max_acc})
    with open(os.path.join(args.out, "report.json"), "w") as f:
        json.dump(report, f, indent=1)
    print(f"\nreport -> {args.out}/report.json")


if __name__ == "__main__":
    main()

"""Telemetry bundle: one object owning the span tracer, the metrics
registry, and the on-disk stream for a run.

Layout of a telemetry dir (``FedConfig.telemetry_dir``):

  metrics.jsonl       one JSON object per round record (deterministic:
                      sorted keys, fixed separators, NO wall-clock
                      fields — bit-stable across kill-and-resume)
  metrics-NNNNN.jsonl rotated segments (atomic ``os.replace`` rotation)
  trace.json          Chrome trace-event export of the span ring buffer
  run_summary.json    final counters + per-stage totals + slowest rounds

The engine truncates ``metrics.jsonl`` on checkpoint resume
(:meth:`Telemetry.resume_at`) so records for rounds >= the restore point
are dropped before the resumed run re-emits them — no duplicates, and
the resumed stream is byte-identical to an uninterrupted one.

A process-wide *default* telemetry (:func:`set_default`) lets harnesses
(``benchmarks/run.py``) thread span collection through trainers they did
not construct: ``from_config`` always returns a fresh bundle (its own
registry — counters never bleed between populations), sharing only the
default's tracer when one is installed.
"""
from __future__ import annotations

import json
import os

from repro.obs import metrics as metrics_lib
from repro.obs import trace as trace_lib

_ASYNC_VIEW = {
    "dispatches": "async.dispatches",
    "folds": "async.folds",
    "max_in_flight": "async.max_in_flight",
    "lease_expiries": "async.lease_expiries",
    "requeues": "async.requeues",
    "staleness_hist": "async.staleness_hist",
}

SUMMARY_FORMAT = 1


class JsonlSink:
    """Append-only JSONL stream with atomic size-based rotation."""

    def __init__(self, directory: str, name: str = "metrics",
                 max_bytes: int = 64 * 1024 * 1024):
        self.directory = directory
        self.name = name
        self.max_bytes = int(max_bytes)
        self.path = os.path.join(directory, f"{name}.jsonl")
        self._rotated = 0
        self._fh = None
        self._size = 0
        os.makedirs(directory, exist_ok=True)
        for f in sorted(os.listdir(directory)):
            if f.startswith(f"{name}-") and f.endswith(".jsonl"):
                self._rotated += 1

    @staticmethod
    def encode(record: dict) -> str:
        # deterministic encoding — the bit-stability contract
        return json.dumps(record, sort_keys=True, separators=(",", ":"))

    def _open(self):
        # persistent append handle: a per-record open/close costs more
        # than the round record itself on the fused round (BENCH_obs);
        # flush-per-record keeps every line visible to the OS, which is
        # what kill-and-resume needs (process death, not power loss)
        self._fh = open(self.path, "a")
        self._size = self._fh.tell()

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def emit(self, record: dict):
        line = self.encode(record) + "\n"
        if self._fh is None:
            self._open()
        if self._size and self._size + len(line) > self.max_bytes:
            self.close()
            dst = os.path.join(self.directory,
                               f"{self.name}-{self._rotated:05d}.jsonl")
            os.replace(self.path, dst)
            self._rotated += 1
            self._open()
        self._fh.write(line)
        self._fh.flush()
        self._size += len(line)

    def segment_paths(self) -> list:
        segs = sorted(
            os.path.join(self.directory, f) for f in os.listdir(self.directory)
            if f.startswith(f"{self.name}-") and f.endswith(".jsonl"))
        if os.path.exists(self.path):
            segs.append(self.path)
        return segs

    def records(self) -> list:
        out = []
        for path in self.segment_paths():
            with open(path) as f:
                for line in f:
                    if line.strip():
                        out.append(json.loads(line))
        return out

    def truncate_from(self, t: int):
        """Drop round records with ``rec['t'] >= t`` (resume point) and
        compact the stream back into the main file, atomically."""
        self.close()
        keep = [r for r in self.records()
                if not (r.get("kind") == "round" and r.get("t", -1) >= t)]
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            for r in keep:
                f.write(self.encode(r) + "\n")
        for path in self.segment_paths():
            if path != self.path:
                os.remove(path)
        os.replace(tmp, self.path)
        self._rotated = 0


class Telemetry:
    """Tracer + registry + (optional) on-disk stream for one run."""

    def __init__(self, enabled: bool = False, directory: str | None = None,
                 capacity: int = 65536, annotate: bool = False,
                 tracer: trace_lib.Tracer | None = None):
        # the registry is ALWAYS fresh — counters must not bleed between
        # populations/trainers constructed in one process (benchmarks
        # assert on exact per-run counts); only the tracer may be shared
        # (``from_config`` threads the process default's tracer through so
        # a harness can collect spans from trainers it did not build)
        self.registry = metrics_lib.MetricsRegistry()
        self.tracer = tracer if tracer is not None else trace_lib.Tracer(
            enabled=enabled, capacity=capacity, annotate=annotate)
        self.directory = None
        self._sink = None
        if directory:
            self.configure(directory)

    # -- wiring ---------------------------------------------------------
    def configure(self, directory: str | None = None, enabled: bool = True,
                  annotate: bool | None = None):
        """Enable tracing and (when ``directory`` is set) open the JSONL
        stream. Called by ``Population.attach`` / trainer init from
        ``FedConfig.telemetry_dir``."""
        self.tracer.enabled = bool(enabled)
        if annotate is not None:
            self.tracer.annotate = bool(annotate)
        if directory:
            self.directory = directory
            self._sink = JsonlSink(directory)
        return self

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled

    @property
    def recording(self) -> bool:
        """True when round records should be built (a sink is open)."""
        return self._sink is not None

    # -- tracing delegates ---------------------------------------------
    def span(self, kind: str, **attrs):
        return self.tracer.span(kind, **attrs)

    def wrap(self, kind: str, fn, **attrs):
        return self.tracer.wrap(kind, fn, **attrs)

    # -- legacy views ---------------------------------------------------
    def async_view(self) -> metrics_lib.MetricsView:
        """``History.async_stats``-shaped view over the async.* metrics."""
        return self.registry.view(_ASYNC_VIEW)

    # -- stream ---------------------------------------------------------
    def round_record(self, record: dict):
        if self._sink is not None:
            self._sink.emit(record)

    def resume_at(self, t: int):
        """Checkpoint resume at round ``t``: drop already-streamed records
        for t' >= t and restart the span clock (cumulative counters come
        back via ``registry.restore`` from checkpoint meta)."""
        if self._sink is not None:
            self._sink.truncate_from(t)
        self.tracer.clear()

    def stream_records(self) -> list:
        return self._sink.records() if self._sink is not None else []

    # -- finalization ---------------------------------------------------
    def summary(self, extra: dict | None = None) -> dict:
        stages = self.tracer.stage_totals()
        rounds = self.tracer.round_totals()
        top = sorted(rounds.items(), key=lambda kv: -kv[1])[:10]
        doc = {
            "format": SUMMARY_FORMAT,
            "counters": self.registry.snapshot(),
            "stages": stages,
            "span_kinds": sorted(stages),
            "top_rounds": [{"t": t, "s": s} for t, s in top],
        }
        if extra:
            doc.update(extra)
        return doc

    def finalize(self, extra: dict | None = None) -> dict | None:
        """Write ``trace.json`` + ``run_summary.json`` (idempotent; no-op
        without a directory)."""
        if not self.directory:
            return None
        if self._sink is not None:
            self._sink.close()      # emit() reopens lazily if run resumes
        trace_lib.export_chrome_trace(
            os.path.join(self.directory, "trace.json"), self.tracer)
        doc = self.summary(extra)
        tmp = os.path.join(self.directory, "run_summary.json.tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, os.path.join(self.directory, "run_summary.json"))
        return doc

    def profile(self, subdir: str = "profile"):
        """Programmatic ``jax.profiler`` capture scoped to a with-block."""
        tel = self

        class _Profile:
            def __enter__(self):
                trace_lib.start_profiler(
                    os.path.join(tel.directory or ".", subdir))
                return self

            def __exit__(self, *exc):
                trace_lib.stop_profiler()
                return False

        return _Profile()


# -- process-wide default (benchmark harness hook) -----------------------
_DEFAULT: Telemetry | None = None


def set_default(tel: Telemetry | None):
    global _DEFAULT
    _DEFAULT = tel


def get_default() -> Telemetry | None:
    return _DEFAULT


def from_config(cfg) -> Telemetry:
    """Telemetry for a trainer: always a FRESH bundle (own registry), but
    sharing the process default's *tracer* when one is installed — span
    collection crosses object boundaries, metric counts never do.
    ``cfg.telemetry_dir`` additionally opens the JSONL stream."""
    shared = _DEFAULT.tracer if _DEFAULT is not None else None
    tdir = getattr(cfg, "telemetry_dir", None)
    if tdir:
        return Telemetry(enabled=True, directory=tdir, tracer=shared)
    return Telemetry(enabled=False, tracer=shared)

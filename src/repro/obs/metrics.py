"""Typed metrics registry: counters / gauges / histograms with a declared
schema, plus dict-like *views* that keep the runtime's historical surfaces
(``Population.stats``, ``History.async_stats``) working unchanged.

Namespacing matters: ``lease_expiries`` / ``requeues`` exist both as async
window counters (``async.*`` — incremented by the engine's fill loop) and
as population degradation counters (``pop.*`` — incremented by the
streamed staging path); a view maps the short legacy key to its
namespaced metric, so the two never collide in one registry.

Snapshots are plain JSON-able dicts and round-trip through checkpoint
meta: :meth:`MetricsRegistry.snapshot` → ``__meta__`` →
:meth:`MetricsRegistry.restore`.

>>> reg = MetricsRegistry()
>>> reg.declare([MetricSpec("pop.killed_clients", COUNTER)])
>>> reg.inc("pop.killed_clients", 3)
>>> view = reg.view({"killed_clients": "pop.killed_clients"})
>>> view["killed_clients"]
3
>>> reg.restore(reg.snapshot()); view["killed_clients"]
3
>>> reg.hist("async.staleness_hist")["0"] = 4
>>> reg.snapshot()["async.staleness_hist"]
{'0': 4}
"""
from __future__ import annotations

from collections.abc import MutableMapping
from typing import NamedTuple

COUNTER = "counter"
GAUGE = "gauge"
HIST = "hist"
_KINDS = (COUNTER, GAUGE, HIST)


class MetricSpec(NamedTuple):
    name: str
    kind: str
    help: str = ""


#: async dispatch-window counters (engine._run_async); the legacy
#: ``History.async_stats`` keys are these names minus the "async." prefix.
ASYNC_SCHEMA = (
    MetricSpec("async.dispatches", COUNTER, "cohorts dispatched"),
    MetricSpec("async.folds", COUNTER, "in-flight results folded"),
    MetricSpec("async.max_in_flight", GAUGE, "peak dispatch-window depth"),
    MetricSpec("async.lease_expiries", COUNTER, "cohort leases expired"),
    MetricSpec("async.requeues", COUNTER, "expired cohorts re-dispatched"),
    MetricSpec("async.staleness_hist", HIST, "folds by staleness s"),
)

#: per-round series counters (engine._emit_round)
ROUND_SCHEMA = (
    MetricSpec("rounds.completed", COUNTER, "rounds folded into history"),
    MetricSpec("rounds.evals", COUNTER, "rounds with a measured accuracy"),
    MetricSpec("rounds.quarantined", COUNTER, "client updates quarantined"),
    MetricSpec("rounds.migrations", COUNTER, "cohort group-membership flips"),
    MetricSpec("rounds.cold_started", COUNTER, "eq.-9 newcomers cold-started"),
    MetricSpec("rounds.checkpoints", COUNTER, "checkpoints written"),
    MetricSpec("rounds.shift_checks", COUNTER,
               "clients probed by the shift detector"),
    MetricSpec("rounds.empty_folds", COUNTER,
               "rounds whose cohort was entirely screened (identity fold)"),
)

#: coordinator/worker control-plane counters (launch.coordinator) —
#: declared when a Coordinator attaches, not in the default registry, so
#: single-process runs keep their exact metric set.
FLEET_SCHEMA = (
    MetricSpec("fleet.jobs", COUNTER, "jobs dispatched to workers"),
    MetricSpec("fleet.results", COUNTER, "job results folded in"),
    MetricSpec("fleet.heartbeats", COUNTER, "worker heartbeats received"),
    MetricSpec("fleet.heartbeat_misses", COUNTER,
               "heartbeat-window expiries observed while awaiting results"),
    MetricSpec("fleet.worker_deaths", COUNTER,
               "workers declared dead (missed heartbeats / closed pipe)"),
    MetricSpec("fleet.lease_expiries", COUNTER, "fleet job leases expired"),
    MetricSpec("fleet.requeues", COUNTER, "expired jobs re-dispatched"),
    MetricSpec("fleet.joins", COUNTER,
               "workers adopted mid-run (elastic joins + resurrections)"),
    MetricSpec("fleet.leaves", COUNTER, "workers departed gracefully"),
    MetricSpec("fleet.stale_results", COUNTER,
               "results for superseded job ids ignored"),
    MetricSpec("fleet.msgs_dropped", COUNTER, "chaos: messages dropped"),
    MetricSpec("fleet.msgs_duplicated", COUNTER,
               "chaos: messages delivered twice"),
    MetricSpec("fleet.msgs_reordered", COUNTER,
               "chaos: messages held past a later one"),
    MetricSpec("fleet.workers", GAUGE, "live workers"),
)


def _zero(kind):
    return {} if kind == HIST else 0


class MetricsRegistry:
    """Declared metrics + current values; thread-safe enough for the
    runtime's single-writer-per-metric counters (dict ops are atomic
    under the GIL; no read-modify-write races across threads exist
    because each metric has one incrementing site)."""

    def __init__(self, specs=ASYNC_SCHEMA + ROUND_SCHEMA):
        self._specs: dict[str, MetricSpec] = {}
        self._values: dict[str, object] = {}
        self.declare(specs)

    # -- schema ---------------------------------------------------------
    def declare(self, specs) -> None:
        """Idempotently declare metrics; a kind conflict is an error."""
        for spec in specs:
            spec = MetricSpec(*spec)
            if spec.kind not in _KINDS:
                raise ValueError(f"unknown metric kind {spec.kind!r}")
            old = self._specs.get(spec.name)
            if old is not None:
                if old.kind != spec.kind:
                    raise ValueError(
                        f"metric {spec.name!r} redeclared as {spec.kind}, "
                        f"was {old.kind}")
                continue
            self._specs[spec.name] = spec
            self._values[spec.name] = _zero(spec.kind)

    @property
    def schema(self) -> dict:
        """{name: MetricSpec} of everything declared."""
        return dict(self._specs)

    def names(self, prefix: str = "") -> list:
        return sorted(n for n in self._specs if n.startswith(prefix))

    def _check(self, name):
        if name not in self._specs:
            raise KeyError(f"metric {name!r} not declared")

    # -- updates --------------------------------------------------------
    def inc(self, name: str, n=1):
        self._check(name)
        if self._specs[name].kind == HIST:
            raise TypeError(f"cannot inc histogram {name!r}")
        self._values[name] += n

    def set(self, name: str, value):
        self._check(name)
        if self._specs[name].kind == HIST:
            if not isinstance(value, dict):
                raise TypeError(f"histogram {name!r} takes a dict")
            self._values[name] = dict(value)
        else:
            self._values[name] = value

    def observe(self, name: str, key, n=1):
        """Bump bucket ``key`` of histogram ``name``."""
        h = self.hist(name)
        key = str(key)
        h[key] = h.get(key, 0) + n

    def get(self, name: str):
        self._check(name)
        return self._values[name]

    def hist(self, name: str) -> dict:
        """The *live* bucket dict — callers may mutate it in place (the
        engine's staleness histogram does)."""
        self._check(name)
        if self._specs[name].kind != HIST:
            raise TypeError(f"metric {name!r} is not a histogram")
        return self._values[name]

    # -- lifecycle ------------------------------------------------------
    def reset(self, names=None):
        """Zero the given metrics (all when ``names`` is None). Histograms
        are cleared in place so live views/aliases stay attached."""
        for name in (self._specs if names is None else names):
            self._check(name)
            if self._specs[name].kind == HIST:
                self._values[name].clear()
            else:
                self._values[name] = 0

    def snapshot(self) -> dict:
        """JSON-able copy of every value (histograms copied)."""
        return {n: (dict(v) if isinstance(v, dict) else v)
                for n, v in self._values.items()}

    def restore(self, snap: dict):
        """Load a snapshot; unknown names are declared on the fly (a newer
        checkpoint read by older code keeps its counters)."""
        for name, value in (snap or {}).items():
            if name not in self._specs:
                kind = HIST if isinstance(value, dict) else COUNTER
                self.declare([MetricSpec(name, kind)])
            if self._specs[name].kind == HIST:
                live = self._values[name]
                live.clear()
                live.update(value)
            else:
                self._values[name] = value

    def view(self, mapping: dict) -> "MetricsView":
        """Dict-like alias view: {legacy_key: metric_name}."""
        return MetricsView(self, dict(mapping))


class MetricsView(MutableMapping):
    """MutableMapping over a fixed alias→metric mapping. Reads return the
    live value (histograms by reference, so in-place mutation patterns
    like ``hist[k] = hist.get(k, 0) + 1`` keep working); writes go
    through :meth:`MetricsRegistry.set`. Keys cannot be added/removed —
    the schema owns the key set."""

    def __init__(self, registry: MetricsRegistry, mapping: dict):
        self._registry = registry
        self._mapping = mapping

    def __getitem__(self, key):
        return self._registry.get(self._mapping[key])

    def __setitem__(self, key, value):
        self._registry.set(self._mapping[key], value)

    def __delitem__(self, key):
        raise TypeError("metric views have a fixed key set")

    def __iter__(self):
        return iter(self._mapping)

    def __len__(self):
        return len(self._mapping)

    def __contains__(self, key):
        return key in self._mapping

    def __repr__(self):
        return f"MetricsView({dict(self)!r})"

    def __eq__(self, other):
        if isinstance(other, (dict, MutableMapping)):
            return dict(self) == dict(other)
        return NotImplemented

    def snapshot(self) -> dict:
        """Plain-dict copy under the legacy key names."""
        return {k: (dict(v) if isinstance(v, dict) else v)
                for k, v in self.items()}

"""Unified telemetry layer (docs/observability.md).

``obs.trace``     — span tracer + Chrome-trace export + profiler hooks
``obs.metrics``   — typed counters/gauges/histograms behind one schema
``obs.telemetry`` — the per-run bundle wiring both to a telemetry dir
"""
from repro.obs.metrics import (ASYNC_SCHEMA, COUNTER, GAUGE, HIST,
                               ROUND_SCHEMA, MetricsRegistry, MetricSpec,
                               MetricsView)
from repro.obs.telemetry import (JsonlSink, Telemetry, from_config,
                                 get_default, set_default)
from repro.obs.trace import (NULL_SPAN, SPAN_KINDS, SpanRecord, Tracer,
                             chrome_trace_doc, export_chrome_trace,
                             start_profiler, stop_profiler,
                             validate_chrome_trace)

__all__ = [
    "ASYNC_SCHEMA", "COUNTER", "GAUGE", "HIST", "ROUND_SCHEMA",
    "MetricSpec", "MetricsRegistry", "MetricsView", "JsonlSink",
    "Telemetry", "from_config", "get_default", "set_default",
    "NULL_SPAN", "SPAN_KINDS", "SpanRecord", "Tracer",
    "chrome_trace_doc", "export_chrome_trace", "start_profiler",
    "stop_profiler", "validate_chrome_trace",
]

"""Low-overhead span tracer with Chrome-trace export.

Spans are context managers around the runtime's hot seams (cohort staging,
H2D, dispatch, fold, state-table write, eval, checkpoint). Design goals:

  * zero-cost when disabled — ``Tracer.span`` returns a shared no-op
    context manager singleton (``NULL_SPAN``) without allocating,
  * thread-safe — spans are opened from the main loop, the population's
    prefetch producer, and the async state-writer thread; completed
    records land in a bounded ``deque`` ring buffer,
  * monotonic clocks — ``time.perf_counter_ns`` throughout; wall time
    never enters a record, so traces are comparable across restarts.

Per-thread nesting depth is tracked with a ``threading.local`` stack so
exports can reconstruct parent/child structure (the async window nests
h2d inside stage inside the dispatch fill loop).

Export targets the Chrome trace-event JSON format (complete events,
``ph: "X"``) loadable in ``chrome://tracing`` / Perfetto, validated by
:func:`validate_chrome_trace`. When ``annotate=True`` each span also
enters a ``jax.profiler.TraceAnnotation`` so spans line up with XLA
activity inside a programmatic profiler capture
(:func:`start_profiler` / :func:`stop_profiler`).

>>> tr = Tracer(enabled=True)
>>> with tr.span("stage", t=0):
...     with tr.span("h2d"):
...         pass
>>> [ (r.kind, r.depth) for r in tr.records() ]
[('h2d', 1), ('stage', 0)]
>>> Tracer(enabled=False).span("stage") is NULL_SPAN
True
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time


class _NullSpan:
    """Shared no-op context manager: the disabled-tracer fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()

#: canonical span kinds instrumented across the runtime (docs/observability.md)
SPAN_KINDS = ("stage", "h2d", "dispatch", "fold", "state-write", "eval",
              "checkpoint", "lease", "heartbeat")


class SpanRecord:
    """One completed span: monotonic start/duration in ns + context."""
    __slots__ = ("kind", "start_ns", "dur_ns", "tid", "depth", "attrs")

    def __init__(self, kind, start_ns, dur_ns, tid, depth, attrs):
        self.kind = kind
        self.start_ns = start_ns
        self.dur_ns = dur_ns
        self.tid = tid
        self.depth = depth
        self.attrs = attrs

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"SpanRecord({self.kind!r}, dur={self.dur_ns / 1e6:.3f}ms, "
                f"depth={self.depth}, attrs={self.attrs})")


class _Span:
    __slots__ = ("_tracer", "kind", "attrs", "_start", "_annot")

    def __init__(self, tracer, kind, attrs):
        self._tracer = tracer
        self.kind = kind
        self.attrs = attrs
        self._start = 0
        self._annot = None

    def __enter__(self):
        tr = self._tracer
        stack = tr._stack()
        stack.append(self)
        if tr.annotate:
            import jax
            self._annot = jax.profiler.TraceAnnotation(self.kind)
            self._annot.__enter__()
        self._start = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        end = time.perf_counter_ns()
        tr = self._tracer
        if self._annot is not None:
            self._annot.__exit__(*exc)
        stack = tr._stack()
        # tolerate a foreign pop (mis-nesting) rather than corrupting depth
        if stack and stack[-1] is self:
            stack.pop()
        depth = len(stack)
        tr._records.append(SpanRecord(
            self.kind, self._start - tr.epoch_ns, end - self._start,
            threading.get_ident(), depth, self.attrs))
        return False


class Tracer:
    """Thread-safe span tracer over a bounded ring buffer.

    ``capacity`` bounds memory: the oldest records are dropped once the
    ring is full (``deque(maxlen=...)`` — appends are atomic under the
    GIL, so producer/writer threads need no extra lock).
    """

    def __init__(self, enabled: bool = False, capacity: int = 65536,
                 annotate: bool = False):
        self.enabled = bool(enabled)
        self.annotate = bool(annotate)
        self.capacity = int(capacity)
        self.epoch_ns = time.perf_counter_ns()
        self._records = collections.deque(maxlen=self.capacity)
        self._local = threading.local()

    # -- recording ------------------------------------------------------
    def span(self, kind: str, **attrs):
        """Open a span; returns ``NULL_SPAN`` (no allocation) when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, kind, attrs)

    def wrap(self, kind: str, fn, **attrs):
        """Wrap ``fn`` so every call runs inside a ``kind`` span.

        The enabled check happens per call, so a tracer enabled after
        executors were built still records their dispatches.
        """
        def wrapped(*args, **kwargs):
            if not self.enabled:
                return fn(*args, **kwargs)
            with _Span(self, kind, attrs):
                return fn(*args, **kwargs)
        wrapped.__wrapped__ = fn
        return wrapped

    def _stack(self):
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def open_depth(self) -> int:
        """Open (unclosed) spans on the *calling* thread — 0 when balanced."""
        return len(self._stack())

    # -- inspection -----------------------------------------------------
    def records(self):
        """Snapshot of completed spans (oldest first)."""
        return list(self._records)

    def clear(self):
        self._records.clear()
        self.epoch_ns = time.perf_counter_ns()

    def stage_totals(self) -> dict:
        """Aggregate per-kind timing: {kind: {count, total_s, max_s}}."""
        out = {}
        for r in self._records:
            agg = out.setdefault(r.kind, {"count": 0, "total_s": 0.0,
                                          "max_s": 0.0})
            s = r.dur_ns / 1e9
            agg["count"] += 1
            agg["total_s"] += s
            if s > agg["max_s"]:
                agg["max_s"] = s
        return out

    def round_totals(self) -> dict:
        """Per-round attributed time: {t: seconds} over spans with a ``t``
        attr (stage/fold/eval carry the round index)."""
        out = {}
        for r in self._records:
            t = r.attrs.get("t")
            if t is None or r.depth > 0:   # count top-level spans only
                continue
            out[int(t)] = out.get(int(t), 0.0) + r.dur_ns / 1e9
        return out

    # -- export ---------------------------------------------------------
    def chrome_events(self) -> list:
        """Records as Chrome trace-event complete events (``ph: "X"``)."""
        pid = os.getpid()
        events = []
        for r in self._records:
            ev = {"name": r.kind, "cat": "repro", "ph": "X",
                  "ts": r.start_ns / 1e3, "dur": r.dur_ns / 1e3,
                  "pid": pid, "tid": r.tid}
            if r.attrs:
                ev["args"] = {k: v for k, v in r.attrs.items()}
            events.append(ev)
        return events


def chrome_trace_doc(events: list) -> dict:
    """Wrap events in the JSON object format Perfetto expects."""
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(path: str, tracer: Tracer) -> dict:
    """Atomically write the tracer's records as a Chrome trace JSON file."""
    doc = chrome_trace_doc(tracer.chrome_events())
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return doc


def validate_chrome_trace(doc) -> list:
    """Validate a trace document against the trace-event schema subset we
    emit. Returns a list of error strings (empty = valid)."""
    errors = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["trace document must be an object with a 'traceEvents' key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                errors.append(f"event {i}: missing required key {key!r}")
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "i", "C", "M"):
            errors.append(f"event {i}: unknown phase {ph!r}")
        if ph == "X" and "dur" not in ev:
            errors.append(f"event {i}: complete event missing 'dur'")
        for key in ("ts", "dur"):
            if key in ev and (not isinstance(ev[key], (int, float))
                              or ev[key] < 0):
                errors.append(f"event {i}: {key!r} must be a number >= 0")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"event {i}: 'args' must be an object")
    return errors


# -- programmatic jax.profiler hooks ------------------------------------
_PROFILING = False


def start_profiler(log_dir: str):
    """Start a programmatic ``jax.profiler`` capture into ``log_dir``."""
    global _PROFILING
    import jax
    os.makedirs(log_dir, exist_ok=True)
    jax.profiler.start_trace(log_dir)
    _PROFILING = True


def stop_profiler():
    """Stop the capture started by :func:`start_profiler` (idempotent)."""
    global _PROFILING
    if not _PROFILING:
        return
    import jax
    jax.profiler.stop_trace()
    _PROFILING = False

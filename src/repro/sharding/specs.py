"""PartitionSpec rules: per-architecture tensor parallelism + the federated
round executor's 2-D ``(data, model)`` placement.

Two families of specs live here:

  * the per-architecture rules below (``param_specs`` / ``state_specs`` /
    ``data_specs`` / ``cache_specs``) used by the launch dry-runs, and
  * the *federated-round* specs (``cohort_pspec`` / ``group_param_pspec`` /
    ``group_param_specs`` / ``data_axis_names``) used by
    ``fed.parallel.make_sharded_executor``: the vmapped client batch shards
    its leading (client) axis over the mesh's data axes, and the m-stacked
    group parameters shard their largest divisible non-group dim over
    "model" — replicated when the model axis has size 1, so the 1-device
    and 1-D-mesh paths are special cases of the same placement.

>>> from repro.sharding.specs import cohort_pspec, group_param_pspec
>>> cohort_pspec(2, data_axes=("data",))          # (K, max_n) client batch
PartitionSpec(('data',), None)
>>> group_param_pspec((3, 16, 10), model_size=2)  # m-stacked (m, d, C) leaf
PartitionSpec(None, 'model', None)
>>> group_param_pspec((3, 16, 10), model_size=1)  # model axis 1: replicate
PartitionSpec(None, None, None)

Tensor-parallel scheme over the "model" mesh axis (size MP=16):
  embedding / lm_head        shard the (padded) vocab dim
  attention wq/wo            shard heads      (only if n_heads  % MP == 0)
  attention wk/wv            shard kv heads   (only if n_kv     % MP == 0)
  MLP w_gate/w_up/w_down     shard d_ff
  MoE expert stacks          shard the EXPERT axis (expert parallelism)
  MLA w_uq/w_uk/w_uv/wo      shard heads;  w_dq shards q_rank
  Mamba2 wz/wx/out_proj      shard d_inner;  B/C/dt stay replicated
  xLSTM                      replicated on "model" (4 heads < MP) — these
                             models are small; ZeRO handles their memory
  1-D params (norms, biases) replicated

Batch/data tensors shard over ("pod","data") when the batch dim divides the
axis product, else they are replicated (long_500k has B=1).

``zero=True`` additionally shards optimizer moments (and optionally params,
fsdp=True) over "data" along the largest already-unsharded dim that divides
— ZeRO-1/3 style memory scaling. This is a §Perf lever, off by default.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.zoo import ArchConfig

MP_AXIS = "model"


def _path_names(path):
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def _rule(names: list[str], shape: tuple, cfg: ArchConfig, mp: int,
          moe_2d: bool = False) -> P:
    """PartitionSpec for one parameter leaf (without the stacked-layer dim —
    the caller prepends None for leaves living under 'blocks')."""
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    nd = len(shape)
    rep = P(*([None] * nd))
    if nd <= 1:
        return rep

    heads_ok = cfg.n_heads % mp == 0
    kv_ok = cfg.n_kv_heads % mp == 0
    ff = cfg.moe_d_ff if (cfg.family == "moe" and parent != "shared") else cfg.d_ff
    ff_ok = ff % mp == 0 and ff > 0
    vocab_ok = cfg.padded_vocab % mp == 0
    di_ok = (cfg.ssm_expand * cfg.d_model) % mp == 0

    if name == "embed":
        return P(MP_AXIS, None) if vocab_ok else rep
    if name == "lm_head":
        return P(None, MP_AXIS) if vocab_ok else rep
    if name in ("frontend_proj",):
        return rep
    if parent == "projector":
        return rep

    if parent == "attn" or parent == "shared_attn":
        if name in ("wq",):
            return P(None, MP_AXIS) if heads_ok else rep
        if name in ("wk", "wv"):
            return P(None, MP_AXIS) if kv_ok else rep
        if name == "wo":
            return P(MP_AXIS, None) if heads_ok else rep
        # MLA projections
        if name == "w_dq":
            return P(None, MP_AXIS) if cfg.q_rank % mp == 0 else rep
        if name == "w_uq":
            return (P(MP_AXIS, None) if cfg.q_rank % mp == 0
                    else (P(None, MP_AXIS) if heads_ok else rep))
        if name in ("w_uk", "w_uv"):
            return P(None, MP_AXIS) if heads_ok else rep
        if name == "w_dkv":
            return rep
    if parent == "mlp" or parent == "shared":
        if name in ("w_gate", "w_up"):
            return P(None, MP_AXIS) if ff_ok else rep
        if name == "w_down":
            return P(MP_AXIS, None) if ff_ok else rep
    if parent == "moe":
        if name == "router":
            return rep
        if name in ("w_gate", "w_up", "w_down") and nd == 3:
            if moe_2d and cfg.n_experts % (mp * mp) == 0:
                # 2-D expert parallelism: experts over BOTH axes -> weights
                # never gathered; tokens move via all-to-all (§Perf)
                return P(("data", MP_AXIS), None, None)
            return (P(MP_AXIS, None, None) if cfg.n_experts % mp == 0 else rep)
    if parent == "mixer":
        if name in ("wz", "wx"):
            return P(None, MP_AXIS) if di_ok else rep
        if name == "out_proj":
            return P(MP_AXIS, None) if di_ok else rep
        if name == "conv_x":
            return P(None, MP_AXIS) if di_ok else rep
        return rep
    # xLSTM / leftovers: replicate
    return rep


def param_specs(params, cfg: ArchConfig, mp: int = 16,
                fsdp_axis: Optional[str] = None, moe_2d: bool = False):
    """Pytree of PartitionSpec matching ``params``.

    fsdp_axis: if set (e.g. "data"), additionally shard each leaf's largest
    not-yet-sharded divisible dim over that axis (ZeRO-3 / FSDP).
    moe_2d: shard MoE expert stacks over BOTH mesh axes (expert parallelism
    across the full chip count — weights stay put, tokens all-to-all).
    """
    def spec_for(path, leaf):
        names = _path_names(path)
        stacked = ("blocks" in names) or (names and names[0] == "blocks_list")
        shape = leaf.shape[1:] if stacked and leaf.ndim >= 1 else leaf.shape
        base = _rule(names, shape, cfg, mp, moe_2d=moe_2d)
        parts = ([None] + list(base)) if stacked else list(base)
        if fsdp_axis is not None and leaf.ndim >= 2:
            parts = _add_fsdp(parts, leaf.shape, fsdp_axis)
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def _add_fsdp(parts, shape, axis, axis_size: int = 16):
    """Shard the largest unsharded, divisible dim over ``axis``."""
    used = set()
    for p in parts:
        for a in (p if isinstance(p, tuple) else (p,)):
            used.add(a)
    if axis in used:
        return parts          # axis already consumed by this leaf's spec
    best, best_dim = -1, -1
    for i, (p, d) in enumerate(zip(parts, shape)):
        if p is None and d % axis_size == 0 and d > best:
            best, best_dim = d, i
    if best_dim >= 0:
        parts = list(parts)
        parts[best_dim] = axis
    return parts


def state_specs(state_template, cfg: ArchConfig, mp: int = 16,
                zero: bool = False, fsdp: bool = False, moe_2d: bool = False):
    """Specs for the full train state {params, mu, nu, step}."""
    p_specs = param_specs(state_template["params"], cfg, mp,
                          fsdp_axis="data" if fsdp else None, moe_2d=moe_2d)
    m_specs = param_specs(state_template["mu"], cfg, mp,
                          fsdp_axis="data" if (zero or fsdp) else None,
                          moe_2d=moe_2d)
    return {"params": p_specs, "mu": m_specs, "nu": m_specs, "step": P()}


# ---------------------------------------------------------------------------
# Federated round executor (fed.parallel) — 2-D (data, model) placement
# ---------------------------------------------------------------------------

def data_axis_names(mesh) -> tuple:
    """The mesh axes the client (cohort) axis shards over: the data-ish
    axes ("pod", "data") when present, every axis of a mesh that has
    neither (the legacy 1-D case)."""
    named = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return named or tuple(mesh.axis_names)


def cohort_pspec(ndim: int, data_axes=("data",)) -> P:
    """Spec for one K-leading cohort leaf (X/Y/n/keys/assignment state):
    client axis sharded over the data axes, everything else replicated."""
    return P(tuple(data_axes), *([None] * (ndim - 1)))


def block_staged_pspec(ndim: int, data_axes=("data",)) -> P:
    """Spec for one staged round-block leaf (cohort ids / solver keys /
    alive mask of shape ``(B, K, ...)``): the scan (round) axis stays
    replicated — every device steps through all B rounds — and the client
    axis (axis 1) shards over the data axes, i.e. ``cohort_pspec`` shifted
    one axis right.

    >>> from repro.sharding.specs import block_staged_pspec
    >>> block_staged_pspec(2, data_axes=("data",))   # (B, K) cohort ids
    PartitionSpec(None, ('data',))
    """
    return P(None, tuple(data_axes), *([None] * (ndim - 2)))


def group_param_pspec(shape: tuple, model_size: int,
                      model_axis: str = MP_AXIS) -> P:
    """Spec for one m-stacked group-parameter leaf.

    The leading (group) axis stays replicated — every device owns all m
    group models, exactly like the 1-D path — and the *largest* trailing
    dim divisible by ``model_size`` shards over "model" (the local solver's
    parameter axis). No divisible dim, or ``model_size == 1``, degrades to
    full replication: the 1-device and 1-D-mesh placements are the
    ``model_size == 1`` special case.
    """
    nd = len(shape)
    parts = [None] * nd
    if model_size > 1 and nd >= 2:
        best, best_dim = -1, -1
        for i in range(1, nd):
            if shape[i] % model_size == 0 and shape[i] > best:
                best, best_dim = shape[i], i
        if best_dim >= 0:
            parts[best_dim] = model_axis
    return P(*parts)


def group_param_specs(group_params, mesh) -> object:
    """Pytree of ``group_param_pspec`` for an m-stacked parameter pytree
    under ``mesh`` (model-axis size read off the mesh; 1 when absent)."""
    model_size = dict(mesh.shape).get(MP_AXIS, 1)
    return jax.tree_util.tree_map(
        lambda l: group_param_pspec(tuple(l.shape), model_size), group_params)


# ---------------------------------------------------------------------------
# Data tensors
# ---------------------------------------------------------------------------

def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_specs(batch_tree, mesh, include_model: bool = False):
    """Shard the leading batch dim over ("pod","data") when divisible.

    include_model (§Perf): for architectures with NO tensor-parallel
    parameters (e.g. xLSTM: 4 heads < 16-way model axis, everything
    replicated) the "model" axis is idle — shard the batch over it too,
    dividing activation memory by the model-axis size for free.
    """
    axes = batch_axes(mesh)
    if include_model:
        axes = axes + (MP_AXIS,)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % total == 0 and leaf.shape[0] > 0:
            return P(axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map(spec, batch_tree)


def cache_specs(cache_tree, cfg: ArchConfig, mesh, mp: int = 16,
                seq_shard: bool = False):
    """Decode-cache sharding: batch dim over data axes; head/expert-ish dims
    over "model" where divisible. Cache layouts (leading L = stacked layers):
      attn k/v   (L, B, S, KV, hd)
      mla        c_kv (L, B, S, r) / k_pe (L, B, S, rope)
      mamba      conv_* (L, B, W-1, C) / ssm (L, B, H, P, N)
      xlstm      per-layer lists of small states

    seq_shard (§Perf optimization): when the kv-head dim does NOT divide the
    model axis (kv < 16), shard the cache's SEQUENCE dim over "model"
    instead of replicating. Attention over a seq-sharded cache only needs
    softmax-stat all-reduces (bytes ~ B·H), eliminating the full-cache
    all-gather XLA otherwise inserts to re-lay-out the loop-carried cache.
    """
    axes = batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def spec_for(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim
        parts = [None] * nd
        stacked = nd >= 1 and any(n in ("k", "v", "c_kv", "k_pe", "conv_x",
                                        "conv_B", "conv_C", "ssm")
                                  for n in names)
        # batch dim position: 1 for stacked layer caches, 0 for xlstm lists
        bdim = 1 if (stacked and names[0] != "xlstm") else 0
        if nd > bdim and leaf.shape[bdim] % total == 0:
            parts[bdim] = axes
        # model-axis dims
        last = names[-1]
        if last in ("k", "v") and nd == 5:
            if cfg.n_kv_heads % mp == 0:
                parts[3] = MP_AXIS
            elif seq_shard and leaf.shape[2] % mp == 0:
                parts[2] = MP_AXIS
        if last == "c_kv" and nd == 4:
            if seq_shard and leaf.shape[2] % mp == 0:
                parts[2] = MP_AXIS           # MLA latent: seq dim
            elif cfg.kv_rank % mp == 0:
                parts[3] = MP_AXIS
        if last == "k_pe" and nd == 4 and seq_shard and leaf.shape[2] % mp == 0:
            parts[2] = MP_AXIS
        if last == "ssm" and nd == 5:
            H = leaf.shape[2]
            if H % mp == 0:
                parts[2] = MP_AXIS
        if last in ("conv_x",) and nd == 4 and leaf.shape[3] % mp == 0:
            parts[3] = MP_AXIS
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)

"""Synthetic federated dataset generators (offline stand-ins, see DESIGN.md).

  mnist_like    10-class class-conditional clusters in R^784, label-skew
                partition with #classes/client knob (paper §3.1 / Table 1).
  femnist_like  62 classes, 200 writer-clients; each writer applies a private
                affine style transform — natural feature-shift non-IID.
  synthetic     Shamir et al. Synthetic(alpha, beta) — exactly the paper's
                generator (60-dim, 10 classes, d_w = 610 with MCLR).
  sent140_like  binary sentiment over token sequences; each client (account)
                has a private topic mixture; positive/negative lexicons.

Virtual (lazy) populations for the streamed engine — construction at
N ≥ 10⁵ costs only the (N,) size vectors; client i's data is generated on
first touch from a per-client ``SeedSequence`` and never materialized as a
full (N, max_n, ...) stack:

  virtual_synthetic   Synthetic(alpha, beta) behind a ``VirtualClientStore``
  virtual_mnist_like  label-skew class-cluster clients, same store API
"""
from __future__ import annotations

import numpy as np

from repro.data.federated import (FederatedData, label_skew_partition,
                                  pack_clients, power_law_sizes)


def _class_prototypes(rng, n_classes: int, dim: int, sep: float = 2.2):
    protos = rng.normal(0, 1, (n_classes, dim)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    return protos * sep


def mnist_like(seed: int = 0, n_clients: int = 1000,
               classes_per_client: int = 2, total_train: int = 69035,
               dim: int = 784, n_classes: int = 10) -> FederatedData:
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng, n_classes, dim)
    n_total = int(total_train * 1.4)
    Y = rng.integers(0, n_classes, n_total)
    X = (protos[Y] + rng.normal(0, 1.0, (n_total, dim))).astype(np.float32)
    clients = label_skew_partition(rng, X, Y, n_clients, classes_per_client,
                                   n_classes, total_train)
    return pack_clients(f"mnist_like_c{classes_per_client}", clients,
                        n_classes, {"classes_per_client": classes_per_client})


def femnist_like(seed: int = 0, n_clients: int = 200,
                 total_train: int = 18345, dim: int = 784,
                 n_classes: int = 62, n_styles: int = 5) -> FederatedData:
    """Writer-level non-IID: clients belong to latent style groups; each
    style applies a shared rotation+shift to the class prototypes, and each
    writer adds a small private perturbation.  The latent styles give CFL
    something real to discover — mirroring FEMNIST's writer clusters."""
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng, n_classes, dim)
    # style transforms: random orthogonal-ish mixing + bias
    styles = []
    for s in range(n_styles):
        M = np.eye(dim, dtype=np.float32) + 0.35 * rng.normal(
            0, 1 / np.sqrt(dim), (dim, dim)).astype(np.float32)
        b = rng.normal(0, 0.9, dim).astype(np.float32)
        styles.append((M, b))
    sizes = power_law_sizes(rng, n_clients, total_train, min_size=30,
                            max_size=400)
    style_of = rng.integers(0, n_styles, n_clients)
    clients = []
    for i in range(n_clients):
        M, b = styles[style_of[i]]
        n_i = sizes[i]
        # each writer covers a subset of classes (handwriting habit)
        cls = rng.choice(n_classes, rng.integers(8, 20), replace=False)
        y = rng.choice(cls, n_i)
        x = protos[y] + rng.normal(0, 0.9, (n_i, dim)).astype(np.float32)
        x = x @ M.T + b + rng.normal(0, 0.1, (n_i, dim)).astype(np.float32)
        n_te = max(1, n_i // 5)
        clients.append({"x": x[n_te:].astype(np.float32), "y": y[n_te:],
                        "x_test": x[:n_te].astype(np.float32), "y_test": y[:n_te]})
    return pack_clients("femnist_like", clients, n_classes,
                        {"style_of": style_of})


def synthetic(alpha: float = 1.0, beta: float = 1.0, seed: int = 0,
              n_clients: int = 100, dim: int = 60,
              n_classes: int = 10) -> FederatedData:
    """Shamir/FedProx Synthetic(alpha, beta) — the paper's exact generator."""
    rng = np.random.default_rng(seed)
    sizes = power_law_sizes(rng, n_clients, 75349, min_size=20, max_size=1200)
    diag = np.array([(j + 1) ** -1.2 for j in range(dim)], np.float32)
    clients = []
    for i in range(n_clients):
        u = rng.normal(0, alpha)
        Bv = rng.normal(0, beta)
        v = rng.normal(Bv, 1, dim)
        W = rng.normal(u, 1, (dim, n_classes)).astype(np.float32)
        b = rng.normal(u, 1, n_classes).astype(np.float32)
        n_i = sizes[i]
        x = rng.normal(v, np.sqrt(diag), (n_i, dim)).astype(np.float32)
        logits = x @ W + b
        y = np.argmax(logits, 1).astype(np.int32)
        n_te = max(1, n_i // 5)
        clients.append({"x": x[n_te:], "y": y[n_te:],
                        "x_test": x[:n_te], "y_test": y[:n_te]})
    return pack_clients(f"synthetic_{alpha}_{beta}", clients, n_classes, {})


def _virtual_sizes(seed: int, n_clients: int, mean_size: int,
                   min_size: int, max_size: int):
    """(n_train, n_test) per-client size vectors, power-law distributed —
    the only O(N) arrays a virtual population materializes up front."""
    rng = np.random.default_rng(seed)
    total = power_law_sizes(rng, n_clients, mean_size * n_clients,
                            min_size=min_size, max_size=max_size)
    n_test = np.maximum(1, total // 5).astype(np.int32)
    n_train = (total - n_test).astype(np.int32)
    return n_train, n_test


def virtual_synthetic(alpha: float = 1.0, beta: float = 1.0, seed: int = 0,
                      n_clients: int = 100_000, dim: int = 60,
                      n_classes: int = 10, mean_size: int = 40,
                      min_size: int = 10, max_size: int = 120,
                      memmap_dir: str | None = None, **store_kw):
    """Shamir Synthetic(alpha, beta) as a lazy ``VirtualClientStore``.

    Statistically the same population as ``synthetic`` but with per-client
    seeding (``SeedSequence([seed, i])``), so client i's shard is a pure
    function of i — generated on first touch, optionally persisted to
    memory-mapped shard files, never stacked host- or device-side."""
    from repro.fed.store import VirtualClientStore
    n_train, n_test = _virtual_sizes(seed, n_clients, mean_size,
                                     min_size, max_size)
    diag = np.array([(j + 1) ** -1.2 for j in range(dim)], np.float32)

    def client_fn(i: int):
        rng = np.random.default_rng([seed, 7919, i])
        u = rng.normal(0, alpha)
        Bv = rng.normal(0, beta)
        v = rng.normal(Bv, 1, dim)
        W = rng.normal(u, 1, (dim, n_classes)).astype(np.float32)
        b = rng.normal(u, 1, n_classes).astype(np.float32)
        tot = int(n_train[i]) + int(n_test[i])
        x = rng.normal(v, np.sqrt(diag), (tot, dim)).astype(np.float32)
        y = np.argmax(x @ W + b, 1).astype(np.int32)
        n_te = int(n_test[i])
        return {"x": x[n_te:], "y": y[n_te:],
                "x_test": x[:n_te], "y_test": y[:n_te]}

    return VirtualClientStore(
        f"virtual_synthetic_{alpha}_{beta}_N{n_clients}", n_clients,
        client_fn, max_train=int(n_train.max()), max_test=int(n_test.max()),
        feat=(dim,), n_classes=n_classes, n_train=n_train, n_test=n_test,
        memmap_dir=memmap_dir, **store_kw)


def virtual_mnist_like(seed: int = 0, n_clients: int = 100_000,
                       classes_per_client: int = 2, dim: int = 64,
                       n_classes: int = 10, mean_size: int = 40,
                       min_size: int = 10, max_size: int = 120,
                       memmap_dir: str | None = None, **store_kw):
    """Label-skew class-cluster population as a lazy ``VirtualClientStore``
    (the ``mnist_like`` structure without the global sampling pool, so each
    client is independently generable)."""
    from repro.fed.store import VirtualClientStore
    n_train, n_test = _virtual_sizes(seed, n_clients, mean_size,
                                     min_size, max_size)
    protos = _class_prototypes(np.random.default_rng(seed), n_classes, dim)

    def client_fn(i: int):
        rng = np.random.default_rng([seed, 104729, i])
        cls = rng.choice(n_classes, classes_per_client, replace=False)
        tot = int(n_train[i]) + int(n_test[i])
        y = rng.choice(cls, tot).astype(np.int32)
        x = (protos[y] + rng.normal(0, 1.0, (tot, dim))).astype(np.float32)
        n_te = int(n_test[i])
        return {"x": x[n_te:], "y": y[n_te:],
                "x_test": x[:n_te], "y_test": y[:n_te]}

    return VirtualClientStore(
        f"virtual_mnist_c{classes_per_client}_N{n_clients}", n_clients,
        client_fn, max_train=int(n_train.max()), max_test=int(n_test.max()),
        feat=(dim,), n_classes=n_classes, n_train=n_train, n_test=n_test,
        memmap_dir=memmap_dir, **store_kw)


def sent140_like(seed: int = 0, n_clients: int = 772, vocab: int = 1000,
                 seq_len: int = 25, total_train: int = 40783) -> FederatedData:
    """Binary sentiment over token sequences.  Each account mixes a private
    topic distribution with shared positive/negative lexicons, so accounts
    are statistically heterogeneous in both vocabulary and label balance."""
    rng = np.random.default_rng(seed)
    n_topics = 8
    pos_lex = rng.choice(vocab, 60, replace=False)
    neg_lex = np.array([t for t in rng.choice(vocab, 120, replace=False)
                        if t not in set(pos_lex)][:60])
    topic_words = [rng.choice(vocab, 120, replace=False) for _ in range(n_topics)]
    sizes = power_law_sizes(rng, n_clients, total_train, min_size=12,
                            max_size=200)
    clients = []
    for i in range(n_clients):
        mix = rng.dirichlet(np.ones(n_topics) * 0.4)
        pos_rate = np.clip(rng.beta(3, 3), 0.15, 0.85)
        n_i = sizes[i]
        y = (rng.random(n_i) < pos_rate).astype(np.int32)
        x = np.zeros((n_i, seq_len), np.int32)
        for s in range(n_i):
            topic = rng.choice(n_topics, p=mix)
            base = rng.choice(topic_words[topic], seq_len)
            lex = pos_lex if y[s] == 1 else neg_lex
            n_sent = rng.integers(3, 8)
            pos = rng.choice(seq_len, n_sent, replace=False)
            base[pos] = rng.choice(lex, n_sent)
            x[s] = base
        n_te = max(1, n_i // 5)
        clients.append({"x": x[n_te:].astype(np.float32), "y": y[n_te:],
                        "x_test": x[:n_te].astype(np.float32), "y_test": y[:n_te]})
    return pack_clients("sent140_like", clients, 2, {"seq_len": seq_len})

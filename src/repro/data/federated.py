"""Federated dataset container + non-IID partitioners.

Offline-reproduction note (repro band = data gate): MNIST/FEMNIST/Sent140
downloads are unavailable in this environment, so the generators in
``repro.data.generators`` synthesize datasets with the *same statistical
structure* the paper manipulates: class-conditional clusters, label-skew
(#classes/client), power-law client sizes, writer/account-level feature
shift. The Shamir Synthetic(α,β) set is exactly the paper's formula.

All clients are padded to ``max_samples`` so a single jitted/vmapped local
solver serves every client (the TPU client-parallel engine relies on this).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class FederatedData:
    """Stacked, padded per-client data.

    x_train: (N, max_n, ...) float   y_train: (N, max_n) int
    n_train: (N,) valid counts       (same trio for test)
    """
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    n_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    n_test: np.ndarray
    n_classes: int
    meta: dict = field(default_factory=dict)

    @property
    def n_clients(self) -> int:
        return self.x_train.shape[0]

    def client(self, i: int):
        return {
            "x": self.x_train[i, : self.n_train[i]],
            "y": self.y_train[i, : self.n_train[i]],
            "x_test": self.x_test[i, : self.n_test[i]],
            "y_test": self.y_test[i, : self.n_test[i]],
        }

    def store(self):
        """This population behind the host-resident ``ClientStore`` API —
        the streamed trainers' small-N backing (``fed.store``)."""
        from repro.fed.store import ArrayClientStore
        return ArrayClientStore(self)


def power_law_sizes(rng: np.random.Generator, n_clients: int, total: int,
                    alpha: float = 1.5, min_size: int = 10,
                    max_size: int = 512) -> np.ndarray:
    """Client training-set sizes following a (truncated) power law, as in the
    paper's MNIST setup ("the training set size follows a power law")."""
    raw = rng.pareto(alpha, n_clients) + 1.0
    sizes = raw / raw.sum() * total
    return np.clip(sizes.astype(int), min_size, max_size)


def pack_clients(name: str, clients: list, n_classes: int,
                 meta: dict | None = None) -> FederatedData:
    """clients: list of dicts with x/y/x_test/y_test -> padded FederatedData."""
    N = len(clients)
    max_tr = max(len(c["y"]) for c in clients)
    max_te = max(max(len(c["y_test"]) for c in clients), 1)
    feat = clients[0]["x"].shape[1:]
    xt = np.zeros((N, max_tr) + feat, np.float32)
    yt = np.zeros((N, max_tr), np.int32)
    nt = np.zeros((N,), np.int32)
    xe = np.zeros((N, max_te) + feat, np.float32)
    ye = np.zeros((N, max_te), np.int32)
    ne = np.zeros((N,), np.int32)
    for i, c in enumerate(clients):
        n, m = len(c["y"]), len(c["y_test"])
        xt[i, :n], yt[i, :n], nt[i] = c["x"], c["y"], n
        if m:
            xe[i, :m], ye[i, :m], ne[i] = c["x_test"], c["y_test"], m
    return FederatedData(name, xt, yt, nt, xe, ye, ne, n_classes, meta or {})


def label_skew_partition(rng: np.random.Generator, X: np.ndarray,
                         Y: np.ndarray, n_clients: int,
                         classes_per_client: int, n_classes: int,
                         total_train: int, test_frac: float = 0.2):
    """Assign each client ``classes_per_client`` classes and sub-sample its
    data from those classes only (the paper's non-IID MNIST construction)."""
    sizes = power_law_sizes(rng, n_clients, total_train)
    by_class = {c: list(np.where(Y == c)[0]) for c in range(n_classes)}
    for c in by_class:
        rng.shuffle(by_class[c])
    cursors = {c: 0 for c in range(n_classes)}
    clients = []
    for i in range(n_clients):
        cls = rng.choice(n_classes, classes_per_client, replace=False)
        n_i = sizes[i]
        idx = []
        for j, c in enumerate(cls):
            want = n_i // classes_per_client + (1 if j < n_i % classes_per_client else 0)
            pool = by_class[c]
            take = []
            while len(take) < want:
                if cursors[c] >= len(pool):       # recycle (sampling w/o
                    cursors[c] = 0                 # replacement until exhausted)
                    rng.shuffle(pool)
                take.append(pool[cursors[c]])
                cursors[c] += 1
            idx.extend(take)
        idx = np.array(idx)
        rng.shuffle(idx)
        n_te = max(1, int(len(idx) * test_frac))
        clients.append({
            "x": X[idx[n_te:]], "y": Y[idx[n_te:]],
            "x_test": X[idx[:n_te]], "y_test": Y[idx[:n_te]],
        })
    return clients

"""Mamba2 (SSD — state-space duality) block, TPU-adapted.

Training/prefill uses the *chunked* SSD algorithm (Dao & Gu 2024, listing 1):
intra-chunk quadratic term (MXU-friendly batched matmuls) + an inter-chunk
state recurrence over only seq_len/chunk steps. This is the TPU-native
adaptation of the CUDA selective-scan: instead of a warp-level scan we block
the sequence so >95% of FLOPs are dense matmuls, and the sequential part
carries only the (B, H, P, N) boundary states.

Sharding note: the input projection is stored as SEPARATE kernels per
segment (z / x / B / C / dt) rather than one fused matmul, so the d_inner
segments can be cleanly tensor-parallel over the mesh "model" axis while the
small B/C/dt segments stay replicated (see sharding/specs.py).

Decode is the O(1) recurrent update on the carried state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.modules import dense_init, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_mamba2(key, d_model: int, *, d_state: int = 64, expand: int = 2,
                head_dim: int = 64, conv_width: int = 4, n_groups: int = 1,
                dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    gn = n_groups * d_state
    ks = jax.random.split(key, 8)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[6], (n_heads,))
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    cw = lambda k, ch: (jax.random.normal(k, (conv_width, ch))
                        * (1.0 / conv_width ** 0.5)).astype(dtype)
    return {
        "wz": dense_init(ks[0], d_model, d_inner, dtype),
        "wx": dense_init(ks[1], d_model, d_inner, dtype),
        "wB": dense_init(ks[2], d_model, gn, dtype),
        "wC": dense_init(ks[3], d_model, gn, dtype),
        "wdt": dense_init(ks[4], d_model, n_heads, dtype),
        "conv_x": cw(ks[5], d_inner),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_B": cw(jax.random.fold_in(ks[5], 1), gn),
        "conv_B_b": jnp.zeros((gn,), dtype),
        "conv_C": cw(jax.random.fold_in(ks[5], 2), gn),
        "conv_C_b": jnp.zeros((gn,), dtype),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), dtype)},
        "out_proj": dense_init(ks[7], d_inner, d_model, dtype),
    }


def mamba2_dims(d_model: int, d_state: int, expand: int, head_dim: int,
                n_groups: int = 1):
    d_inner = expand * d_model
    return dict(d_inner=d_inner, n_heads=d_inner // head_dim,
                head_dim=head_dim, d_state=d_state, n_groups=n_groups)


# ---------------------------------------------------------------------------
# Chunked SSD
# ---------------------------------------------------------------------------

def _segsum(x):
    """x: (..., T) -> (..., T, T) lower-triangular segment sums (else -inf)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, NEG_INF)


def ssd_chunked(X, dtA, B, C, chunk: int, init_state=None):
    """SSD over the full sequence.

    X   (b, l, h, p)   dt-scaled inputs
    dtA (b, l, h)      log decay per step (dt * A, A < 0)
    B,C (b, l, h, n)   input/output projections (already head-expanded)
    Returns (Y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = X.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    c = l // chunk
    Xc = X.reshape(b, c, chunk, h, p)
    Bc = B.reshape(b, c, chunk, h, n)
    Cc = C.reshape(b, c, chunk, h, n)
    A = dtA.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)       # (b,h,c,Q)
    A_cs = jnp.cumsum(A, axis=-1)                               # (b,h,c,Q)

    # 1) intra-chunk (diagonal blocks): quadratic attention-like term
    L = jnp.exp(_segsum(A))                                     # (b,h,c,Q,Q)
    Y_diag = jnp.einsum("bcqhn,bckhn,bhcqk,bckhp->bcqhp", Cc, Bc, L, Xc)

    # 2) chunk-end states
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)               # (b,h,c,Q)
    states = jnp.einsum("bckhn,bhck,bckhp->bchpn", Bc, decay_states, Xc)

    # 3) inter-chunk recurrence (the only sequential part: c steps)
    chunk_decay = jnp.exp(A_cs[..., -1])                        # (b,h,c)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    states = states.astype(jnp.float32)

    def step(carry, inp):
        dec, s = inp                                            # (b,h), (b,h,p,n)
        new = dec[..., None, None] * carry + s
        return new, carry                                       # emit state *entering* chunk

    final, prev_states = jax.lax.scan(
        step, init_state,
        (chunk_decay.transpose(2, 0, 1), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (b,c,h,p,n)

    # 4) state -> output within each chunk
    state_decay_out = jnp.exp(A_cs)                             # (b,h,c,Q)
    Y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Cc, prev_states, state_decay_out)

    return (Y_diag + Y_off).reshape(b, l, h, p), final


# ---------------------------------------------------------------------------
# Full block forward (training / prefill)
# ---------------------------------------------------------------------------

def _causal_conv(x, w, b):
    """Depthwise causal conv. x:(B,S,C), w:(W,C)."""
    W = w.shape[0]
    pads = [jnp.pad(x, ((0, 0), (W - 1 - i, i), (0, 0)))[:, : x.shape[1]]
            for i in range(W)]
    # pads[i][t] = x[t - (W-1-i)]
    out = sum(p * w[i][None, None, :] for i, p in enumerate(pads))
    return out + b[None, None, :]


def mamba2_fwd(params, x, *, d_state: int, expand: int, head_dim: int,
               chunk: int = 128, n_groups: int = 1):
    B_, S, D = x.shape
    dims = mamba2_dims(D, d_state, expand, head_dim, n_groups)
    di, H, P, N = dims["d_inner"], dims["n_heads"], head_dim, d_state

    dt_ = x.dtype
    z = x @ params["wz"].astype(dt_)
    xs = jax.nn.silu(_causal_conv(x @ params["wx"].astype(dt_),
                                  params["conv_x"].astype(dt_),
                                  params["conv_x_b"].astype(dt_)))
    Bm = jax.nn.silu(_causal_conv(x @ params["wB"].astype(dt_),
                                  params["conv_B"].astype(dt_),
                                  params["conv_B_b"].astype(dt_)))
    Cm = jax.nn.silu(_causal_conv(x @ params["wC"].astype(dt_),
                                  params["conv_C"].astype(dt_),
                                  params["conv_C_b"].astype(dt_)))
    dt_raw = x @ params["wdt"].astype(dt_)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])     # (B,S,H)
    A = -jnp.exp(params["A_log"])                                # (H,)
    dtA = dt * A[None, None, :]                                  # log decay

    X = xs.reshape(B_, S, H, P) * dt[..., None].astype(dt_)
    rep = H // n_groups
    Bh = jnp.repeat(Bm.reshape(B_, S, n_groups, N), rep, axis=2)
    Ch = jnp.repeat(Cm.reshape(B_, S, n_groups, N), rep, axis=2)

    Y, _ = ssd_chunked(X, dtA, Bh.astype(dt_), Ch.astype(dt_), chunk)
    Y = Y.astype(dt_) + params["D"].astype(dt_)[None, None, :, None] * xs.reshape(B_, S, H, P)
    y = Y.reshape(B_, S, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"].astype(dt_)


# ---------------------------------------------------------------------------
# Decode (single token, O(1) state)
# ---------------------------------------------------------------------------

def init_mamba2_cache(batch: int, d_model: int, *, d_state: int, expand: int,
                      head_dim: int, conv_width: int = 4, n_groups: int = 1,
                      dtype=jnp.float32):
    dims = mamba2_dims(d_model, d_state, expand, head_dim, n_groups)
    gn = n_groups * d_state
    return {
        "conv_x": jnp.zeros((batch, conv_width - 1, dims["d_inner"]), dtype),
        "conv_B": jnp.zeros((batch, conv_width - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, conv_width - 1, gn), dtype),
        "ssm": jnp.zeros((batch, dims["n_heads"], head_dim, d_state), dtype),
    }


def _conv_step(state, new, w, b):
    """state: (B, W-1, C); new: (B, C) -> (out (B, C), new state)."""
    window = jnp.concatenate([state, new[:, None, :]], axis=1)
    out = jnp.einsum("bwc,wc->bc", window, w) + b
    return out, window[:, 1:]


def mamba2_step(params, cache, x, *, d_state: int, expand: int,
                head_dim: int, n_groups: int = 1):
    """x: (B, 1, D) -> (y (B,1,D), new cache)."""
    B_, one, D = x.shape
    dims = mamba2_dims(D, d_state, expand, head_dim, n_groups)
    di, H, P, N = dims["d_inner"], dims["n_heads"], head_dim, d_state
    dt_ = x.dtype
    xt = x[:, 0]

    z = xt @ params["wz"].astype(dt_)
    xs_raw, cx = _conv_step(cache["conv_x"], xt @ params["wx"].astype(dt_),
                            params["conv_x"].astype(dt_),
                            params["conv_x_b"].astype(dt_))
    Bm_raw, cB = _conv_step(cache["conv_B"], xt @ params["wB"].astype(dt_),
                            params["conv_B"].astype(dt_),
                            params["conv_B_b"].astype(dt_))
    Cm_raw, cC = _conv_step(cache["conv_C"], xt @ params["wC"].astype(dt_),
                            params["conv_C"].astype(dt_),
                            params["conv_C_b"].astype(dt_))
    xs, Bm, Cm = map(jax.nn.silu, (xs_raw, Bm_raw, Cm_raw))
    dt_raw = xt @ params["wdt"].astype(dt_)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, :])
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :]).astype(dt_)                 # (B,H)

    rep = H // n_groups
    Bh = jnp.repeat(Bm.reshape(B_, n_groups, N), rep, axis=1)
    Ch = jnp.repeat(Cm.reshape(B_, n_groups, N), rep, axis=1)
    Xh = xs.reshape(B_, H, P) * dt[..., None].astype(dt_)

    new_ssm = (decay[..., None, None] * cache["ssm"]
               + jnp.einsum("bhp,bhn->bhpn", Xh, Bh))
    Yh = jnp.einsum("bhpn,bhn->bhp", new_ssm, Ch)
    Yh = Yh + params["D"].astype(dt_)[None, :, None] * xs.reshape(B_, H, P)
    y = Yh.reshape(B_, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    y = (y @ params["out_proj"].astype(dt_))[:, None, :]
    return y, {"conv_x": cx, "conv_B": cB, "conv_C": cC, "ssm": new_ssm}

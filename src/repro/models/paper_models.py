"""The paper's own experiment models: MCLR, MLP, LSTM sentiment classifier.

Table 2 of the paper:
  MNIST    MCLR (d_w=7,850)     MLP-128 (d_w=101,770)
  FEMNIST  MCLR (d_w=20,410)    MLP-512 (d_w=415,258)
  Synthetic(1,1) MCLR (d_w=610)
  Sent140  LSTM (d_w=243,861)

These run inside the federated engine (fed/), each exposing
  init(key) -> params
  apply(params, x) -> logits
  loss(params, batch) -> scalar
  accuracy(params, batch) -> scalar
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelSpec:
    name: str
    init: Callable
    apply: Callable

    def loss(self, params, batch):
        logits = self.apply(params, batch["x"])
        labels = batch["y"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))

    def accuracy(self, params, batch):
        logits = self.apply(params, batch["x"])
        return jnp.mean(jnp.argmax(logits, -1) == batch["y"])

    def correct_count(self, params, batch):
        logits = self.apply(params, batch["x"])
        return jnp.sum(jnp.argmax(logits, -1) == batch["y"])


# ---------------------------------------------------------------------------

def mclr(in_dim: int, n_classes: int) -> ModelSpec:
    """Multinomial logistic regression (convex)."""
    def init(key):
        return {"w": jnp.zeros((in_dim, n_classes)),
                "b": jnp.zeros((n_classes,))}

    def apply(params, x):
        return x @ params["w"] + params["b"]

    return ModelSpec(f"mclr_{in_dim}x{n_classes}", init, apply)


def mlp(in_dim: int, hidden: int, n_classes: int) -> ModelSpec:
    """One-hidden-layer perceptron (the paper's MLP-128 / MLP-512)."""
    def init(key):
        k1, k2 = jax.random.split(key)
        s1 = (2.0 / in_dim) ** 0.5
        s2 = (2.0 / hidden) ** 0.5
        return {"w1": jax.random.normal(k1, (in_dim, hidden)) * s1,
                "b1": jnp.zeros((hidden,)),
                "w2": jax.random.normal(k2, (hidden, n_classes)) * s2,
                "b2": jnp.zeros((n_classes,))}

    def apply(params, x):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    return ModelSpec(f"mlp_{in_dim}x{hidden}x{n_classes}", init, apply)


def lstm_classifier(vocab: int, embed: int, hidden: int,
                    n_classes: int = 2) -> ModelSpec:
    """LSTM sequence classifier (the paper's Sent140 model)."""
    def init(key):
        ks = jax.random.split(key, 4)
        s = (1.0 / hidden) ** 0.5
        return {
            "emb": jax.random.normal(ks[0], (vocab, embed)) * 0.1,
            "wx": jax.random.normal(ks[1], (embed, 4 * hidden)) * (1.0 / embed) ** 0.5,
            "wh": jax.random.normal(ks[2], (hidden, 4 * hidden)) * s,
            "b": jnp.zeros((4 * hidden,)),
            "w_out": jax.random.normal(ks[3], (hidden, n_classes)) * s,
            "b_out": jnp.zeros((n_classes,)),
        }

    def apply(params, x):          # x: (B, T) tokens (stored as float in the
        B, T = x.shape             # padded federated container)
        e = params["emb"][x.astype(jnp.int32)]     # (B, T, E)

        def cell(carry, e_t):
            h, c = carry
            z = e_t @ params["wx"] + h @ params["wh"] + params["b"]
            i, f, g, o = jnp.split(z, 4, -1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), None

        h0 = jnp.zeros((B, params["wh"].shape[0]))
        (h, _), _ = jax.lax.scan(cell, (h0, h0), e.transpose(1, 0, 2))
        return h @ params["w_out"] + params["b_out"]

    return ModelSpec(f"lstm_{vocab}x{embed}x{hidden}", init, apply)

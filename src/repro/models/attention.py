"""Attention blocks: GQA/MQA/MHA with RoPE, sliding-window, decode caches, MLA.

Shapes
  x            (B, S, D)
  q            (B, S, H, hd)
  k/v          (B, S, KV, hd)
  cache k/v    (B, Smax, KV, hd)   — ring buffer when windowed

All masking is done with additive -inf biases so one softmax path serves
causal / bidirectional / sliding-window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   dtype=jnp.float32, qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


# ---------------------------------------------------------------------------
# Core scaled-dot-product with GQA head repetition
# ---------------------------------------------------------------------------

def _repeat_kv(k, n_heads: int):
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each kv head."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


def sdpa(q, k, v, mask_bias, softmax_scale: float):
    """q:(B,Sq,H,hd) k,v:(B,Sk,H,hd) mask_bias:(Sq,Sk) or (B,1,Sq,Sk)."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * softmax_scale
    scores = scores + mask_bias
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def make_mask_bias(sq: int, sk: int, *, causal: bool, window: int | None,
                   q_offset: int = 0):
    """Additive bias (sq, sk). q position i maps to absolute i + q_offset."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Full-sequence (training / prefill) attention
# ---------------------------------------------------------------------------

def attention_fwd(params, x, *, n_heads: int, n_kv: int, head_dim: int,
                  rope_theta: float | None, causal: bool = True,
                  window: int | None = None, positions=None):
    B, S, D = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, n_heads, head_dim)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, S, n_kv, head_dim)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, S, n_kv, head_dim)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype).reshape(n_heads, head_dim)
        k = k + params["bk"].astype(x.dtype).reshape(n_kv, head_dim)
        v = v + params["bv"].astype(x.dtype).reshape(n_kv, head_dim)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    k = _repeat_kv(k, n_heads)
    v = _repeat_kv(v, n_heads)
    bias = make_mask_bias(S, S, causal=causal, window=window)
    out = sdpa(q, k, v, bias, 1.0 / head_dim ** 0.5)
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode-step attention with (optionally ring-buffer) KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
    }


def attention_decode(params, cache, x, pos, *, n_heads: int, n_kv: int,
                     head_dim: int, rope_theta: float | None,
                     window: int | None = None, kv_spec=None):
    """One-token decode. x:(B,1,D), pos:(B,) absolute position of the new token.

    Cache holds ``max_len`` slots. If ``window`` is set the cache is a ring
    buffer of size max_len (== window) indexed by pos % max_len; otherwise the
    cache is positional (slot == pos).

    kv_spec: optional PartitionSpec for the (B, Smax, KV, hd) cache. When the
    cache is sequence-sharded (kv heads < model axis), constraining the
    updated cache AND the head-repeated copies keeps the score einsum
    shard-local over the sequence — only softmax stats cross chips, instead
    of an involuntary full-cache rematerialization (see §Perf).
    """
    B, one, D = x.shape
    max_len = cache["k"].shape[1]
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, 1, n_heads, head_dim)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, 1, n_kv, head_dim)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, 1, n_kv, head_dim)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype).reshape(n_heads, head_dim)
        k = k + params["bk"].astype(x.dtype).reshape(n_kv, head_dim)
        v = v + params["bv"].astype(x.dtype).reshape(n_kv, head_dim)
    if rope_theta is not None:
        q = apply_rope(q, pos[:, None], rope_theta)
        k = apply_rope(k, pos[:, None], rope_theta)

    slot = pos % max_len if window is not None else pos
    bidx = jnp.arange(B)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])
    if kv_spec is not None:
        new_k = jax.lax.with_sharding_constraint(new_k, kv_spec)
        new_v = jax.lax.with_sharding_constraint(new_v, kv_spec)

    kk = _repeat_kv(new_k, n_heads)
    vv = _repeat_kv(new_v, n_heads)
    if kv_spec is not None:
        kk = jax.lax.with_sharding_constraint(kk, kv_spec)
        vv = jax.lax.with_sharding_constraint(vv, kv_spec)
    # Validity of each cache slot relative to the current position.
    slots = jnp.arange(max_len)[None, :]                       # (1, Smax)
    if window is not None:
        # slot s holds absolute position: the most recent p <= pos with
        # p % max_len == s.  Valid iff that position > pos - window and >= 0.
        delta = (slot[:, None] - slots) % max_len              # age of slot
        abs_pos = pos[:, None] - delta
        valid = (abs_pos >= 0) & (abs_pos > pos[:, None] - window)
    else:
        valid = slots <= pos[:, None]
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[:, None, None, :]
    out = sdpa(q, kk, vv, bias, 1.0 / head_dim ** 0.5)
    y = out.reshape(B, 1, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    return y, {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2/V3)
# ---------------------------------------------------------------------------
# Low-rank joint compression of q and kv. The decode cache stores only the
# compressed kv latent c_kv (rank r_kv) and the decoupled rope key k_pe.

def init_mla(key, d_model: int, n_heads: int, *, q_rank: int, kv_rank: int,
             qk_nope: int, qk_rope: int, v_dim: int, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], d_model, q_rank, dtype),
        "w_uq": dense_init(ks[1], q_rank, n_heads * (qk_nope + qk_rope), dtype),
        "w_dkv": dense_init(ks[2], d_model, kv_rank + qk_rope, dtype),
        "w_uk": dense_init(ks[3], kv_rank, n_heads * qk_nope, dtype),
        "w_uv": dense_init(ks[4], kv_rank, n_heads * v_dim, dtype),
        "wo": dense_init(ks[5], n_heads * v_dim, d_model, dtype),
        "q_norm": {"scale": jnp.ones((q_rank,), dtype)},
        "kv_norm": {"scale": jnp.ones((kv_rank,), dtype)},
    }


def _mla_qkv(params, x, positions, *, n_heads, qk_nope, qk_rope, v_dim,
             kv_rank, rope_theta):
    from repro.models.modules import rmsnorm
    B, S, D = x.shape
    cq = rmsnorm(params["q_norm"], x @ params["w_dq"].astype(x.dtype))
    q = (cq @ params["w_uq"].astype(x.dtype)).reshape(B, S, n_heads, qk_nope + qk_rope)
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]
    q_pe = apply_rope(q_pe, positions, rope_theta)

    dkv = x @ params["w_dkv"].astype(x.dtype)
    c_kv = rmsnorm(params["kv_norm"], dkv[..., :kv_rank])
    k_pe = apply_rope(dkv[..., kv_rank:][:, :, None, :], positions, rope_theta)
    return q_nope, q_pe, c_kv, k_pe[:, :, 0, :]


def mla_fwd(params, x, *, n_heads: int, qk_nope: int, qk_rope: int,
            v_dim: int, kv_rank: int, rope_theta: float,
            causal: bool = True, window: int | None = None, positions=None,
            q_chunk: int | None = None):
    """q_chunk (§Perf): when set, attention streams over query chunks with a
    running softmax — peak scores memory S*q_chunk instead of S²."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(
        params, x, positions, n_heads=n_heads, qk_nope=qk_nope,
        qk_rope=qk_rope, v_dim=v_dim, kv_rank=kv_rank, rope_theta=rope_theta)
    k_nope = (c_kv @ params["w_uk"].astype(x.dtype)).reshape(B, S, n_heads, qk_nope)
    v = (c_kv @ params["w_uv"].astype(x.dtype)).reshape(B, S, n_heads, v_dim)
    scale = 1.0 / (qk_nope + qk_rope) ** 0.5

    def block(qn, qp, q_off):
        sq = qn.shape[1]
        s = (jnp.einsum("bqhd,bkhd->bhqk", qn, k_nope,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bqhd,bkd->bhqk", qp, k_pe,
                          preferred_element_type=jnp.float32)) * scale
        s = s + make_mask_bias(sq, S, causal=causal, window=window,
                               q_offset=q_off)
        p = jax.nn.softmax(s, -1).astype(x.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    if q_chunk is None or S <= q_chunk:
        out = block(q_nope, q_pe, 0)
    else:
        assert S % q_chunk == 0
        nc = S // q_chunk
        qn_c = q_nope.reshape(B, nc, q_chunk, n_heads, qk_nope)
        qp_c = q_pe.reshape(B, nc, q_chunk, n_heads, qk_rope)

        def body(_, i):
            o = jax.checkpoint(block)(qn_c[:, i], qp_c[:, i], i * q_chunk)
            return None, o
        _, outs = jax.lax.scan(body, None, jnp.arange(nc))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, n_heads, v_dim)
    return out.reshape(B, S, n_heads * v_dim) @ params["wo"].astype(x.dtype)


def init_mla_cache(batch: int, max_len: int, kv_rank: int, qk_rope: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, kv_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, qk_rope), dtype),
    }


def mla_decode(params, cache, x, pos, *, n_heads: int, qk_nope: int,
               qk_rope: int, v_dim: int, kv_rank: int, rope_theta: float,
               window: int | None = None):
    """Absorbed-matrix MLA decode: attend in the compressed latent space.

    score(t) = q_nopeᵀ W_uk c_kv[t] + q_peᵀ k_pe[t]
             = (W_ukᵀ q_nope)ᵀ c_kv[t] + ...
    so the cache never needs expansion to per-head keys (DeepSeek-V3 §2.1).
    """
    B, one, D = x.shape
    max_len = cache["c_kv"].shape[1]
    q_nope, q_pe, c_kv_new, k_pe_new = _mla_qkv(
        params, x, pos[:, None], n_heads=n_heads, qk_nope=qk_nope,
        qk_rope=qk_rope, v_dim=v_dim, kv_rank=kv_rank, rope_theta=rope_theta)

    slot = pos % max_len if window is not None else pos
    bidx = jnp.arange(B)
    c_kv = cache["c_kv"].at[bidx, slot].set(c_kv_new[:, 0])
    k_pe = cache["k_pe"].at[bidx, slot].set(k_pe_new[:, 0])

    # absorb W_uk into the query:  q_lat (B,1,H,r_kv)
    w_uk = params["w_uk"].astype(x.dtype).reshape(kv_rank, n_heads, qk_nope)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    scale = 1.0 / (qk_nope + qk_rope) ** 0.5
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", q_pe, k_pe,
                           preferred_element_type=jnp.float32)) * scale

    slots = jnp.arange(max_len)[None, :]
    if window is not None:
        delta = (slot[:, None] - slots) % max_len
        abs_pos = pos[:, None] - delta
        valid = (abs_pos >= 0) & (abs_pos > pos[:, None] - window)
    else:
        valid = slots <= pos[:, None]
    scores = scores + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
    # out latent (B,1,H,r_kv) -> expand through W_uv
    out_lat = jnp.einsum("bhqk,bkr->bqhr", probs, c_kv)
    w_uv = params["w_uv"].astype(x.dtype).reshape(kv_rank, n_heads, v_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_uv)
    y = out.reshape(B, 1, n_heads * v_dim) @ params["wo"].astype(x.dtype)
    return y, {"c_kv": c_kv, "k_pe": k_pe}

"""Unified architecture zoo: one ArchConfig covers dense / MoE / MLA / SSM /
xLSTM / hybrid / VLM / audio families.

Layer parameters for uniform stacks are *stacked* along a leading axis and
iterated with ``jax.lax.scan`` (keeps HLO compact — a 61-layer model compiles
as one while-loop). Heterogeneous stacks (xLSTM's sLSTM/mLSTM mix) use a
Python loop; Zamba2's shared attention block rides inside the scan behind a
``lax.cond`` on the layer index.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.modules import (dense_init, embed_init, init_rmsnorm,
                                  mlp_apply, init_mlp, rmsnorm, tree_stack)


# ===========================================================================
# Config
# ===========================================================================

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    mlp_act: str = "silu"
    mlp_gated: bool = True
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d)
    causal: bool = True
    # --- MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    moe_impl: str = "scatter"        # scatter (baseline) | grouped (§Perf)
    # --- MLA (DeepSeek)
    mla: bool = False
    mtp: bool = False                # DeepSeek multi-token-prediction head
    mtp_weight: float = 0.3
    q_rank: int = 1536
    kv_rank: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head_dim: int = 128
    # --- SSM (Mamba2)
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssd_chunk: int = 128
    # --- hybrid (Zamba2)
    shared_attn_period: int = 0      # >0: shared attn block every N layers
    # --- xLSTM
    xlstm_pattern: Tuple[str, ...] = ()   # 'm' / 's' per layer
    mlstm_proj_factor: int = 2
    xlstm_chunk: int = 32
    mlstm_impl: str = "recurrent"    # recurrent (baseline) | chunkwise (§Perf)
    xlstm_scan_units: bool = False   # scan over periodic layer units (§Perf):
                                     # bounds live buffers to ONE unit instead
                                     # of the whole python-loop stack
    # --- modality frontend (stub per the carve-out)
    frontend: str = "none"           # none | audio | vision
    frontend_dim: int = 0
    n_patches: int = 256
    # --- attention variant
    window: Optional[int] = None     # sliding-window size (None = full)
    attn_q_chunk: Optional[int] = None  # query-chunked attention (§Perf)
    # --- numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    norm_eps: float = 1e-5
    aux_loss_weight: float = 0.01
    z_loss_weight: float = 0.001
    lr: float = 3e-4
    weight_decay: float = 0.1
    source: str = ""                 # citation for the config

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return (self.vocab_size + 255) // 256 * 256

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def decode_supported(self) -> bool:
        return self.family != "audio"

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode is supported (O(1)/O(window) state)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def with_window(self, window: int) -> "ArchConfig":
        return dataclasses.replace(self, window=window)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ===========================================================================
# Parameter init
# ===========================================================================

def _init_dense_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, cfg.p_dtype,
                                    cfg.qkv_bias),
        "ln2": init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gated, cfg.p_dtype),
    }


def _init_moe_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    if cfg.mla:
        a = attn.init_mla(k1, cfg.d_model, cfg.n_heads, q_rank=cfg.q_rank,
                          kv_rank=cfg.kv_rank, qk_nope=cfg.qk_nope,
                          qk_rope=cfg.qk_rope, v_dim=cfg.v_head_dim,
                          dtype=cfg.p_dtype)
    else:
        a = attn.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, cfg.p_dtype, cfg.qkv_bias)
    return {
        "ln1": init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "attn": a,
        "ln2": init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "moe": moe_lib.init_moe(k2, cfg.d_model, cfg.moe_d_ff or cfg.d_ff,
                                cfg.n_experts, cfg.n_shared_experts,
                                gated=cfg.mlp_gated, dtype=cfg.p_dtype),
    }


def _init_mamba_block(key, cfg: ArchConfig):
    return {
        "ln": init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "mixer": ssm_lib.init_mamba2(key, cfg.d_model, d_state=cfg.ssm_state,
                                     expand=cfg.ssm_expand,
                                     head_dim=cfg.ssm_head_dim,
                                     conv_width=cfg.conv_width,
                                     dtype=cfg.p_dtype),
    }


def init_params(key, cfg: ArchConfig):
    keys = jax.random.split(key, cfg.n_layers + 5)
    params = {}
    if cfg.frontend == "audio":
        params["frontend_proj"] = dense_init(keys[-1], cfg.frontend_dim,
                                             cfg.d_model, cfg.p_dtype)
    else:
        params["embed"] = embed_init(keys[-1], cfg.padded_vocab, cfg.d_model,
                                     cfg.p_dtype)
        if cfg.frontend == "vision":
            k1, k2 = jax.random.split(keys[-2])
            params["projector"] = {
                "w1": dense_init(k1, cfg.frontend_dim, cfg.d_model, cfg.p_dtype),
                "w2": dense_init(k2, cfg.d_model, cfg.d_model, cfg.p_dtype),
            }

    lk = keys[: cfg.n_layers]
    if cfg.family in ("dense", "vlm"):
        params["blocks"] = tree_stack([_init_dense_block(k, cfg) for k in lk])
    elif cfg.family == "audio":
        params["blocks"] = tree_stack([_init_dense_block(k, cfg) for k in lk])
    elif cfg.family == "moe":
        params["blocks"] = tree_stack([_init_moe_block(k, cfg) for k in lk])
    elif cfg.family == "hybrid":
        params["blocks"] = tree_stack([_init_mamba_block(k, cfg) for k in lk])
        params["shared_attn"] = _init_dense_block(keys[-3], cfg)
    elif cfg.family == "ssm":
        assert len(cfg.xlstm_pattern) == cfg.n_layers
        blocks = []
        for k, kind in zip(lk, cfg.xlstm_pattern):
            if kind == "s":
                blocks.append(("s", xlstm_lib.init_slstm(k, cfg.d_model,
                                                         cfg.n_heads, cfg.p_dtype)))
            else:
                blocks.append(("m", xlstm_lib.init_mlstm(
                    k, cfg.d_model, cfg.n_heads,
                    proj_factor=cfg.mlstm_proj_factor, dtype=cfg.p_dtype)))
        params["blocks_list"] = [b for _, b in blocks]
    else:
        raise ValueError(cfg.family)

    if cfg.mtp:
        km = jax.random.split(keys[-5], 2)
        params["mtp"] = {
            "proj": dense_init(km[0], 2 * cfg.d_model, cfg.d_model, cfg.p_dtype),
            "norm_h": init_rmsnorm(cfg.d_model, cfg.p_dtype),
            "norm_e": init_rmsnorm(cfg.d_model, cfg.p_dtype),
            "block": _init_dense_block(km[1], cfg.replace(
                mla=False, d_ff=max(cfg.moe_d_ff or cfg.d_ff, cfg.d_ff))),
        }
    params["final_norm"] = init_rmsnorm(cfg.d_model, cfg.p_dtype)
    if cfg.family == "audio":
        params["lm_head"] = dense_init(keys[-4], cfg.d_model, cfg.padded_vocab,
                                       cfg.p_dtype)
    elif not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-4], cfg.d_model, cfg.padded_vocab,
                                       cfg.p_dtype)
    return params


# ===========================================================================
# Block forwards
# ===========================================================================

def _dense_block_fwd(cfg: ArchConfig, p, x, positions):
    h = x + attn.attention_fwd(
        p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads, head_dim=cfg.hd, rope_theta=cfg.rope_theta,
        causal=cfg.causal, window=cfg.window, positions=positions)
    h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps), cfg.mlp_act)
    return h


def _moe_block_fwd(cfg: ArchConfig, p, x, positions):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        a = attn.mla_fwd(p["attn"], xn, n_heads=cfg.n_heads, qk_nope=cfg.qk_nope,
                         qk_rope=cfg.qk_rope, v_dim=cfg.v_head_dim,
                         kv_rank=cfg.kv_rank, rope_theta=cfg.rope_theta,
                         causal=cfg.causal, window=cfg.window,
                         positions=positions, q_chunk=cfg.attn_q_chunk)
    else:
        a = attn.attention_fwd(p["attn"], xn, n_heads=cfg.n_heads,
                               n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                               rope_theta=cfg.rope_theta, causal=cfg.causal,
                               window=cfg.window, positions=positions)
    h = x + a
    moe_fn = (moe_lib.moe_apply_grouped if cfg.moe_impl == "grouped"
              else moe_lib.moe_apply)
    y, aux = moe_fn(p["moe"], rmsnorm(p["ln2"], h, cfg.norm_eps),
                    top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor,
                    act=cfg.mlp_act)
    return h + y, aux


def _mamba_block_fwd(cfg: ArchConfig, p, x):
    return x + ssm_lib.mamba2_fwd(
        p["mixer"], rmsnorm(p["ln"], x, cfg.norm_eps), d_state=cfg.ssm_state,
        expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim, chunk=cfg.ssd_chunk)


# ===========================================================================
# Full forward (training / prefill)
# ===========================================================================

def embed_inputs(params, cfg: ArchConfig, batch):
    """Returns (hidden (B,S,D), positions (B,S) or None)."""
    if cfg.family == "audio":
        x = batch["frames"].astype(cfg.act_dtype) @ params["frontend_proj"].astype(cfg.act_dtype)
        return x, None
    tok = params["embed"].astype(cfg.act_dtype)[batch["tokens"]]
    if cfg.embed_scale:
        tok = tok * jnp.asarray(cfg.d_model ** 0.5, cfg.act_dtype)
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(cfg.act_dtype)
        proj = params["projector"]
        pe = jax.nn.gelu(pe @ proj["w1"].astype(cfg.act_dtype))
        pe = pe @ proj["w2"].astype(cfg.act_dtype)
        tok = jnp.concatenate([pe, tok], axis=1)
    return tok, None


def _logits(params, cfg: ArchConfig, h):
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings and cfg.family != "audio":
        return h @ params["embed"].astype(h.dtype).T
    return h @ params["lm_head"].astype(h.dtype)


def _pattern_period(pattern):
    """Smallest p such that pattern repeats every p layers."""
    L = len(pattern)
    for p in range(1, L + 1):
        if L % p == 0 and pattern == pattern[:p] * (L // p):
            return p
    return L


def forward(params, cfg: ArchConfig, batch, return_hidden: bool = False):
    """-> (logits (B,S,V), aux dict). return_hidden adds aux['hidden']."""
    x, _ = embed_inputs(params, cfg, batch)
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :]
    aux = {"load_balance_loss": jnp.zeros((), jnp.float32),
           "router_z_loss": jnp.zeros((), jnp.float32)}

    if cfg.family in ("dense", "vlm", "audio"):
        def body(h, p):
            return _dense_block_fwd(cfg, p, h, positions), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])

    elif cfg.family == "moe":
        def body(h, p):
            h, a = _moe_block_fwd(cfg, p, h, positions)
            return h, (a.load_balance_loss, a.router_z_loss)
        if cfg.remat:
            body = jax.checkpoint(body)
        x, (lb, zl) = jax.lax.scan(body, x, params["blocks"])
        aux["load_balance_loss"] = jnp.mean(lb)
        aux["router_z_loss"] = jnp.mean(zl)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        period = cfg.shared_attn_period

        def body(carry, inp):
            h = carry
            i, p = inp
            h = _mamba_block_fwd(cfg, p, h)
            if period > 0:
                h = jax.lax.cond(
                    (i + 1) % period == 0,
                    lambda hh: _dense_block_fwd(cfg, shared, hh, positions),
                    lambda hh: hh, h)
            return h, None
        if cfg.remat:
            body = jax.checkpoint(body)
        idx = jnp.arange(cfg.n_layers)
        x, _ = jax.lax.scan(body, x, (idx, params["blocks"]))

    elif cfg.family == "ssm":
        def block_fn(kind):
            if kind == "s":
                return partial(xlstm_lib.slstm_block_fwd, n_heads=cfg.n_heads,
                               chunk=cfg.xlstm_chunk)
            return partial(xlstm_lib.mlstm_block_fwd, n_heads=cfg.n_heads,
                           proj_factor=cfg.mlstm_proj_factor,
                           chunk=cfg.xlstm_chunk, impl=cfg.mlstm_impl)

        period = _pattern_period(cfg.xlstm_pattern)
        if cfg.xlstm_scan_units and period < cfg.n_layers:
            # scan over repeating units: the while loop bounds live buffers
            # to one unit's backward instead of the whole stack (Perf)
            n_units = cfg.n_layers // period
            unit_kinds = cfg.xlstm_pattern[:period]
            stacked = tuple(
                tree_stack([params["blocks_list"][u * period + j]
                            for u in range(n_units)])
                for j in range(period))

            def unit_body(h, unit_params):
                for j, kind in enumerate(unit_kinds):
                    fn = block_fn(kind)
                    # nested remat: only ONE block's backward is live at a
                    # time inside the unit's recompute
                    h = jax.checkpoint(fn)(unit_params[j], h) if cfg.remat \
                        else fn(unit_params[j], h)
                return h, None
            body = jax.checkpoint(unit_body) if cfg.remat else unit_body
            x, _ = jax.lax.scan(body, x, stacked)
        else:
            for kind, p in zip(cfg.xlstm_pattern, params["blocks_list"]):
                fn = block_fn(kind)
                x = jax.checkpoint(fn)(p, x) if cfg.remat else fn(p, x)
    else:
        raise ValueError(cfg.family)

    if return_hidden:
        aux["hidden"] = x
    return _logits(params, cfg, x), aux


def mtp_logits(params, cfg: ArchConfig, hidden, tokens):
    """DeepSeek-V3 multi-token-prediction head (one extra depth):
    position t combines its final hidden state with the embedding of token
    t+1 to predict token t+2. hidden: (B,S,D); tokens: (B,S).
    Returns logits (B, S-1, V) for targets tokens[t+2]."""
    mtp = params["mtp"]
    h = rmsnorm(mtp["norm_h"], hidden[:, :-1], cfg.norm_eps)
    e = params["embed"].astype(hidden.dtype)[tokens[:, 1:]]
    e = rmsnorm(mtp["norm_e"], e, cfg.norm_eps)
    x = jnp.concatenate([h, e], axis=-1) @ mtp["proj"].astype(hidden.dtype)
    positions = jnp.arange(x.shape[1])[None, :]
    x = _dense_block_fwd(cfg.replace(mla=False), mtp["block"], x, positions)
    return _logits(params, cfg, x)


# ===========================================================================
# Loss / train step
# ===========================================================================

def _ce(logits, labels):
    logits32 = logits.astype(jnp.float32)
    mask = (labels >= 0)
    safe = jnp.where(mask, labels, 0)
    ce = -jnp.take_along_axis(jax.nn.log_softmax(logits32, -1),
                              safe[..., None], axis=-1)[..., 0]
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1)


def loss_fn(params, cfg: ArchConfig, batch):
    logits, aux = forward(params, cfg, batch, return_hidden=cfg.mtp)
    labels = batch["labels"]
    if cfg.family == "vlm":                       # loss only on text positions
        logits = logits[:, -labels.shape[1]:]
    ce = _ce(logits, labels)
    total = (ce + cfg.aux_loss_weight * aux["load_balance_loss"]
             + cfg.z_loss_weight * aux["router_z_loss"])
    metrics = {"ce": ce}
    if cfg.mtp:
        hidden = aux.pop("hidden")
        m_logits = mtp_logits(params, cfg, hidden, batch["tokens"])
        mtp_ce = _ce(m_logits, labels[:, 1:])
        total = total + cfg.mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    aux.pop("hidden", None)
    return total, {**metrics, **aux}


def init_train_state(key, cfg: ArchConfig):
    params = init_params(key, cfg)
    zeros = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"params": params, "mu": zeros(), "nu": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def train_step(state, batch, cfg: ArchConfig, b1=0.9, b2=0.95, eps=1e-8):
    """One AdamW step; returns (new_state, metrics)."""
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        state["params"], cfg, batch)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * g32
        nu_n = b2 * nu + (1 - b2) * jnp.square(g32)
        u = (mu_n / bc1) / (jnp.sqrt(nu_n / bc2) + eps)
        p_n = p.astype(jnp.float32) - cfg.lr * (u + cfg.weight_decay * p.astype(jnp.float32))
        return p_n.astype(p.dtype), mu_n, nu_n

    flat_p, treedef = jax.tree_util.tree_flatten(state["params"])
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"params": new_p, "mu": new_mu, "nu": new_nu, "step": step}
    return new_state, {"loss": loss, **metrics}


# ===========================================================================
# Decode: cache init + serve_step
# ===========================================================================

def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    dt = cfg.act_dtype
    if cfg.family in ("dense", "vlm"):
        one = attn.init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, dt)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), one)
    if cfg.family == "moe":
        if cfg.mla:
            one = attn.init_mla_cache(batch, max_len, cfg.kv_rank, cfg.qk_rope, dt)
        else:
            one = attn.init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, dt)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), one)
    if cfg.family == "hybrid":
        m = ssm_lib.init_mamba2_cache(batch, cfg.d_model, d_state=cfg.ssm_state,
                                      expand=cfg.ssm_expand,
                                      head_dim=cfg.ssm_head_dim,
                                      conv_width=cfg.conv_width, dtype=dt)
        mstack = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), m)
        n_apps = cfg.n_layers // cfg.shared_attn_period if cfg.shared_attn_period else 0
        sa = attn.init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, dt)
        sstack = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (max(n_apps, 1),) + x.shape).copy(), sa)
        return {"mamba": mstack, "shared_attn": sstack}
    if cfg.family == "ssm":
        caches = []
        for kind in cfg.xlstm_pattern:
            if kind == "s":
                caches.append(xlstm_lib.init_slstm_cache(batch, cfg.d_model, dt))
            else:
                caches.append(xlstm_lib.init_mlstm_cache(
                    batch, cfg.d_model, cfg.n_heads, cfg.mlstm_proj_factor, dt))
        return {"xlstm": caches}
    raise ValueError(f"{cfg.family} has no decode cache (encoder-only?)")


def serve_step(params, cfg: ArchConfig, cache, tokens, pos, kv_spec=None):
    """Decode ONE token. tokens: (B,1) int32; pos: (B,) absolute positions.
    Returns (logits (B, V), new_cache).

    kv_spec: optional PartitionSpec for one layer's (B, Smax, KV, hd) KV
    cache — forwarded to attention_decode to pin sequence-sharded caches
    (see sharding/specs.py cache_specs(seq_shard=True) and §Perf)."""
    x = params["embed"].astype(cfg.act_dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.act_dtype)

    if cfg.family in ("dense", "vlm"):
        def body(carry, xs):
            h = carry
            p, c = xs
            y, c2 = attn.attention_decode(
                p["attn"], c, rmsnorm(p["ln1"], h, cfg.norm_eps), pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta, window=cfg.window, kv_spec=kv_spec)
            h = h + y
            h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps),
                              cfg.mlp_act)
            return h, c2
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))

    elif cfg.family == "moe":
        def body(carry, xs):
            h = carry
            p, c = xs
            xn = rmsnorm(p["ln1"], h, cfg.norm_eps)
            if cfg.mla:
                y, c2 = attn.mla_decode(p["attn"], c, xn, pos,
                                        n_heads=cfg.n_heads, qk_nope=cfg.qk_nope,
                                        qk_rope=cfg.qk_rope, v_dim=cfg.v_head_dim,
                                        kv_rank=cfg.kv_rank,
                                        rope_theta=cfg.rope_theta,
                                        window=cfg.window)
            else:
                y, c2 = attn.attention_decode(p["attn"], c, xn, pos,
                                              n_heads=cfg.n_heads,
                                              n_kv=cfg.n_kv_heads,
                                              head_dim=cfg.hd,
                                              rope_theta=cfg.rope_theta,
                                              window=cfg.window,
                                              kv_spec=kv_spec)
            h = h + y
            y2, _ = moe_lib.moe_apply(p["moe"],
                                      rmsnorm(p["ln2"], h, cfg.norm_eps),
                                      top_k=cfg.top_k,
                                      capacity_factor=cfg.capacity_factor,
                                      act=cfg.mlp_act)
            return h + y2, c2
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))

    elif cfg.family == "hybrid":
        # python loop: shared-attn applications each own a cache slot
        new_mamba, new_shared = [], []
        app = 0
        for i in range(cfg.n_layers):
            p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            c = jax.tree_util.tree_map(lambda a: a[i], cache["mamba"])
            y, c2 = ssm_lib.mamba2_step(p["mixer"],
                                        c, rmsnorm(p["ln"], x, cfg.norm_eps),
                                        d_state=cfg.ssm_state,
                                        expand=cfg.ssm_expand,
                                        head_dim=cfg.ssm_head_dim)
            x = x + y
            new_mamba.append(c2)
            if cfg.shared_attn_period and (i + 1) % cfg.shared_attn_period == 0:
                sp = params["shared_attn"]
                sc = jax.tree_util.tree_map(lambda a: a[app], cache["shared_attn"])
                y, sc2 = attn.attention_decode(
                    sp["attn"], sc, rmsnorm(sp["ln1"], x, cfg.norm_eps), pos,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta, window=cfg.window)
                x = x + y
                x = x + mlp_apply(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps),
                                  cfg.mlp_act)
                new_shared.append(sc2)
                app += 1
        new_cache = {"mamba": tree_stack(new_mamba),
                     "shared_attn": tree_stack(new_shared) if new_shared
                     else cache["shared_attn"]}

    elif cfg.family == "ssm":
        new_list = []
        for kind, p, c in zip(cfg.xlstm_pattern, params["blocks_list"],
                              cache["xlstm"]):
            if kind == "s":
                x, c2 = xlstm_lib.slstm_block_step(p, c, x, n_heads=cfg.n_heads)
            else:
                x, c2 = xlstm_lib.mlstm_block_step(
                    p, c, x, n_heads=cfg.n_heads,
                    proj_factor=cfg.mlstm_proj_factor)
            new_list.append(c2)
        new_cache = {"xlstm": new_list}
    else:
        raise ValueError(cfg.family)

    logits = _logits(params, cfg, x)[:, 0]
    return logits, new_cache

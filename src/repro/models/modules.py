"""Functional neural-net building blocks (no flax — plain pytrees of arrays).

Every layer is a pair of functions:
  init_*(key, ...) -> params (nested dict of jnp arrays)
  *_apply(params, x, ...) -> y

Parameters are stored in whatever dtype ``param_dtype`` requests; compute is
performed in ``dtype`` (activations). This mirrors common mixed-precision
TPU practice (bf16 activations, fp32 or bf16 params).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float | None = None):
    """Lecun-normal style init for a (in_dim, out_dim) kernel."""
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def zeros_init(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": ones_init((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": ones_init((dim,), dtype), "bias": zeros_init((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # squared ReLU (Nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    if name == "geglu_gelu":  # gate activation for GeGLU (gemma)
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# Gated / plain MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_apply(params, x, act: str):
    """Gated (SwiGLU/GeGLU) if 'w_gate' present, else plain act(xW)W."""
    a = act_fn(act)
    up = x @ params["w_up"].astype(x.dtype)
    if "w_gate" in params:
        gate = a(x @ params["w_gate"].astype(x.dtype))
        h = gate * up
    else:
        h = a(up)
    return h @ params["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)          # (half,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                    # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------

def tree_stack(trees: Sequence):
    """Stack a list of identically-structured pytrees along new axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def param_count(params) -> int:
    return int(sum(p.size for p in jax.tree_util.tree_leaves(params)))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p, tree)


def flatten_updates(tree) -> jnp.ndarray:
    """Flatten a pytree of arrays into a single 1-D vector (paper's Δw)."""
    leaves = [jnp.ravel(l) for l in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(l.dtype, jnp.floating)]
    return jnp.concatenate(leaves) if leaves else jnp.zeros((0,))


def unflatten_like(vec, tree):
    """Inverse of flatten_updates given a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for l in leaves:
        if jnp.issubdtype(l.dtype, jnp.floating):
            out.append(vec[off:off + l.size].reshape(l.shape).astype(l.dtype))
            off += l.size
        else:
            out.append(l)
    return jax.tree_util.tree_unflatten(treedef, out)

"""Mixture-of-Experts layer with sort-based capacity dispatch.

Design (TPU-native, expert-parallel friendly):
  * top-k router with softmax gates (optionally normalized over top-k).
  * dispatch by sorting flattened token-expert assignments by expert id and
    scattering into a dense (E, C, D) buffer (capacity C); tokens beyond an
    expert's capacity are dropped (their combine weight is zero) — the classic
    Switch/GShard capacity discipline, which keeps every shape static for XLA.
  * expert compute is one batched einsum over the expert axis — when experts
    are sharded over the "model" mesh axis, XLA inserts the all-to-all
    (dispatch) and all-to-all (combine) automatically from the shardings.
  * aux losses: Switch load-balance loss + router z-loss.

FLOPs are proportional to E·C·D·F with C ≈ tokens·top_k/E · capacity_factor,
i.e. only *active* expert compute — no dense all-experts waste.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.modules import dense_init, act_fn


class MoEAux(NamedTuple):
    load_balance_loss: jnp.ndarray
    router_z_loss: jnp.ndarray
    expert_load: jnp.ndarray          # fraction of tokens routed per expert


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             n_shared: int = 0, shared_d_ff: int | None = None,
             gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    scale = 1.0 / (d_model ** 0.5)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32, scale=0.02),
        "w_up": (jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (n_experts, d_ff, d_model)) * (1.0 / d_ff ** 0.5)).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[3], (n_experts, d_model, d_ff)) * scale).astype(dtype)
    if n_shared > 0:
        sdff = shared_d_ff or d_ff
        p["shared"] = {
            "w_up": dense_init(ks[4], d_model, n_shared * sdff, dtype),
            "w_gate": dense_init(ks[5], d_model, n_shared * sdff, dtype),
            "w_down": dense_init(jax.random.fold_in(ks[4], 7), n_shared * sdff, d_model, dtype),
        }
    return p


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu", normalize_gates: bool = True):
    """x: (B, S, D) -> (y, MoEAux)."""
    B, S, D = x.shape
    E = params["router"].shape[1]
    N = B * S
    xt = x.reshape(N, D)

    logits = (xt.astype(jnp.float32) @ params["router"])            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)             # (N, k)
    if normalize_gates:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    capacity = max(1, int(round(N * top_k / E * capacity_factor)))
    # round capacity up to a lane-friendly multiple of 8
    capacity = (capacity + 7) // 8 * 8

    # ---- dispatch bookkeeping: position of each (token, slot) within expert
    flat_e = expert_ids.reshape(-1)                                 # (N*k,)
    # rank of each assignment within its expert, computed via one-hot cumsum
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)             # (N*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot                  # (N*k, E)
    rank = jnp.sum(pos_in_e, axis=-1) - 1                           # (N*k,)
    keep = rank < capacity
    safe_rank = jnp.where(keep, rank, capacity - 1)

    # scatter tokens into (E, C, D)
    buf = jnp.zeros((E, capacity, D), xt.dtype)
    tok_idx = jnp.repeat(jnp.arange(N), top_k)
    src = jnp.where(keep[:, None], xt[tok_idx], 0)
    buf = buf.at[flat_e, safe_rank].add(src)

    # ---- expert compute (batched over E; shard E over mesh "model")
    a = act_fn(act)
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(xt.dtype))
    if "w_gate" in params:
        g = a(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(xt.dtype)))
        h = g * up
    else:
        h = a(up)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xt.dtype))

    # ---- combine back with gate weights
    gathered = out_buf[flat_e, safe_rank]                           # (N*k, D)
    w = (gate_vals.reshape(-1) * keep).astype(xt.dtype)
    y = jnp.zeros((N, D), xt.dtype).at[tok_idx].add(gathered * w[:, None])

    # ---- shared expert(s), always-on (DeepSeek-style)
    if "shared" in params:
        sh = params["shared"]
        g = a(xt @ sh["w_gate"].astype(xt.dtype))
        y = y + (g * (xt @ sh["w_up"].astype(xt.dtype))) @ sh["w_down"].astype(xt.dtype)

    # ---- aux losses
    me = jnp.mean(probs, axis=0)                                    # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    load_balance = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    load = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.float32), axis=0) / (N * top_k)
    return y.reshape(B, S, D), MoEAux(load_balance, z_loss, load)


# ---------------------------------------------------------------------------
# Grouped (GShard-style) dispatch — the SPMD-friendly production path (§Perf)
# ---------------------------------------------------------------------------
# The scatter-based path above uses gathers whose indices span the sharded
# token axis, which forces XLA to replicate (N·k, D) token copies. Here every
# sort/gather is BATCHED over a group axis G (= the batch dim, sharded over
# "data"), so all index ops stay shard-local, and the (G,E,C,D)->(E,G,C,D)
# transpose before expert compute lowers to the canonical MoE all-to-all.

def moe_apply_grouped(params, x, *, top_k: int, capacity_factor: float = 1.25,
                      act: str = "silu", normalize_gates: bool = True):
    """x: (B, S, D) -> (y, MoEAux). Groups = batch rows."""
    B, S, D = x.shape
    E = params["router"].shape[1]
    G, T = B, S
    xt = x                                                           # (G,T,D)

    logits = xt.astype(jnp.float32) @ params["router"]               # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)              # (G,T,k)
    if normalize_gates:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    TK = T * top_k
    capacity = max(1, int(round(T * top_k / E * capacity_factor)))
    capacity = (capacity + 7) // 8 * 8
    C = capacity

    flat_e = expert_ids.reshape(G, TK)
    tok_of_slot = jnp.broadcast_to(jnp.arange(TK) // top_k, (G, TK))
    order = jnp.argsort(flat_e, axis=1, stable=True)                 # (G,TK)
    sorted_e = jnp.take_along_axis(flat_e, order, 1)
    sorted_tok = jnp.take_along_axis(tok_of_slot, order, 1)

    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts                     # (G,E)
    rank_sorted = (jnp.arange(TK)[None, :]
                   - jnp.take_along_axis(starts, sorted_e, 1))       # (G,TK)
    keep_sorted = rank_sorted < C

    # (G,E,C): which sorted slot fills buffer cell (e, c)
    src_slot = starts[:, :, None] + jnp.arange(C)[None, None, :]
    cell_valid = jnp.arange(C)[None, None, :] < jnp.minimum(counts, C)[:, :, None]
    slot_idx = jnp.clip(src_slot, 0, TK - 1).reshape(G, E * C)
    tok_for_buf = jnp.take_along_axis(sorted_tok, slot_idx, 1)       # (G,E*C)
    buf = jnp.take_along_axis(xt, tok_for_buf[..., None], axis=1)    # (G,E*C,D)
    buf = buf * cell_valid.reshape(G, E * C, 1).astype(buf.dtype)
    buf = buf.reshape(G, E, C, D)

    # ---- expert compute sharded over E: the transpose IS the all-to-all
    ebuf = buf.transpose(1, 0, 2, 3).reshape(E, G * C, D)
    a = act_fn(act)
    up = jnp.einsum("ecd,edf->ecf", ebuf, params["w_up"].astype(ebuf.dtype))
    if "w_gate" in params:
        g = a(jnp.einsum("ecd,edf->ecf", ebuf, params["w_gate"].astype(ebuf.dtype)))
        h = g * up
    else:
        h = a(up)
    out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(ebuf.dtype))
    out_buf = out_e.reshape(E, G, C, D).transpose(1, 0, 2, 3)        # all-to-all back
    out_flat = out_buf.reshape(G, E * C, D)

    # ---- combine: sorted slots read their buffer cell, then unsort
    dest = sorted_e * C + jnp.clip(rank_sorted, 0, C - 1)            # (G,TK)
    vals_sorted = jnp.take_along_axis(out_flat, dest[..., None], axis=1)
    vals_sorted = vals_sorted * keep_sorted[..., None].astype(vals_sorted.dtype)
    inv = jnp.argsort(order, axis=1, stable=True)
    vals = jnp.take_along_axis(vals_sorted, inv[..., None], axis=1)  # (G,TK,D)
    w = gate_vals.reshape(G, T, top_k).astype(vals.dtype)
    y = jnp.sum(vals.reshape(G, T, top_k, D) * w[..., None], axis=2)

    if "shared" in params:
        sh = params["shared"]
        gsh = a(x @ sh["w_gate"].astype(x.dtype))
        y = y + (gsh * (x @ sh["w_up"].astype(x.dtype))) @ sh["w_down"].astype(x.dtype)

    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0].reshape(-1), E,
                                 dtype=jnp.float32), axis=0)
    load_balance = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    load = jnp.sum(jax.nn.one_hot(flat_e.reshape(-1), E, dtype=jnp.float32),
                   axis=0) / (G * TK)
    return y, MoEAux(load_balance, z_loss, load)

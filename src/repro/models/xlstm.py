"""xLSTM blocks: sLSTM (scalar memory, recurrent gating) and mLSTM (matrix
memory) — Beck et al. 2024 (arXiv:2405.04517), stabilized formulations.

TPU adaptation notes (see DESIGN.md):
  * both cells are implemented as stabilized recurrent scans over time; to
    keep the backward-pass memory bounded the scan is blocked into chunks of
    ``chunk`` steps with ``jax.checkpoint`` around each chunk (boundary states
    stored, interiors recomputed).
  * a chunkwise-parallel mLSTM (SSD-style) is the §Perf hillclimb path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.modules import dense_init, init_layernorm, layernorm


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


# ===========================================================================
# sLSTM
# ===========================================================================

def init_slstm(key, d_model: int, n_heads: int, dtype=jnp.float32):
    dh = d_model // n_heads
    ks = jax.random.split(key, 4)
    return {
        "ln": init_layernorm(d_model, dtype),
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype),        # i,f,z,o
        "r": (jax.random.normal(ks[1], (n_heads, dh, 4 * dh))
              * (1.0 / dh ** 0.5)).astype(dtype),                      # block-diag recurrent
        "b": jnp.zeros((4 * d_model,), dtype),
        "gn": init_layernorm(d_model, dtype),                          # post group-norm
        "w_up": dense_init(ks[2], d_model, (4 * d_model) // 3, dtype),
        "w_gate": dense_init(jax.random.fold_in(ks[2], 1), d_model, (4 * d_model) // 3, dtype),
        "w_down": dense_init(ks[3], (4 * d_model) // 3, d_model, dtype),
    }


def slstm_cell(params, carry, x_t, n_heads: int):
    """One step. carry = (h, c, n, m) each (B, d). x_t: (B, d)."""
    h, c, n, m = carry
    B, d = x_t.shape
    dh = d // n_heads
    gates_in = x_t @ params["w_in"].astype(x_t.dtype)                  # (B, 4d)
    hh = h.reshape(B, n_heads, dh)
    gates_rec = jnp.einsum("bhd,hde->bhe", hh, params["r"].astype(x_t.dtype))
    gates = (gates_in.reshape(B, n_heads, 4 * dh) + gates_rec
             ).reshape(B, 4 * d) + params["b"].astype(x_t.dtype)
    i_r, f_r, z_r, o_r = jnp.split(gates.astype(jnp.float32), 4, axis=-1)

    f_log = _logsigmoid(f_r)
    m_new = jnp.maximum(f_log + m, i_r)
    i_g = jnp.exp(i_r - m_new)
    f_g = jnp.exp(f_log + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_r)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1e-6)
    h_new = h_new.astype(x_t.dtype)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_scan(params, x, n_heads: int, chunk: int = 64, init=None):
    """x: (B, S, d) -> (h_seq (B,S,d), final carry)."""
    B, S, d = x.shape
    if init is None:
        z32 = jnp.zeros((B, d), jnp.float32)
        init = (jnp.zeros((B, d), x.dtype), z32, z32, z32 - 30.0)

    cell = partial(slstm_cell, params, n_heads=n_heads)

    @jax.checkpoint
    def run_chunk(carry, xc):                                          # xc: (Q, B, d)
        return jax.lax.scan(lambda cr, xt: cell(cr, xt), carry, xc)

    q = min(chunk, S)
    while S % q:
        q -= 1
    xs = x.transpose(1, 0, 2).reshape(S // q, q, B, d)
    carry, hs = jax.lax.scan(run_chunk, init, xs)
    h_seq = hs.reshape(S, B, d).transpose(1, 0, 2)
    return h_seq, carry


def slstm_block_fwd(params, x, *, n_heads: int, chunk: int = 64):
    """Full pre-norm sLSTM block with post-FFN (factor 4/3, gated)."""
    h, _ = slstm_scan(params, layernorm(params["ln"], x), n_heads, chunk)
    x = x + layernorm(params["gn"], h)
    ff_in = x
    g = jax.nn.silu(ff_in @ params["w_gate"].astype(x.dtype))
    up = ff_in @ params["w_up"].astype(x.dtype)
    return x + (g * up) @ params["w_down"].astype(x.dtype)


def init_slstm_cache(batch: int, d_model: int, dtype=jnp.float32):
    z32 = jnp.zeros((batch, d_model), jnp.float32)
    return {"h": jnp.zeros((batch, d_model), dtype), "c": z32, "n": z32,
            "m": z32 - 30.0}


def slstm_block_step(params, cache, x, *, n_heads: int):
    """x: (B,1,d) decode step."""
    xt = layernorm(params["ln"], x)[:, 0]
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    carry, h = slstm_cell(params, carry, xt, n_heads)
    y = x + layernorm(params["gn"], h)[:, None, :]
    g = jax.nn.silu(y @ params["w_gate"].astype(x.dtype))
    up = y @ params["w_up"].astype(x.dtype)
    y = y + (g * up) @ params["w_down"].astype(x.dtype)
    return y, {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}


# ===========================================================================
# mLSTM
# ===========================================================================
#
# Two sequence implementations:
#   * recurrent  — stabilized per-step scan (chunk-rematted). Baseline; the
#     backward pass materializes (B,H,P,P) matrix-memory states and starves
#     the MXU (tiny per-step ops).
#   * chunkwise  — SSD-style parallel form (§Perf optimization): intra-chunk
#     quadratic attention-like term (dense matmuls) + an inter-chunk
#     recurrence carrying only the stabilized (C̃, ñ, m) boundary state.
#     Identical outputs (tested to 1e-4 against the recurrent form).

def init_mlstm(key, d_model: int, n_heads: int, *, proj_factor: int = 2,
               dtype=jnp.float32):
    di = proj_factor * d_model
    ks = jax.random.split(key, 8)
    return {
        "ln": init_layernorm(d_model, dtype),
        "w_up": dense_init(ks[0], d_model, di, dtype),
        "w_gate_out": dense_init(ks[1], d_model, di, dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_if": dense_init(ks[5], di, 2 * n_heads, jnp.float32, scale=0.02),
        "b_if": jnp.concatenate([jnp.zeros((n_heads,)),
                                 jnp.linspace(3.0, 6.0, n_heads)]),     # forget-gate bias high
        "gn": init_layernorm(di, dtype),
        "w_down": dense_init(ks[6], di, d_model, dtype),
    }


def mlstm_cell(carry, inp):
    """carry: (C (B,H,P,P), n (B,H,P), m (B,H)); inp: q,k,v (B,H,P), i/f raw (B,H)."""
    C, n, m = carry
    q, k, v, i_r, f_r = inp
    P = q.shape[-1]
    f_log = _logsigmoid(f_r)
    m_new = jnp.maximum(f_log + m, i_r)                                 # (B,H)
    i_g = jnp.exp(i_r - m_new)
    f_g = jnp.exp(f_log + m - m_new)
    k32 = k.astype(jnp.float32) / P ** 0.5
    v32 = v.astype(jnp.float32)
    C_new = f_g[..., None, None] * C + i_g[..., None, None] * (
        k32[..., :, None] * v32[..., None, :])                          # (B,H,P,P)
    n_new = f_g[..., None] * n + i_g[..., None] * k32
    q32 = q.astype(jnp.float32)
    num = jnp.einsum("bhp,bhpv->bhv", q32, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q32, n_new)),
                      jnp.exp(-m_new)) + 1e-6
    h = (num / den[..., None]).astype(q.dtype)
    return (C_new, n_new, m_new), h


def mlstm_scan(x_inner, params, n_heads: int, chunk: int = 32, init=None):
    """x_inner: (B, S, di) pre-projected. Returns (h (B,S,di), carry)."""
    B, S, di = x_inner.shape
    P = di // n_heads
    q = (x_inner @ params["wq"].astype(x_inner.dtype)).reshape(B, S, n_heads, P)
    k = (x_inner @ params["wk"].astype(x_inner.dtype)).reshape(B, S, n_heads, P)
    v = (x_inner @ params["wv"].astype(x_inner.dtype)).reshape(B, S, n_heads, P)
    if_r = (x_inner.astype(jnp.float32) @ params["w_if"]
            + params["b_if"]).reshape(B, S, 2, n_heads)
    i_r, f_r = if_r[:, :, 0], if_r[:, :, 1]                             # (B,S,H)

    if init is None:
        init = (jnp.zeros((B, n_heads, P, P), jnp.float32),
                jnp.zeros((B, n_heads, P), jnp.float32),
                jnp.zeros((B, n_heads), jnp.float32) - 30.0)

    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    resh = lambda a: a.transpose(1, 0, *range(2, a.ndim)).reshape(
        S // Q, Q, *a.shape[0:1], *a.shape[2:])
    xs = tuple(map(resh, (q, k, v, i_r, f_r)))

    @jax.checkpoint
    def run_chunk(carry, xc):
        return jax.lax.scan(mlstm_cell, carry, xc)

    carry, hs = jax.lax.scan(run_chunk, init, xs)
    h = hs.reshape(S, B, n_heads, P).transpose(1, 0, 2, 3).reshape(B, S, di)
    return h, carry


def mlstm_chunkwise(q, k, v, i_r, f_r, chunk: int, init=None):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B,S,H,P); i_r: raw input-gate logits (B,S,H); f_r: raw
    forget-gate logits (B,S,H). Returns (h (B,S,H,P), carry).
    All gate math in fp32; the intra-chunk term is a masked (Q×Q) matmul.
    """
    B, S, H, P = q.shape
    Q = chunk
    assert S % Q == 0, (S, Q)
    NC = S // Q
    scale = 1.0 / P ** 0.5
    f_log = _logsigmoid(f_r.astype(jnp.float32))
    i32 = i_r.astype(jnp.float32)

    resh = lambda a: a.reshape(B, NC, Q, *a.shape[2:])
    # q/k/v stay in input dtype (bf16 in production): the score and output
    # einsums accumulate in fp32 via preferred_element_type; only the gate
    # path is fp32. Halves the full-sequence stacks + their cotangents.
    qc = resh(q)
    kc = resh(k * jnp.asarray(scale, k.dtype))
    vc = resh(v)
    ic = resh(i32)                                   # (B,NC,Q,H)
    fc = resh(f_log)
    b = jnp.cumsum(fc, axis=2)                       # inclusive log-decay sums

    # intra-chunk log weights D[t, j] = b_t - b_j + i_j  (j <= t)
    D = b[:, :, :, None, :] - b[:, :, None, :, :] + ic[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    D = jnp.where(tri, D, -1e30)                     # (B,NC,Q,Q,H)
    m_intra = jnp.max(D, axis=3)                     # (B,NC,Q,H)

    if init is None:
        init = (jnp.zeros((B, H, P, P), jnp.float32),
                jnp.zeros((B, H, P), jnp.float32),
                jnp.zeros((B, H), jnp.float32) - 30.0)

    def chunk_step(carry, xs):
        C_p, n_p, m_p = carry                        # scaled state, log-scale m_p
        qq, kk, vv, bb, ii, DD, mi = xs              # (B,Q,H,P)... (B,Q,Q,H)...
        m_state = bb + m_p[:, None, :]               # (B,Q,H)
        m_t = jnp.maximum(mi, m_state)
        s = jnp.einsum("bqhp,bjhp->bqjh", qq, kk,
                       preferred_element_type=jnp.float32)
        w = jnp.exp(DD - m_t[:, :, None, :]) * s     # (B,Q,Q,H) fp32
        num = jnp.einsum("bqjh,bjhp->bqhp", w.astype(vv.dtype), vv,
                         preferred_element_type=jnp.float32)
        den = jnp.sum(w, axis=2)                     # (B,Q,H) == q·n_intra
        sc_state = jnp.exp(m_state - m_t)            # (B,Q,H)
        num = num + sc_state[..., None] * jnp.einsum(
            "bqhp,bhpv->bqhv", qq.astype(jnp.float32), C_p)
        den = den + sc_state * jnp.einsum(
            "bqhp,bhp->bqh", qq.astype(jnp.float32), n_p)
        h = num / (jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None] + 1e-6)

        # carry to next chunk
        g = bb[:, -1:, :] - bb + ii                  # (B,Q,H)
        m_C = jnp.maximum(bb[:, -1] + m_p, jnp.max(g, axis=1))   # (B,H)
        sc_prev = jnp.exp(bb[:, -1] + m_p - m_C)
        wg = jnp.exp(g - m_C[:, None, :])            # (B,Q,H)
        C_n = (sc_prev[..., None, None] * C_p
               + jnp.einsum("bqh,bqhp,bqhv->bhpv",
                            wg.astype(kk.dtype), kk, vv,
                            preferred_element_type=jnp.float32))
        n_n = sc_prev[..., None] * n_p + jnp.einsum(
            "bqh,bqhp->bhp", wg.astype(kk.dtype), kk,
            preferred_element_type=jnp.float32)
        return (C_n, n_n, m_C), h

    tr = lambda a: a.transpose(1, 0, *range(2, a.ndim))
    xs = tuple(map(tr, (qc, kc, vc, b, ic, D, m_intra)))
    carry, hs = jax.lax.scan(chunk_step, init, xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return h.astype(q.dtype), carry


def mlstm_seq(x_inner, params, n_heads: int, chunk: int = 32,
              impl: str = "recurrent"):
    """Dispatch: recurrent scan (baseline) or chunkwise-parallel (§Perf)."""
    if impl == "recurrent":
        return mlstm_scan(x_inner, params, n_heads, chunk)
    B, S, di = x_inner.shape
    P = di // n_heads
    q = (x_inner @ params["wq"].astype(x_inner.dtype)).reshape(B, S, n_heads, P)
    k = (x_inner @ params["wk"].astype(x_inner.dtype)).reshape(B, S, n_heads, P)
    v = (x_inner @ params["wv"].astype(x_inner.dtype)).reshape(B, S, n_heads, P)
    if_r = (x_inner.astype(jnp.float32) @ params["w_if"]
            + params["b_if"]).reshape(B, S, 2, n_heads)
    h, carry = mlstm_chunkwise(q, k, v, if_r[:, :, 0], if_r[:, :, 1],
                               min(chunk, S))
    return h.reshape(B, S, di), carry


def mlstm_block_fwd(params, x, *, n_heads: int, proj_factor: int = 2,
                    chunk: int = 32, impl: str = "recurrent"):
    xn = layernorm(params["ln"], x)
    inner = xn @ params["w_up"].astype(x.dtype)
    gate = jax.nn.silu(xn @ params["w_gate_out"].astype(x.dtype))
    h, _ = mlstm_seq(inner, params, n_heads, chunk, impl=impl)
    h = layernorm(params["gn"], h) * gate
    return x + h @ params["w_down"].astype(x.dtype)


def init_mlstm_cache(batch: int, d_model: int, n_heads: int,
                     proj_factor: int = 2, dtype=jnp.float32):
    di = proj_factor * d_model
    P = di // n_heads
    return {"C": jnp.zeros((batch, n_heads, P, P), jnp.float32),
            "n": jnp.zeros((batch, n_heads, P), jnp.float32),
            "m": jnp.zeros((batch, n_heads), jnp.float32) - 30.0}


def mlstm_block_step(params, cache, x, *, n_heads: int, proj_factor: int = 2):
    B, one, d = x.shape
    di = proj_factor * d
    P = di // n_heads
    xn = layernorm(params["ln"], x)[:, 0]
    inner = xn @ params["w_up"].astype(x.dtype)
    gate = jax.nn.silu(xn @ params["w_gate_out"].astype(x.dtype))
    q = (inner @ params["wq"].astype(x.dtype)).reshape(B, n_heads, P)
    k = (inner @ params["wk"].astype(x.dtype)).reshape(B, n_heads, P)
    v = (inner @ params["wv"].astype(x.dtype)).reshape(B, n_heads, P)
    if_r = (inner.astype(jnp.float32) @ params["w_if"]
            + params["b_if"]).reshape(B, 2, n_heads)
    carry = (cache["C"], cache["n"], cache["m"])
    carry, h = mlstm_cell(carry, (q, k, v, if_r[:, 0], if_r[:, 1]))
    h = h.reshape(B, di)
    h = layernorm(params["gn"], h) * gate
    y = x + (h @ params["w_down"].astype(x.dtype))[:, None, :]
    return y, {"C": carry[0], "n": carry[1], "m": carry[2]}

"""Round-based federated training engines: FedAvg / FedProx base trainer.

Two feeding modes share one compiled round program:

  * pinned (default, small N): the padded per-client train/eval stacks are
    uploaded once at init and selection is a device gather — the fast path
    and the streamed path's equivalence oracle.
  * ``population=`` (``fed.population.Population``): the population stays
    host-resident in a ``fed.store.ClientStore`` and only the scheduled
    round cohort is streamed to device, double-buffered so the next
    cohort's H2D transfer overlaps the running round; evaluation streams
    fixed-size client blocks. Population size is then bounded by host
    memory (or disk, with memmapped shards) instead of device memory.

When more than one device is visible the round executor's client axis is
sharded over the mesh's data axes (``fed.parallel.make_sharded_executor``);
a single device gets the plain jit path, and a 2-D ``(data, model)`` mesh
(``launch.mesh.make_fed_mesh`` / ``REPRO_MODEL_AXIS``) additionally shards
the local solver's parameter dim over "model" — see docs/scaling.md.
Cohort *selection* draws from a
dedicated ``select_rng`` stream (distinct from the cold-start/ablation
``rng``), so a same-seed streamed population reproduces the pinned
trainer's selection sequence exactly.

``FedConfig.block_size > 1`` turns on *round-block execution* on the
pinned path: ``run()`` stages up to ``block_size`` upcoming cohorts (+
keys + zero-weight dropout padding) on the host — selection never depends
on device results — and dispatches them as ONE scan-fused program
(``fed.rounds.make_block_executor``) with the group state carried and
*donated* across rounds, fetching the stacked per-round metrics once per
block. Blocks break back to the per-round path on anything that needs the
host between rounds: group cold start, cold newcomers in a staged cohort,
or a streamed population (whose arrivals must be observed round by
round). ``FedConfig.eval_every`` sets the evaluation cadence on both
paths (1 = every round, the paper's tables; skipped rounds record NaN
accuracy, which ``History`` ignores).

``FedConfig.async_depth >= 1`` switches ``run()`` to the *asynchronous*
scheduler loop (``_run_async``): up to ``async_depth`` cohort dispatches
stay in flight at once and each completed dispatch is folded into the
live group state with FedAsync staleness weights α·(s+1)^(-β), where the
staleness s is counted per group (``ClientStateTable.init_group_version``
/ the pinned trainer's own clock). Every dispatch holds a *lease*: a
dispatch not ready by ``async_lease_timeout`` is abandoned and requeued
with capped exponential backoff (``async_backoff``/``async_backoff_cap``,
at most ``async_max_retries`` times), so a dead client or straggler trace
degrades throughput instead of stalling the loop. Degradation counters
(dispatches, folds, max in-flight depth, lease expiries, requeues, a
staleness histogram) surface in ``History.async_stats`` and — when
streaming — ``Population.stats``. The D=1 / weight-1.0 configuration is
the *equivalence mode*: bit-identical to the synchronous block (pinned)
and per-round (streamed) paths — tests/test_async.py holds all four
frameworks to it. See docs/architecture.md, "Async execution &
staleness".
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData
from repro.fed import client as client_lib
from repro.fed import leases as leases_lib
from repro.fed import parallel as parallel_lib
from repro.fed import rounds as rounds_lib
from repro.fed import server as server_lib
from repro.models.paper_models import ModelSpec
from repro.obs import telemetry as obs_lib

# the async runtime's lease record — shared with the coordinator/worker
# control plane (fed.leases generalizes what PR 7 built here)
_AsyncLease = leases_lib.Lease


@dataclass
class FedConfig:
    n_rounds: int = 50
    clients_per_round: int = 20          # K
    local_epochs: int = 20               # E
    batch_size: int = 10                 # B
    lr: float = 0.03
    mu: float = 0.0                      # FedProx proximal weight (0 = FedAvg)
    seed: int = 0
    # CFL knobs
    n_groups: int = 3                    # m
    pretrain_scale: int = 20             # alpha (pre-train alpha*m clients)
    eta_g: float = 0.0                   # inter-group aggregation lr
    measure: str = "edc"                 # edc | madc
    rcc: bool = False                    # ablation: random cluster centers
    rac: bool = False                    # ablation: randomly assign cold clients
    svd_iters: int = 4
    dropout_rate: float = 0.0            # per-round client drop probability
                                         # (network jitter, paper §3.3)
    eval_every: int = 1                  # evaluate every e-th round (1 =
                                         # every round, the paper's tables)
    block_size: int = 1                  # rounds fused per scan dispatch on
                                         # the pinned path (1 = per-round)
    # in-program update quarantine: screen non-finite / norm-outlier client
    # updates into the zero-weight path (fed.rounds) so poisoned payloads
    # never touch group params; counts surface in RoundMetrics.quarantined
    quarantine: bool = False
    quarantine_mult: float = 10.0        # outlier threshold: mult x median
                                         # cohort update norm
    # checkpoint/restore: every `checkpoint_every` completed rounds write an
    # atomic ckpt_<t>.npz into `checkpoint_dir` (0 / None = off); a fresh
    # same-config trainer resumes bit-identically via load_checkpoint()
    checkpoint_every: int = 0
    checkpoint_dir: str | None = None
    # retention: after a successful cadence write keep only the newest
    # `checkpoint_keep` ckpt_<t>.npz archives (0 = keep all); pruning is
    # atomic-after-write, so the latest checkpoint is never at risk
    checkpoint_keep: int = 0
    # asynchronous runtime (0 = synchronous): up to `async_depth` cohort
    # dispatches in flight, folded FIFO into the live group state with
    # FedAsync staleness weights alpha * (staleness + 1)^(-beta) — the
    # staleness counted per group. Depth 1 with the default alpha=1/beta=0
    # is the equivalence mode: weight 1.0 everywhere, bit-identical to the
    # synchronous paths.
    async_depth: int = 0
    async_alpha: float = 1.0
    async_beta: float = 0.0
    # cohort leases: a dispatch whose result is not ready within
    # `async_lease_timeout` seconds is abandoned and requeued with capped
    # exponential backoff; after `async_max_retries` requeues the run
    # raises (the cohort is unrecoverable, not merely slow)
    async_lease_timeout: float = 30.0
    async_max_retries: int = 3
    async_backoff: float = 0.05
    async_backoff_cap: float = 1.0
    # distribution-shift migration (FedGroup, FlexCFL-style): when set,
    # every `shift_check_every` rounds each assigned cohort client with a
    # cached eq.-9 direction is re-probed (one pre-training pass from the
    # current auxiliary model); cosine drift (1 - cos)/2 between the fresh
    # and cached directions beyond `shift_threshold` invalidates the cached
    # row and re-routes the client through eq. 9 — a migration, counted in
    # rounds.migrations. None (default) disables detection entirely and
    # preserves the static trainer's rng streams bit for bit.
    shift_threshold: float | None = None
    shift_check_every: int = 1
    # strategy-zoo knobs (fed.strategies): FedClust compares only the
    # trailing `fedclust_frac` of the flattened weights (the classifier
    # head in practice); LCFL keeps a client in its current group unless a
    # rival group's loss beats it by more than `lcfl_margin` (hysteresis)
    fedclust_frac: float = 0.25
    lcfl_margin: float = 0.1
    # telemetry (repro.obs): setting a directory enables span tracing and
    # streams per-round JSONL records + a Chrome trace + run_summary.json
    # there (docs/observability.md); None leaves the tracer a no-op
    telemetry_dir: str | None = None


@dataclass
class RoundMetrics:
    round: int
    weighted_acc: float
    mean_loss: float
    discrepancy: float
    quarantined: int = 0        # clients screened out by the update
                                # quarantine this round (0 when off)


@dataclass
class History:
    """Per-round metrics. Rounds skipped by the ``eval_every`` cadence
    record ``weighted_acc = nan``; the aggregates below ignore them (a NaN
    never satisfies ``>=``, and ``max_acc`` filters it explicitly).

    ``async_stats`` is the async runtime's degradation record (all-zero on
    synchronous runs): dispatches / folds / max_in_flight / lease_expiries
    / requeues counters plus ``staleness_hist``, a {max-staleness:
    fold-count} histogram. Inside a trainer it is a registry-backed view
    (``repro.obs.metrics``) over the ``async.*`` metrics — reads and
    writes land in the unified registry, whose snapshot rides checkpoint
    meta, so a resumed run reports totals consistent with an
    uninterrupted one."""

    rounds: list = field(default_factory=list)
    async_stats: dict = field(default_factory=dict)
    # engine hook fired on every add() — emits the per-round telemetry
    # record from whichever path (round / block / async fold) added it
    _on_add: object = field(default=None, repr=False, compare=False)

    def add(self, m: RoundMetrics):
        self.rounds.append(m)
        if self._on_add is not None:
            self._on_add(m)

    @property
    def max_acc(self) -> float:
        return max((r.weighted_acc for r in self.rounds
                    if not math.isnan(r.weighted_acc)), default=0.0)

    @property
    def total_quarantined(self) -> int:
        return sum(r.quarantined for r in self.rounds)

    def rounds_to_reach(self, target: float):
        for r in self.rounds:
            if r.weighted_acc >= target:
                return r.round
        return None


class FedAvgTrainer:
    """FedAvg (mu=0) / FedProx (mu>0) with a consensus global model."""

    framework = "fedavg"

    def __init__(self, model: ModelSpec, data: FederatedData | None,
                 cfg: FedConfig, mesh=None, population=None):
        self.model, self.cfg = model, cfg
        self.population = population
        self.rng = np.random.default_rng(cfg.seed)
        # cohort sampling draws from its own derived stream: the streamed
        # scheduler (same seed) replays the identical selection sequence,
        # and selection is decorrelated from the cold-start draws above
        from repro.fed.store import SELECT_STREAM
        self.select_rng = np.random.default_rng([cfg.seed, SELECT_STREAM])
        self.key = jax.random.PRNGKey(cfg.seed)
        if population is not None:
            self.data = data                    # optional at population scale
            self.n_clients = population.store.n_clients
            max_samples = population.store.max_train
        else:
            if data is None:
                raise ValueError("pass data= (pinned) or population=")
            self.data = data
            self.n_clients = data.n_clients
            max_samples = data.x_train.shape[1]
        self._max_samples = max_samples
        self.solver = client_lib.make_batch_solver(
            model, epochs=cfg.local_epochs, batch_size=cfg.batch_size,
            lr=cfg.lr, mu=cfg.mu, max_samples=max_samples)
        self.eval_fn = client_lib.make_eval_fn(model)
        self.params = model.init(jax.random.PRNGKey(cfg.seed + 1))
        self.history = History()
        from repro.models.modules import param_count
        self.model_size = param_count(self.params)
        self.comm_params = 0        # cumulative parameters transferred
        self._round_exec = None     # lazily-built single-dispatch round
        self._block_exec = None     # lazily-built scan-fused round block
        self._grouped_eval = None   # lazily-jitted fused grouped eval
        self._eval_zero_mem = None  # (N,) zeros for the consensus eval
        self._async_exec = None     # lazily-built async dispatch program
        self._async_fold_jit = None  # lazily-jitted staleness fold
        self.group_version = None   # (m,) per-group staleness clock (async)
        self._resumed = False       # load_checkpoint -> next run() keeps
                                    # restored Population.stats totals
        self._last_staleness = None  # last async fold's max staleness /
        self._last_weights = None    # per-group weights (round record)
        self._fold_alive = None     # alive cohort size of the fold being
                                    # recorded (rounds.empty_folds detector)
        # client axis sharded over "data" on multi-device (None = plain
        # jit); REPRO_MODEL_AXIS>1 auto-builds the 2-D (data, model) mesh
        self.mesh = parallel_lib.default_fed_mesh() if mesh is None else mesh
        if population is not None:
            population.attach(cfg, self.mesh)
            # one telemetry bundle per runtime: the population already owns
            # one (its degradation counters live there) — share it
            self.obs = population.obs
            self._train_stack = self._test_stack = None
        else:
            # pin the padded per-client stacks on device once — selection is
            # a device gather, not a fresh host->device upload every round
            self.obs = obs_lib.from_config(cfg)
            self._train_stack = tuple(jnp.asarray(a) for a in
                                      (data.x_train, data.y_train,
                                       data.n_train))
            self._test_stack = tuple(jnp.asarray(a) for a in
                                     (data.x_test, data.y_test, data.n_test))
        self._bind_history(self.history)

    def _bind_history(self, h: History):
        """Attach a History to the telemetry layer: ``async_stats`` becomes
        the registry-backed view and every add() emits the round record."""
        h.async_stats = self.obs.async_view()
        h._on_add = self._emit_round
        self.history = h

    # -- telemetry (repro.obs) ---------------------------------------------
    def _emit_round(self, m: RoundMetrics):
        """History.add hook: registry counters + the streamed JSONL round
        record. Record fields are deterministic functions of training state
        (never wall time), so the stream is bit-stable across
        kill-and-resume."""
        reg = self.obs.registry
        reg.inc("rounds.completed")
        if not math.isnan(m.weighted_acc):
            reg.inc("rounds.evals")
        if m.quarantined:
            reg.inc("rounds.quarantined", m.quarantined)
            if self._fold_alive is not None \
                    and m.quarantined >= self._fold_alive:
                # every alive cohort delta was screened: the in-program
                # zero-weight fold left the group params untouched (an
                # identity passthrough, never a 0/0) — count it
                reg.inc("rounds.empty_folds")
        self._fold_alive = None
        if self.obs.recording:
            self.obs.round_record(self._round_record(m))

    def _round_record(self, m: RoundMetrics) -> dict:
        rec = {"kind": "round", "t": m.round, "acc": m.weighted_acc,
               "loss": m.mean_loss, "disc": m.discrepancy,
               "quarantined": m.quarantined}
        if self.group_version is not None:
            rec["group_version"] = [int(v) for v in self.group_version]
        if self._last_staleness is not None:
            rec["staleness"] = self._last_staleness
            rec["weights"] = self._last_weights
            self._last_staleness = self._last_weights = None
        return rec

    def _summary_extra(self) -> dict:
        return {"framework": self.framework,
                "rounds": len(self.history.rounds),
                "max_acc": self.history.max_acc,
                "comm_params": int(self.comm_params)}

    # -- single-dispatch round executor ------------------------------------
    def _exec_spec(self) -> dict:
        """Executor grouping: the consensus trainers run the shared group
        round with a single group; FedGroup overrides with m + η_G,
        IFCA/FeSEM additionally install their assignment stage."""
        return {"n_groups": 1, "eta_g": 0.0}

    def _round_executor(self):
        if self._round_exec is None:
            cfg = self.cfg
            fn = rounds_lib.make_round_executor(
                self.model, epochs=cfg.local_epochs,
                batch_size=cfg.batch_size, lr=cfg.lr, mu=cfg.mu,
                max_samples=self._max_samples, quarantine=cfg.quarantine,
                quarantine_mult=cfg.quarantine_mult, **self._exec_spec())
            self._round_exec = self.obs.wrap(
                "dispatch", parallel_lib.make_sharded_executor(fn, self.mesh),
                exec="round")
        return self._round_exec

    # -- scan-fused round blocks -------------------------------------------
    def _block_kwargs(self) -> dict:
        """make_block_executor extras: the executor grouping plus the
        framework's carry<->assignment-state adapters (FeSEM overrides)."""
        return dict(self._exec_spec())

    def _block_executor(self):
        if self._block_exec is None:
            cfg = self.cfg
            fn = rounds_lib.make_block_executor(
                self.model, epochs=cfg.local_epochs,
                batch_size=cfg.batch_size, lr=cfg.lr, mu=cfg.mu,
                max_samples=self._max_samples, quarantine=cfg.quarantine,
                quarantine_mult=cfg.quarantine_mult, **self._block_kwargs())
            self._block_exec = self.obs.wrap(
                "dispatch",
                parallel_lib.make_sharded_block_executor(fn, self.mesh),
                exec="block")
        return self._block_exec

    def _host_round_pre(self) -> bool:
        """True when the NEXT round must run on the per-round path for
        host work that precedes selection (FedGroup: group cold start)."""
        return False

    def _needs_host(self, idx) -> bool:
        """True when the selected cohort needs host work before the round
        (FedGroup: cold newcomers routed through eq.-9)."""
        return False

    def _stage_comm(self, k: int):
        """Per-staged-round communication accounting (k = alive clients)."""
        self.comm_params += 2 * k * self.model_size

    def _stage_round(self, t: int, idx):
        """One staged round: cohort ids padded to K, solver keys (the
        alive prefix draws ``split(sk, k)`` — exactly the per-round draw),
        the zero-weight alive mask, and the eval-cadence flag."""
        K = min(self.cfg.clients_per_round, self.n_clients)
        self.key, sk = jax.random.split(self.key)
        k = len(idx)
        keys = np.asarray(jax.random.split(sk, k))
        idx = np.asarray(idx, np.int32)
        if k < K:
            idx = np.concatenate([idx, np.full(K - k, idx[0], np.int32)])
            keys = np.concatenate(
                [keys, np.zeros((K - k,) + keys.shape[1:], keys.dtype)])
        alive = np.zeros(K, np.float32)
        alive[:k] = 1.0
        self._stage_comm(k)
        return idx, keys, alive, self._should_eval(t)

    def _stage_block(self, t0: int, max_b: int):
        """Stage up to ``max_b`` upcoming rounds (selection + keys never
        depend on device results). Stops at the first round that needs the
        host; a cohort already drawn for that round is returned as
        ``pending`` so the per-round fallback consumes it without
        re-drawing (the rng streams stay identical to a per-round run)."""
        staged, pending = [], None
        with self.obs.span("stage", t=t0):
            for b in range(max_b):
                if self._host_round_pre():
                    break
                idx = self._select()
                if self._needs_host(idx):
                    pending = idx
                    break
                staged.append(self._stage_round(t0 + b, idx))
        return staged, pending

    # carry construction/teardown — overridden down the trainer hierarchy
    def _membership_host(self):
        return np.zeros(self.n_clients, np.int64)    # consensus: one group

    def _stacked_group_params(self):
        return jax.tree_util.tree_map(lambda p: p[None], self.params)

    def _carry_group_delta(self):
        m = self._exec_spec()["n_groups"]
        return jnp.zeros((m, self.model_size), jnp.float32)

    def _carry_aux(self):
        return None

    def _carry_in(self) -> dict:
        mem = np.append(self._membership_host(), -1).astype(np.int32)
        return dict(group_params=self._stacked_group_params(),
                    global_params=self.params,
                    group_delta=self._carry_group_delta(),
                    membership=jnp.asarray(mem), aux=self._carry_aux())

    def _carry_refs(self, carry: dict):
        """Cheap per-fold reference sync: point the trainer's model-state
        attributes at the (device) carry — no host fetch. The async loop
        calls this after every fold so host work between dispatches
        (FedGroup's eq.-9 cold start, streamed eval) sees current state;
        ``_carry_out`` adds the O(N) host membership fetch on top and runs
        only at block end / checkpoint / run end."""
        self.params = carry["global_params"]

    def _carry_out(self, carry: dict):
        self._carry_refs(carry)

    def _run_block(self, t0: int, staged):
        idx = jnp.asarray(np.stack([s[0] for s in staged]))
        keys = jnp.asarray(np.stack([s[1] for s in staged]))
        alive = jnp.asarray(np.stack([s[2] for s in staged]))
        do_eval = np.asarray([s[3] for s in staged], bool)
        carry, ys = self._block_executor()(
            self._carry_in(), self._train_stack, self._test_stack,
            idx, keys, alive, jnp.asarray(do_eval))
        self._carry_out(carry)
        # ONE device fetch for the whole block's stacked metrics
        mean_loss, disc, correct, total, n_quar = (np.asarray(v) for v in ys)
        for b in range(len(staged)):
            acc = (int(correct[b]) / max(int(total[b]), 1)
                   if do_eval[b] else float("nan"))
            self._fold_alive = int(staged[b][2].sum())
            self.history.add(RoundMetrics(t0 + b, acc, float(mean_loss[b]),
                                          float(disc[b]), int(n_quar[b])))

    # -- helpers -----------------------------------------------------------
    def _select(self):
        if self.population is not None:
            return self.population.next_cohort().idx
        idx = self.select_rng.choice(self.n_clients,
                                     min(self.cfg.clients_per_round,
                                         self.n_clients), replace=False)
        if self.cfg.dropout_rate > 0.0:
            # stragglers drop out before completing the round (the server
            # aggregates whoever finished within the time budget, Alg. 1)
            alive = self.select_rng.random(len(idx)) >= self.cfg.dropout_rate
            if not alive.any():
                alive[self.select_rng.integers(len(idx))] = True
            idx = idx[alive]
        return idx

    def _client_batch(self, idx):
        if self.population is not None:
            # the live cohort's prefetched device arrays (or a slice of
            # them, e.g. the cold-start subset); store gather otherwise
            return self.population.device_batch(idx)
        sel = jnp.asarray(np.asarray(idx, np.int32))
        x, y, n = self._train_stack
        return x[sel], y[sel], n[sel]

    def _solve(self, params, idx):
        x, y, n = self._client_batch(idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(idx))
        deltas, finals = self.solver(params, x, y, n, keys)
        return deltas, finals, n

    def _eval_correct(self, params, client_idx=None):
        """Streamed (population-mode) eval: (correct, total) accumulated
        over blocks of at most ``eval_batch`` clients — no full-population
        device allocation."""
        pop = self.population
        idx = pop.eval_ids() if client_idx is None else np.asarray(client_idx)
        if len(idx) == 0:
            return 0, 0
        correct = total = 0
        for block, x, y, n in pop.eval_batches(idx):
            c = self.eval_fn(params, x, y, n)
            correct += int(np.sum(np.asarray(c)))
            total += int(np.sum(np.asarray(n)))
        return correct, total

    def _should_eval(self, t: int) -> bool:
        e = self.cfg.eval_every
        return e <= 1 or (t + 1) % e == 0

    def _grouped_eval_fn(self):
        if self._grouped_eval is None:
            self._grouped_eval = jax.jit(
                client_lib.grouped_eval_correct(self.model))
        return self._grouped_eval

    def _fused_eval_acc(self, group_params, membership) -> float:
        """Pinned-path weighted accuracy as ONE dispatch regardless of m:
        integer correct/total counts from the fused grouped eval, divided
        on the host (the same division the block executor's stacked
        counts go through — bit-identical metrics)."""
        xt, yt, nt = self._test_stack
        c, tot = self._grouped_eval_fn()(group_params, membership,
                                         xt, yt, nt)
        return int(c) / max(int(tot), 1)

    def _round_eval(self, t: int) -> float:
        """The per-round training loop's evaluation hook (NaN off-cadence).
        The pinned consensus path goes through the fused grouped eval with
        m=1 so the per-round and block-executor paths run the identical
        eval program."""
        if not self._should_eval(t):
            return float("nan")
        with self.obs.span("eval", t=t):
            if self.population is not None:
                return self.evaluate()
            if self._eval_zero_mem is None:
                self._eval_zero_mem = jnp.zeros(self.n_clients, jnp.int32)
            return self._fused_eval_acc(
                jax.tree_util.tree_map(lambda p: p[None], self.params),
                self._eval_zero_mem)

    def evaluate(self, params=None, client_idx=None) -> float:
        params = self.params if params is None else params
        if self.population is not None:
            correct, total = self._eval_correct(params, client_idx)
            return correct / max(total, 1)
        d = self.data
        xt, yt, nt = self._test_stack
        if client_idx is None:
            idx = np.arange(d.n_clients)
        else:
            idx = np.asarray(client_idx)
            if len(idx) == 0:
                return 0.0
            sel = jnp.asarray(idx.astype(np.int32))
            xt, yt, nt = xt[sel], yt[sel], nt[sel]
        correct = self.eval_fn(params, xt, yt, nt)
        total = d.n_test[idx].sum()
        return float(np.sum(np.asarray(correct)) / max(total, 1))

    # -- main loop ---------------------------------------------------------
    def round(self, t: int, idx=None) -> RoundMetrics:
        if idx is None:
            idx = self._select()
        x, y, n = self._client_batch(idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(idx))
        # downlink: 1 model per client; uplink: 1 update per client
        self.comm_params += 2 * len(idx) * self.model_size
        out = self._round_executor()(
            jax.tree_util.tree_map(lambda p: p[None], self.params),
            jnp.zeros(len(idx), jnp.int32), x, y, n, keys)
        self.params = out.global_params
        acc = self._round_eval(t)
        self._fold_alive = len(idx)
        m = RoundMetrics(t, acc, float(out.mean_loss), float(out.discrepancy),
                         int(out.n_quarantined))
        self.history.add(m)
        return m

    def run(self, n_rounds=None) -> History:
        """The block-scheduling loop. With ``block_size > 1`` on the pinned
        path, upcoming rounds are staged on the host and dispatched as one
        scan-fused block; anything that needs the host between rounds —
        group cold start, cold newcomers in a staged cohort, a streamed
        population — breaks back to the per-round path (a cohort already
        drawn for the breaking round is carried over as ``pending``, so
        the rng streams match a pure per-round run exactly).

        Runs ``n_rounds`` MORE rounds, labelled from the current history
        length — so repeated calls keep training forward, and a trainer
        restored via ``load_checkpoint`` continues with the absolute round
        labels (and eval/checkpoint cadence) of the uninterrupted run.
        With ``checkpoint_every``/``checkpoint_dir`` set, an atomic
        snapshot lands every time a multiple of ``checkpoint_every``
        completed rounds is crossed."""
        if self.population is not None:
            if self._resumed:
                self._resumed = False    # keep the restored stats totals
            else:
                self.population.reset_stats()
        t0 = len(self.history.rounds)
        total = t0 + (n_rounds or self.cfg.n_rounds)
        if self.cfg.async_depth >= 1:
            h = self._run_async(t0, total)
            self.obs.finalize(self._summary_extra())
            return h
        blocks = self.cfg.block_size > 1 and (
            self.population is None or
            getattr(self.population, "block_stageable", False))
        t, pending = t0, None
        while t < total:
            prev = t
            if pending is not None:
                self.round(t, idx=pending)
                pending = None
                t += 1
            elif not blocks or total - t < 2:
                self.round(t)
                t += 1
            else:
                staged, pending = self._stage_block(
                    t, min(self.cfg.block_size, total - t))
                if staged:
                    self._run_block(t, staged)
                    t += len(staged)
                elif pending is None:
                    self.round(t)
                    t += 1
            self._maybe_checkpoint(prev, t)
        self.obs.finalize(self._summary_extra())
        return self.history

    # -- asynchronous runtime (FedConfig.async_depth >= 1) -------------------
    def _group_version(self):
        """The (m,) int64 per-group staleness clock: version[g] increments
        every time a fold lands clients in group g, and a dispatch's
        staleness is the clock gap between its dispatch and its fold.
        Shared by reference with the population's state table when
        streaming (like membership), trainer-owned when pinned."""
        if self.group_version is None:
            m = self._exec_spec()["n_groups"]
            if self.population is not None:
                self.group_version = \
                    self.population.state.init_group_version(m)
            else:
                self.group_version = np.zeros(m, np.int64)
        return self.group_version

    def _async_executor(self):
        """Pinned-path async dispatch program: exactly one block-executor
        scan step (same round core, same in-program gather and trash-row
        scatter, no in-program eval — the loop evaluates at fold time),
        compiled WITHOUT carry donation: the snapshot carry is shared with
        the live state and every other in-flight dispatch."""
        if self._async_exec is None:
            cfg = self.cfg
            fn = rounds_lib.make_async_dispatch_executor(
                self.model, epochs=cfg.local_epochs,
                batch_size=cfg.batch_size, lr=cfg.lr, mu=cfg.mu,
                max_samples=self._max_samples, quarantine=cfg.quarantine,
                quarantine_mult=cfg.quarantine_mult, **self._block_kwargs())
            self._async_exec = self.obs.wrap(
                "dispatch",
                parallel_lib.make_async_dispatch_executor(fn, self.mesh),
                exec="async")
        return self._async_exec

    def _async_fold(self):
        """The staleness fold, jitted with the current state and the
        dispatch result both donated (``fed.parallel.make_async_fold``):
        the full-carry fold when pinned, the group-params-only fold when
        streamed (membership and FeSEM rows stay host-resident there)."""
        if self._async_fold_jit is None:
            fold = (rounds_lib.make_staleness_fold()
                    if self.population is None
                    else rounds_lib.make_param_fold())
            self._async_fold_jit = parallel_lib.make_async_fold(fold)
        return self._async_fold_jit

    def _async_host_pre(self):
        """Host work that must precede async staging (FedGroup: the Alg. 3
        group cold start before the first cohort is drawn)."""

    def _async_cold(self, idx) -> np.ndarray:
        """Stage-time cold-newcomer hook; returns the cold client ids so
        the pinned loop can patch their rows into the device carry
        (FedGroup overrides with the eq.-9 client cold start)."""
        return np.empty(0, np.int64)

    def _async_stream_arg(self, idx):
        """The streamed round executor's assignment argument, built exactly
        as the trainer's synchronous ``round()`` builds it."""
        return jnp.zeros(len(idx), jnp.int32)

    def _async_adopt(self, out, idx, folded_groups, folded_global):
        """Adopt a folded *streamed* dispatch — mirrors each trainer's
        synchronous ``round()`` adoption, so the weight-1.0 fold (a
        bitwise passthrough of the dispatch result) reproduces it
        exactly."""
        self.params = folded_global

    def _stage_async(self, t: int):
        """Stage one cohort for async dispatch: host-pre hook, selection,
        cold-newcomer handling, solver keys and communication accounting —
        the same host sequence (and the same rng draw order) as the
        synchronous paths. Returns ``(cold_ids, staged_inputs)``; the
        staged inputs are kept device-resident so an expired lease can
        re-dispatch them against the then-current state."""
        with self.obs.span("stage", t=t):
            self._async_host_pre()
            idx = self._select()
            cold = np.asarray(self._async_cold(idx))
            if self.population is None:
                idx_p, keys, alive, _ = self._stage_round(t, idx)
                return cold, (jnp.asarray(idx_p), jnp.asarray(keys),
                              jnp.asarray(alive))
            x, y, n = self._client_batch(idx)
            self.key, sk = jax.random.split(self.key)
            keys = jax.random.split(sk, len(idx))
            self._stage_comm(len(idx))
            return cold, (np.asarray(idx), x, y, n, keys,
                          self._async_stream_arg(idx))

    def _lease_ready(self, leaves) -> bool:
        """True when every device buffer of a lease's result is computed
        (tests monkeypatch this to script lease expiries)."""
        return all(l.is_ready() for l in leaves)

    def _wait_ready(self, lease: _AsyncLease) -> bool:
        """Poll a lease's result until ready or the deadline passes, the
        poll interval backing off exponentially. Readiness is checked
        before the deadline, so an already-computed result is never
        expired."""
        leaves = [l for l in jax.tree_util.tree_leaves(
            (lease.result, lease.metrics)) if hasattr(l, "is_ready")]
        pause = 1e-4
        while True:
            if self._lease_ready(leaves):
                return True
            if time.monotonic() >= lease.deadline:
                return False
            time.sleep(pause)
            pause = min(pause * 2.0, 0.005)

    def _run_async(self, t0: int, total: int) -> History:
        """The asynchronous scheduler loop: keep up to ``async_depth``
        cohort dispatches in flight against the live state, fold completed
        dispatches FIFO with per-group staleness weights, requeue expired
        leases with capped exponential backoff.

        Fold order defines the round index — a requeued cohort folds later
        and becomes a later round, exactly as an asynchronous server
        accounts a late client — and the eval / checkpoint cadence is
        evaluated at fold time. A checkpoint cadence crossing first drains
        the in-flight window to quiescence: the snapshot then carries no
        outstanding leases (the staleness clocks, counters and rng streams
        capture everything else), and a killed-and-resumed run re-stages
        bit-identically what the uninterrupted run staged after its own
        drain. Folds are FIFO rather than completion-order: on a device
        stream dispatches execute in enqueue order anyway, so FIFO loses
        no overlap and keeps the fold sequence deterministic."""
        cfg = self.cfg
        pop = self.population
        pinned = pop is None
        depth = max(1, int(cfg.async_depth))
        ver = self._group_version()
        # registry-backed view (repro.obs.metrics): the async.* schema
        # pre-seeds every counter, and the histogram dict is live — the
        # in-place bucket bumps below land in the registry
        st = self.history.async_stats
        shist = st["staleness_hist"]
        self._async_host_pre()
        carry = self._carry_in() if pinned else None
        exec_ = self._async_executor() if pinned else self._round_executor()
        fold = self._async_fold()
        policy = leases_lib.RetryPolicy(
            cfg.async_lease_timeout, cfg.async_max_retries,
            cfg.async_backoff, cfg.async_backoff_cap)
        pending = []                 # in-flight leases, FIFO fold order
        requeued = leases_lib.RequeueBuffer()  # expired, backing off
        t_stage = t0                 # cohorts staged so far
        t_fold = t0                  # rounds folded so far

        def dispatch(staged, attempts):
            if pinned:
                idx_d, keys_d, alive_d = staged
                result, mets = exec_(carry, self._train_stack,
                                     idx_d, keys_d, alive_d)
            else:
                result = exec_(self._stacked_group_params(), staged[5],
                               staged[1], staged[2], staged[3], staged[4])
                mets = None
            pending.append(_AsyncLease(
                staged, ver.copy(), result, mets,
                time.monotonic() + cfg.async_lease_timeout, attempts))
            st["dispatches"] += 1
            st["max_in_flight"] = max(st["max_in_flight"], len(pending))

        def fill(fresh):
            nonlocal t_stage, carry
            while len(pending) < depth:
                now = time.monotonic()
                ready = requeued.pop_ready(now)
                if ready is not None:
                    staged, attempts = ready
                    dispatch(staged, attempts)
                elif fresh and t_stage < total:
                    cold, staged = self._stage_async(t_stage)
                    if pinned and len(cold):
                        # the eq.-9 assignments happened on the host —
                        # patch the newcomers' rows into the device carry
                        # (a new membership array; in-flight dispatches
                        # keep the snapshot they were enqueued against)
                        carry = dict(
                            carry,
                            membership=carry["membership"]
                            .at[jnp.asarray(cold, jnp.int32)].set(
                                jnp.asarray(self.membership[cold],
                                            jnp.int32)))
                    dispatch(staged, 0)
                    t_stage += 1
                elif requeued and not pending:
                    # nothing in flight and every lease is backing off:
                    # sleep to the earliest retry instead of spinning
                    time.sleep(max(0.0, requeued.earliest()
                                   - time.monotonic()))
                else:
                    break

        def fold_one(lease):
            nonlocal carry, t_fold
            t = t_fold
            with self.obs.span("fold", t=t):
                s = (ver - lease.version).astype(np.int64)
                w = rounds_lib.staleness_weight(
                    s, alpha=cfg.async_alpha, beta=cfg.async_beta)
                key = str(int(s.max()) if s.size else 0)
                shist[key] = shist.get(key, 0) + 1
                if self.obs.recording:
                    self._last_staleness = int(s.max()) if s.size else 0
                    self._last_weights = [float(v)
                                          for v in np.asarray(w).ravel()]
                if pinned:
                    idx_d, _, alive_d = lease.staged
                    carry = fold(carry, lease.result, idx_d, alive_d,
                                 jnp.asarray(w))
                    self._carry_refs(carry)
                    mean_loss, disc, n_quar, mem = (np.asarray(v)
                                                    for v in lease.metrics)
                    alive_h = np.asarray(alive_d)
                    self._fold_alive = int(alive_h.sum())
                    occupied = np.unique(mem[alive_h > 0])
                    if self._should_eval(t):
                        with self.obs.span("eval", t=t):
                            acc = self._fused_eval_acc(
                                carry["group_params"],
                                carry["membership"][:-1])
                    else:
                        acc = float("nan")
                else:
                    out = lease.result
                    groups, glob = fold(self._stacked_group_params(),
                                        out.group_params, out.global_params,
                                        jnp.asarray(w))
                    self._async_adopt(out, lease.staged[0], groups, glob)
                    self._fold_alive = int(len(lease.staged[0]))
                    occupied = np.unique(np.asarray(out.membership))
                    mean_loss, disc, n_quar = (out.mean_loss,
                                               out.discrepancy,
                                               out.n_quarantined)
                    acc = self._round_eval(t)
                ver[occupied] += 1
                st["folds"] += 1
                self.history.add(RoundMetrics(t, acc, float(mean_loss),
                                              float(disc), int(n_quar)))
            t_fold += 1

        def harvest():
            """Fold the FIFO head if it completes within its lease,
            abandon + requeue it with capped backoff otherwise."""
            lease = pending.pop(0)
            if self._wait_ready(lease):
                fold_one(lease)
                return True
            st["lease_expiries"] += 1
            if pop is not None:
                pop.stats["lease_expiries"] += 1
            requeued.push(lease, policy, time.monotonic())
            st["requeues"] += 1
            if pop is not None:
                pop.stats["requeues"] += 1
            return False

        while t_fold < total:
            fill(fresh=True)
            prev = t_fold
            if pending and harvest():
                e = cfg.checkpoint_every
                if e > 0 and cfg.checkpoint_dir and t_fold // e > prev // e:
                    # drain to quiescence before snapshotting — a
                    # checkpoint never carries an outstanding lease
                    while pending or requeued:
                        fill(fresh=False)
                        if pending:
                            harvest()
                    if pinned:
                        self._carry_out(carry)
                    self.save_checkpoint()
        if pinned:
            self._carry_out(carry)
        if pop is not None:
            pop.stats["writer_retries"] = pop._writer.retries
        return self.history

    # -- checkpoint/restore ------------------------------------------------
    def _maybe_checkpoint(self, prev_t: int, t: int):
        e = self.cfg.checkpoint_every
        if e > 0 and self.cfg.checkpoint_dir and t // e > prev_t // e:
            self.save_checkpoint()

    def _ckpt_model_tree(self) -> dict:
        """The device/model state a checkpoint must capture. Doubles as the
        ``load_pytree`` template: a fresh same-config trainer's live arrays
        have exactly the checkpointed shapes/dtypes."""
        return {"params": self.params, "key": self.key}

    def _ckpt_load_model(self, tree: dict):
        self.params = tree["params"]
        self.key = tree["key"]

    def _ckpt_meta_extra(self) -> dict:
        """Framework-specific JSON-able scalars (FedGroup: cold-start
        flags)."""
        return {}

    def _ckpt_apply_extra(self, extra: dict):
        pass

    def _ckpt_state_arrays(self) -> dict:
        """Framework-owned host arrays of *save-time* shape, merged into
        the checkpoint's ``state`` sub-tree next to the population tables
        (FedGroup: the pinned-mode eq.-9 direction cache). Keys must not
        collide with ``Population.ckpt_state``'s; the load template is
        archive-driven, so variable row counts are fine."""
        return {}

    def _ckpt_apply_state(self, arrays: dict):
        """Restore hook for ``_ckpt_state_arrays`` (receives the full
        ``state`` sub-tree; pick out the framework's own keys)."""
        pass

    def save_checkpoint(self, path: str | None = None) -> str:
        """Atomic full-state snapshot after ``len(history.rounds)``
        completed rounds: model/group state + both rng streams + metrics +
        comm accounting, and (when streaming) the population's scheduler
        stream and state table. ``load_checkpoint`` on a fresh same-config
        trainer resumes bit-identically."""
        from repro.checkpoint import io as ckpt_io
        t = len(self.history.rounds)
        if path is None:
            if not self.cfg.checkpoint_dir:
                raise ValueError("pass a path or set FedConfig"
                                 ".checkpoint_dir")
            path = ckpt_io.checkpoint_path(self.cfg.checkpoint_dir, t)
        # counted before the snapshot so the checkpoint's own registry
        # capture includes itself — resumed totals match uninterrupted ones
        self.obs.registry.inc("rounds.checkpoints")
        with self.obs.span("checkpoint", t=t):
            state, pop_meta = {}, None
            if self.population is not None:
                # drains the writer and syncs writer_retries into the
                # registry BEFORE the snapshot below — every degradation
                # counter reaches the checkpoint through one surface
                state, pop_meta = self.population.ckpt_state()
            state = dict(state, **self._ckpt_state_arrays())
            meta = {"framework": self.framework, "t": t,
                    "n_clients": int(self.n_clients),
                    "rng": self.rng.bit_generator.state,
                    "select_rng": self.select_rng.bit_generator.state,
                    "comm_params": int(self.comm_params),
                    "history": [[r.round, r.weighted_acc, r.mean_loss,
                                 r.discrepancy, r.quarantined]
                                for r in self.history.rounds],
                    "extra": self._ckpt_meta_extra(),
                    # async runtime state: the per-group staleness clocks
                    # (leases themselves never reach a checkpoint — the
                    # async loop drains to quiescence first)
                    "group_version": ([int(v) for v in self.group_version]
                                      if self.group_version is not None
                                      else None),
                    # the unified registry snapshot: async.* degradation
                    # counters, pop.* robustness counters, rounds.* series
                    # — one consistent mid-run capture (format v3)
                    "obs": self.obs.registry.snapshot(),
                    # fleet metadata (ckpt format v4): the coordinator's
                    # control-plane snapshot when a launch.Coordinator owns
                    # this trainer, None on single-process runs
                    "fleet": self._fleet_meta(),
                    "population": pop_meta}
            ckpt_io.save_pytree(path, {"model": self._ckpt_model_tree(),
                                       "state": state}, meta)
        if self.cfg.checkpoint_keep > 0 and self.cfg.checkpoint_dir:
            # retention AFTER the successful atomic write: the archive just
            # written is the newest, so it always survives the prune
            ckpt_io.prune_checkpoints(self.cfg.checkpoint_dir,
                                      self.cfg.checkpoint_keep)
        return path

    def _fleet_meta(self):
        """Checkpoint meta hook: the owning coordinator's control-plane
        snapshot (``launch.coordinator`` overrides this on its trainer);
        None on single-process runs."""
        return None

    def load_checkpoint(self, path_or_dir: str) -> int:
        """Restore a ``save_checkpoint`` snapshot into this trainer (fresh,
        same config, same population construction). Accepts a checkpoint
        file or a directory (picks the latest ``ckpt_*.npz`` — the
        kill-and-resume entry point). Returns the completed-round count;
        ``run(n)`` then continues exactly where the killed run left off."""
        from repro.checkpoint import io as ckpt_io
        path = path_or_dir
        if os.path.isdir(path):
            path = ckpt_io.latest_checkpoint(path)
            if path is None:
                raise FileNotFoundError(
                    f"no ckpt_*.npz checkpoints in {path_or_dir}")
        if self.history.rounds:
            raise RuntimeError("load_checkpoint needs a fresh trainer — "
                               "this one has already trained")
        meta = ckpt_io.load_metadata(path)
        if meta["framework"] != self.framework:
            raise ValueError(
                f"checkpoint was written by framework "
                f"{meta['framework']!r}, this trainer is {self.framework!r}")
        if int(meta["n_clients"]) != self.n_clients:
            raise ValueError(
                f"checkpoint population has {meta['n_clients']} clients, "
                f"this trainer has {self.n_clients}")
        if meta["population"] is not None and self.population is None:
            raise ValueError("checkpoint came from a streamed-population "
                             "run — construct the trainer with the same "
                             "population")
        # the model sub-tree's template is the live (fresh) trainer state;
        # the population sub-tree's row counts are only known at save time,
        # so its template comes from the archive's own specs
        state_tmpl = {
            k[len("state/"):]: np.zeros(shape, dtype)
            for k, (shape, dtype) in ckpt_io.saved_array_specs(path).items()
            if k.startswith("state/")}
        tree = ckpt_io.load_pytree(
            path, {"model": self._ckpt_model_tree(), "state": state_tmpl})
        self._ckpt_load_model(tree["model"])
        self._ckpt_apply_extra(meta.get("extra") or {})
        self.rng.bit_generator.state = meta["rng"]
        self.select_rng.bit_generator.state = meta["select_rng"]
        self.comm_params = int(meta["comm_params"])
        self._bind_history(History(
            [RoundMetrics(int(r[0]), float(r[1]), float(r[2]), float(r[3]),
                          int(r[4])) for r in meta["history"]]))
        gv = meta.get("group_version")
        if gv is not None:
            self._group_version()[:] = np.asarray(gv, np.int64)
        if self.population is not None:
            if meta["population"] is None:
                raise ValueError("checkpoint came from a pinned run — "
                                 "construct the trainer without population")
            self.population.ckpt_restore(
                {k: np.asarray(v) for k, v in tree["state"].items()},
                meta["population"])
        self._ckpt_apply_state(
            {k: np.asarray(v) for k, v in tree["state"].items()})
        # cumulative counters come back through the unified registry
        # snapshot (format v3); pre-v3 archives carried only async_stats
        obs_snap = meta.get("obs")
        if obs_snap is None and meta.get("async_stats"):
            obs_snap = {f"async.{k}": v
                        for k, v in meta["async_stats"].items()}
        self.obs.registry.restore(obs_snap or {})
        # drop streamed round records at/after the resume point — the
        # resumed run re-emits them, so the JSONL stream stays free of
        # duplicates and byte-identical to an uninterrupted run's
        self.obs.resume_at(int(meta["t"]))
        self._resumed = True
        return int(meta["t"])

    def close(self):
        """Stop the population prefetch thread (no-op in pinned mode) and
        finalize the telemetry artifacts (trace.json / run_summary.json)."""
        if self.population is not None:
            self.population.close()
        self.obs.finalize(self._summary_extra())


class FedProxTrainer(FedAvgTrainer):
    framework = "fedprox"

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        if cfg.mu <= 0:
            cfg = dataclasses.replace(cfg, mu=0.01)
        super().__init__(model, data, cfg, mesh=mesh, population=population)


class GroupedTrainer(FedAvgTrainer):
    """Shared machinery for the clustered trainers (FedGroup, IFCA, FeSEM):
    m group models kept as an m-stacked pytree, per-client membership
    bookkeeping, and group-wise weighted-accuracy evaluation."""

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        super().__init__(model, data, cfg, mesh=mesh, population=population)
        self.m = cfg.n_groups
        self._mig_last = None       # cohort membership flips last round
        if population is not None:
            # membership IS the persistent state table's column, so the
            # trainers' in-place writes survive across cohorts/restarts
            self.membership = population.state.membership
        else:
            self.membership = np.full(self.n_clients, -1, np.int64)

    def _adopt_membership(self, idx, new):
        """Write a cohort's new group assignments into the membership
        column, counting migrations (previously-assigned clients switching
        groups — FlexCFL's core drift signal) into the registry."""
        new = np.asarray(new)
        old = self.membership[idx]
        mig = int(np.sum((old >= 0) & (old != new)))
        self._mig_last = mig
        if mig:
            self.obs.registry.inc("rounds.migrations", mig)
        with self.obs.span("state-write", rows=int(len(new))):
            self.membership[idx] = new

    def _round_record(self, m: RoundMetrics) -> dict:
        rec = super()._round_record(m)
        mem = self.membership
        sizes = np.bincount(mem[mem >= 0].astype(np.int64), minlength=self.m)
        rec["group_sizes"] = [int(v) for v in sizes[:self.m]]
        if self._mig_last is not None:
            rec["migrations"] = self._mig_last
            self._mig_last = None
        return rec

    def group_param(self, j: int):
        """The j-th group's parameter pytree (view into the stacked state)."""
        return server_lib.tree_index(self.group_params, j)

    def evaluate_groups(self) -> float:
        """Weighted accuracy: each group model on the test data of all
        clients historically assigned to it (paper §5.1 metric). On the
        pinned path this is ONE fused dispatch regardless of m
        (``fed.client.grouped_eval_correct``); the streamed population
        keeps the per-group blocked eval loop (it cannot pin the test
        stacks)."""
        if self.population is not None:
            eval_ids = self.population.eval_ids()
            mem = self.membership[eval_ids]
            total_correct, total_n = 0, 0
            for j in range(self.m):
                members = eval_ids[mem == j]
                if len(members) == 0:
                    continue
                c, tot = self._eval_correct(self.group_param(j), members)
                total_correct += c
                total_n += tot
            return total_correct / max(total_n, 1)
        return self._fused_eval_acc(
            self.group_params, jnp.asarray(self.membership.astype(np.int32)))

    def _round_eval(self, t: int) -> float:
        if not self._should_eval(t):
            return float("nan")
        with self.obs.span("eval", t=t):
            return self.evaluate_groups()

    # -- round-block carry: m-stacked groups + membership ------------------
    def _membership_host(self):
        return self.membership

    def _stacked_group_params(self):
        return self.group_params

    def _carry_refs(self, carry: dict):
        super()._carry_refs(carry)
        self.group_params = carry["group_params"]

    def _carry_out(self, carry: dict):
        self._carry_refs(carry)
        self.membership[:] = np.asarray(
            carry["membership"])[:-1].astype(self.membership.dtype)

    def _async_adopt(self, out, idx, folded_groups, folded_global):
        # the grouped (IFCA-shaped) adoption: group models + the cohort's
        # membership writes; the consensus params stay untouched, exactly
        # as the synchronous round() leaves them
        self.group_params = folded_groups
        self._adopt_membership(idx, out.membership)

    # -- checkpointing: m-stacked groups + membership ----------------------
    def _ckpt_model_tree(self) -> dict:
        tree = super()._ckpt_model_tree()
        tree["group_params"] = self.group_params
        tree["membership"] = np.asarray(self.membership)
        return tree

    def _ckpt_load_model(self, tree: dict):
        super()._ckpt_load_model(tree)
        self.group_params = tree["group_params"]
        # in place: population mode shares this array with the state table
        self.membership[:] = np.asarray(
            tree["membership"]).astype(self.membership.dtype)

"""Round-based federated training engines: FedAvg / FedProx base trainer.

Two feeding modes share one compiled round program:

  * pinned (default, small N): the padded per-client train/eval stacks are
    uploaded once at init and selection is a device gather — the fast path
    and the streamed path's equivalence oracle.
  * ``population=`` (``fed.population.Population``): the population stays
    host-resident in a ``fed.store.ClientStore`` and only the scheduled
    round cohort is streamed to device, double-buffered so the next
    cohort's H2D transfer overlaps the running round; evaluation streams
    fixed-size client blocks. Population size is then bounded by host
    memory (or disk, with memmapped shards) instead of device memory.

When more than one device is visible the round executor's client axis is
sharded over the mesh's data axes (``fed.parallel.make_sharded_executor``);
a single device gets the plain jit path, and a 2-D ``(data, model)`` mesh
(``launch.mesh.make_fed_mesh`` / ``REPRO_MODEL_AXIS``) additionally shards
the local solver's parameter dim over "model" — see docs/scaling.md.
Cohort *selection* draws from a
dedicated ``select_rng`` stream (distinct from the cold-start/ablation
``rng``), so a same-seed streamed population reproduces the pinned
trainer's selection sequence exactly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData
from repro.fed import client as client_lib
from repro.fed import parallel as parallel_lib
from repro.fed import rounds as rounds_lib
from repro.fed import server as server_lib
from repro.models.paper_models import ModelSpec


@dataclass
class FedConfig:
    n_rounds: int = 50
    clients_per_round: int = 20          # K
    local_epochs: int = 20               # E
    batch_size: int = 10                 # B
    lr: float = 0.03
    mu: float = 0.0                      # FedProx proximal weight (0 = FedAvg)
    seed: int = 0
    # CFL knobs
    n_groups: int = 3                    # m
    pretrain_scale: int = 20             # alpha (pre-train alpha*m clients)
    eta_g: float = 0.0                   # inter-group aggregation lr
    measure: str = "edc"                 # edc | madc
    rcc: bool = False                    # ablation: random cluster centers
    rac: bool = False                    # ablation: randomly assign cold clients
    svd_iters: int = 4
    dropout_rate: float = 0.0            # per-round client drop probability
                                         # (network jitter, paper §3.3)


@dataclass
class RoundMetrics:
    round: int
    weighted_acc: float
    mean_loss: float
    discrepancy: float


@dataclass
class History:
    rounds: list = field(default_factory=list)

    def add(self, m: RoundMetrics):
        self.rounds.append(m)

    @property
    def max_acc(self) -> float:
        return max((r.weighted_acc for r in self.rounds), default=0.0)

    def rounds_to_reach(self, target: float):
        for r in self.rounds:
            if r.weighted_acc >= target:
                return r.round
        return None


class FedAvgTrainer:
    """FedAvg (mu=0) / FedProx (mu>0) with a consensus global model."""

    framework = "fedavg"

    def __init__(self, model: ModelSpec, data: FederatedData | None,
                 cfg: FedConfig, mesh=None, population=None):
        self.model, self.cfg = model, cfg
        self.population = population
        self.rng = np.random.default_rng(cfg.seed)
        # cohort sampling draws from its own derived stream: the streamed
        # scheduler (same seed) replays the identical selection sequence,
        # and selection is decorrelated from the cold-start draws above
        from repro.fed.store import SELECT_STREAM
        self.select_rng = np.random.default_rng([cfg.seed, SELECT_STREAM])
        self.key = jax.random.PRNGKey(cfg.seed)
        if population is not None:
            self.data = data                    # optional at population scale
            self.n_clients = population.store.n_clients
            max_samples = population.store.max_train
        else:
            if data is None:
                raise ValueError("pass data= (pinned) or population=")
            self.data = data
            self.n_clients = data.n_clients
            max_samples = data.x_train.shape[1]
        self._max_samples = max_samples
        self.solver = client_lib.make_batch_solver(
            model, epochs=cfg.local_epochs, batch_size=cfg.batch_size,
            lr=cfg.lr, mu=cfg.mu, max_samples=max_samples)
        self.eval_fn = client_lib.make_eval_fn(model)
        self.params = model.init(jax.random.PRNGKey(cfg.seed + 1))
        self.history = History()
        from repro.models.modules import param_count
        self.model_size = param_count(self.params)
        self.comm_params = 0        # cumulative parameters transferred
        self._round_exec = None     # lazily-built single-dispatch round
        # client axis sharded over "data" on multi-device (None = plain
        # jit); REPRO_MODEL_AXIS>1 auto-builds the 2-D (data, model) mesh
        self.mesh = parallel_lib.default_fed_mesh() if mesh is None else mesh
        if population is not None:
            population.attach(cfg, self.mesh)
            self._train_stack = self._test_stack = None
        else:
            # pin the padded per-client stacks on device once — selection is
            # a device gather, not a fresh host->device upload every round
            self._train_stack = tuple(jnp.asarray(a) for a in
                                      (data.x_train, data.y_train,
                                       data.n_train))
            self._test_stack = tuple(jnp.asarray(a) for a in
                                     (data.x_test, data.y_test, data.n_test))

    # -- single-dispatch round executor ------------------------------------
    def _exec_spec(self) -> dict:
        """Executor grouping: the consensus trainers run the shared group
        round with a single group; FedGroup overrides with m + η_G,
        IFCA/FeSEM additionally install their assignment stage."""
        return {"n_groups": 1, "eta_g": 0.0}

    def _round_executor(self):
        if self._round_exec is None:
            cfg = self.cfg
            fn = rounds_lib.make_round_executor(
                self.model, epochs=cfg.local_epochs,
                batch_size=cfg.batch_size, lr=cfg.lr, mu=cfg.mu,
                max_samples=self._max_samples, **self._exec_spec())
            self._round_exec = parallel_lib.make_sharded_executor(
                fn, self.mesh)
        return self._round_exec

    # -- helpers -----------------------------------------------------------
    def _select(self):
        if self.population is not None:
            return self.population.next_cohort().idx
        idx = self.select_rng.choice(self.n_clients,
                                     min(self.cfg.clients_per_round,
                                         self.n_clients), replace=False)
        if self.cfg.dropout_rate > 0.0:
            # stragglers drop out before completing the round (the server
            # aggregates whoever finished within the time budget, Alg. 1)
            alive = self.select_rng.random(len(idx)) >= self.cfg.dropout_rate
            if not alive.any():
                alive[self.select_rng.integers(len(idx))] = True
            idx = idx[alive]
        return idx

    def _client_batch(self, idx):
        if self.population is not None:
            # the live cohort's prefetched device arrays (or a slice of
            # them, e.g. the cold-start subset); store gather otherwise
            return self.population.device_batch(idx)
        sel = jnp.asarray(np.asarray(idx, np.int32))
        x, y, n = self._train_stack
        return x[sel], y[sel], n[sel]

    def _solve(self, params, idx):
        x, y, n = self._client_batch(idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(idx))
        deltas, finals = self.solver(params, x, y, n, keys)
        return deltas, finals, n

    def _eval_correct(self, params, client_idx=None):
        """Streamed (population-mode) eval: (correct, total) accumulated
        over blocks of at most ``eval_batch`` clients — no full-population
        device allocation."""
        pop = self.population
        idx = pop.eval_ids() if client_idx is None else np.asarray(client_idx)
        if len(idx) == 0:
            return 0, 0
        correct = total = 0
        for block, x, y, n in pop.eval_batches(idx):
            c = self.eval_fn(params, x, y, n)
            correct += int(np.sum(np.asarray(c)))
            total += int(np.sum(np.asarray(n)))
        return correct, total

    def evaluate(self, params=None, client_idx=None) -> float:
        params = self.params if params is None else params
        if self.population is not None:
            correct, total = self._eval_correct(params, client_idx)
            return correct / max(total, 1)
        d = self.data
        xt, yt, nt = self._test_stack
        if client_idx is None:
            idx = np.arange(d.n_clients)
        else:
            idx = np.asarray(client_idx)
            if len(idx) == 0:
                return 0.0
            sel = jnp.asarray(idx.astype(np.int32))
            xt, yt, nt = xt[sel], yt[sel], nt[sel]
        correct = self.eval_fn(params, xt, yt, nt)
        total = d.n_test[idx].sum()
        return float(np.sum(np.asarray(correct)) / max(total, 1))

    # -- main loop ---------------------------------------------------------
    def round(self, t: int) -> RoundMetrics:
        idx = self._select()
        x, y, n = self._client_batch(idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(idx))
        # downlink: 1 model per client; uplink: 1 update per client
        self.comm_params += 2 * len(idx) * self.model_size
        out = self._round_executor()(
            jax.tree_util.tree_map(lambda p: p[None], self.params),
            jnp.zeros(len(idx), jnp.int32), x, y, n, keys)
        self.params = out.global_params
        acc = self.evaluate()
        m = RoundMetrics(t, acc, float(out.mean_loss), float(out.discrepancy))
        self.history.add(m)
        return m

    def run(self, n_rounds=None) -> History:
        for t in range(n_rounds or self.cfg.n_rounds):
            self.round(t)
        return self.history

    def close(self):
        """Stop the population prefetch thread (no-op in pinned mode)."""
        if self.population is not None:
            self.population.close()


class FedProxTrainer(FedAvgTrainer):
    framework = "fedprox"

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        if cfg.mu <= 0:
            cfg = dataclasses.replace(cfg, mu=0.01)
        super().__init__(model, data, cfg, mesh=mesh, population=population)


class GroupedTrainer(FedAvgTrainer):
    """Shared machinery for the clustered trainers (FedGroup, IFCA, FeSEM):
    m group models kept as an m-stacked pytree, per-client membership
    bookkeeping, and group-wise weighted-accuracy evaluation."""

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        super().__init__(model, data, cfg, mesh=mesh, population=population)
        self.m = cfg.n_groups
        if population is not None:
            # membership IS the persistent state table's column, so the
            # trainers' in-place writes survive across cohorts/restarts
            self.membership = population.state.membership
        else:
            self.membership = np.full(self.n_clients, -1, np.int64)

    def group_param(self, j: int):
        """The j-th group's parameter pytree (view into the stacked state)."""
        return server_lib.tree_index(self.group_params, j)

    def evaluate_groups(self) -> float:
        """Weighted accuracy: each group model on the test data of all
        clients historically assigned to it (paper §5.1 metric)."""
        if self.population is not None:
            eval_ids = self.population.eval_ids()
            mem = self.membership[eval_ids]
            total_correct, total_n = 0, 0
            for j in range(self.m):
                members = eval_ids[mem == j]
                if len(members) == 0:
                    continue
                c, tot = self._eval_correct(self.group_param(j), members)
                total_correct += c
                total_n += tot
            return total_correct / max(total_n, 1)
        total_correct, total_n = 0, 0
        xt, yt, nt = self._test_stack
        for j in range(self.m):
            members = np.where(self.membership == j)[0]
            if len(members) == 0:
                continue
            sel = jnp.asarray(members.astype(np.int32))
            correct = self.eval_fn(self.group_param(j), xt[sel], yt[sel],
                                   nt[sel])
            total_correct += int(np.sum(np.asarray(correct)))
            total_n += int(self.data.n_test[members].sum())
        return total_correct / max(total_n, 1)

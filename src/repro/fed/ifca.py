"""IFCA (Ghosh et al., NeurIPS 2020) — the strongest CFL baseline.

Per round the server broadcasts ALL m cluster models to the selected clients;
each client estimates its cluster identity as the model with minimum local
training loss, then optimizes that model. Accurate but communication-heavy
(m× model broadcast per round — the overhead FedGroup's static grouping and
newcomer cold start avoid; we count it in the benchmark).

The argmin-loss estimation runs as the round executor's in-program
assignment stage (``make_ifca_assign``): the per-client loss under all m
stacked group models and the subsequent per-cluster FedAvg are fused into
ONE device dispatch per round — the retired estimate-then-loop baseline
survives as ``fed.rounds.serial_ifca_round``. Fusion changes only the
dispatch count; the m× broadcast *communication accounting* is exactly the
seed's ((m+1) model transfers per selected client per round).

On a mesh the fused assignment rides the executor's placement unchanged:
the per-client losses shard over the data axes with the cohort, and on a
2-D ``(data, model)`` mesh the m stacked models' parameter dim shards
over "model" (docs/scaling.md) — the argmin still runs in-program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import client as client_lib
from repro.fed import rounds as rounds_lib
from repro.fed.engine import FedConfig, GroupedTrainer, RoundMetrics


def make_ifca_assign(model):
    """Assignment stage: per-client argmin of mean train loss over the m
    stacked group models (IFCA §3 cluster-identity estimate)."""
    loss_one = client_lib.client_mean_loss(model)

    def assign(group_params, X, Y, n, state):
        per_client = jax.vmap(loss_one, in_axes=(None, 0, 0, 0))
        losses = jax.vmap(lambda gp: per_client(gp, X, Y, n))(group_params)
        return jnp.argmin(losses, axis=0)                   # (K,) over m

    return assign


class IFCATrainer(GroupedTrainer):
    framework = "ifca"

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        super().__init__(model, data, cfg, mesh=mesh, population=population)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed + 17), self.m)
        # random initializations of cluster centers (IFCA §3)
        self.group_params = rounds_lib.stack_trees(
            [model.init(k) for k in keys])
        self.comm_models_per_round = self.m  # broadcast overhead bookkeeping

    def _exec_spec(self) -> dict:
        return {"n_groups": self.m, "eta_g": 0.0,
                "assign_fn": make_ifca_assign(self.model)}

    def _stage_comm(self, k: int):
        # the m× broadcast accounting is per ALIVE client, block or not
        self.comm_params += (self.m + 1) * k * self.model_size

    def _async_stream_arg(self, idx):
        return None      # the in-program argmin-loss stage needs no state

    def round(self, t: int, idx=None) -> RoundMetrics:
        if idx is None:
            idx = self._select()
        # IFCA broadcasts ALL m cluster models to every selected client
        self.comm_params += (self.m + 1) * len(idx) * self.model_size
        x, y, n = self._client_batch(idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(idx))
        out = self._round_executor()(self.group_params, None, x, y, n, keys)
        self.group_params = out.group_params
        # persists into the population state table when streaming (the
        # trainer's membership array IS the table's column); migrations
        # are counted into the telemetry registry on the way through
        self._adopt_membership(idx, out.membership)
        acc = self._round_eval(t)
        self._fold_alive = len(idx)
        m = RoundMetrics(t, acc, float(out.mean_loss), float(out.discrepancy),
                         int(out.n_quarantined))
        self.history.add(m)
        return m

"""IFCA (Ghosh et al., NeurIPS 2020) — the strongest CFL baseline.

Per round the server broadcasts ALL m cluster models to the selected clients;
each client estimates its cluster identity as the model with minimum local
training loss, then optimizes that model. Accurate but communication-heavy
(m× model broadcast per round — the overhead FedGroup's static grouping and
newcomer cold start avoid; we count it in the benchmark).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import client as client_lib
from repro.fed import server as server_lib
from repro.fed.engine import FedAvgTrainer, FedConfig, RoundMetrics


class IFCATrainer(FedAvgTrainer):
    framework = "ifca"

    def __init__(self, model, data, cfg: FedConfig):
        super().__init__(model, data, cfg)
        self.m = cfg.n_groups
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed + 17), self.m)
        # random initializations of cluster centers (IFCA §3)
        self.group_params = [model.init(k) for k in keys]
        self.loss_fn = client_lib.make_loss_eval_fn(model)
        self.membership = np.full(data.n_clients, -1, np.int64)
        self.comm_models_per_round = self.m  # broadcast overhead bookkeeping

    def _estimate_clusters(self, idx):
        x, y, n = self._client_batch(idx)
        losses = jnp.stack([self.loss_fn(p, x, y, n)
                            for p in self.group_params])       # (m, K)
        return np.asarray(jnp.argmin(losses, axis=0))

    def round(self, t: int) -> RoundMetrics:
        idx = self._select()
        # IFCA broadcasts ALL m cluster models to every selected client
        self.comm_params += (self.m + 1) * len(idx) * self.model_size
        assign = self._estimate_clusters(idx)
        self.membership[idx] = assign
        disc_sum, disc_n = 0.0, 0
        for j in range(self.m):
            members = idx[assign == j]
            if len(members) == 0:
                continue
            deltas, finals, n = self._solve(self.group_params[j], members)
            agg = server_lib.weighted_delta(deltas, n)
            self.group_params[j] = server_lib.apply_delta(
                self.group_params[j], agg)
            diffs = jax.vmap(lambda f: server_lib.tree_norm(
                server_lib.tree_sub(f, self.group_params[j])))(finals)
            disc_sum += float(jnp.sum(diffs))
            disc_n += len(members)
        acc = self.evaluate_groups()
        m = RoundMetrics(t, acc, 0.0, disc_sum / max(disc_n, 1))
        self.history.add(m)
        return m

    def evaluate_groups(self) -> float:
        total_correct, total_n = 0, 0
        d = self.data
        for j in range(self.m):
            members = np.where(self.membership == j)[0]
            if len(members) == 0:
                continue
            correct = self.eval_fn(self.group_params[j],
                                   jnp.asarray(d.x_test[members]),
                                   jnp.asarray(d.y_test[members]),
                                   jnp.asarray(d.n_test[members]))
            total_correct += int(np.sum(np.asarray(correct)))
            total_n += int(d.n_test[members].sum())
        return total_correct / max(total_n, 1)

"""Population engine: streamed round cohorts over a host-resident store.

The pinned trainers upload the whole padded population at init; this module
is the large-N replacement. A ``Population`` bundles

  * a ``ClientStore`` (``fed.store``) holding the population host-resident,
  * a ``Scheduler`` with pluggable cohort samplers — uniform (bit-identical
    to the pinned trainers' selection under the same seed), size-weighted,
    diurnal availability traces, scripted replay — plus a newcomer *arrival
    process* that activates previously unseen clients every round, so
    FedGroup's eq.-9 client cold start runs continuously instead of once,
  * a ``ClientStateTable`` (membership / cold flags / FeSEM local_flat rows
    / cached pre-training directions) gathered and scattered per cohort,
  * a double-buffered *prefetcher*: a producer thread selects round t+1's
    cohort, gathers its padded arrays from the store, and starts the H2D
    transfer (``jax.device_put`` is asynchronous) while the device is still
    executing round t's compiled executor — the transfer hides behind
    compute instead of serializing with it. Over a
    ``fed.store.ShardedClientStore`` + a mesh the prefetcher goes
    *per-shard*: each data-axis slice's rows are gathered and device_put
    separately and assembled into one global cohort array with
    ``jax.make_array_from_single_device_arrays``
    (``fed.parallel.put_sharded_cohort``) — the multi-host feeding path,
    simulated on one machine,
  * an *async state writer*: FeSEM's per-cohort ``local_flat`` rows are
    scattered back into the host state table split per shard on a
    background thread; any reader drains the write queue first, so the
    asynchrony is invisible to program semantics (streamed results stay
    bit-identical to pinned — docs/scaling.md spells out the guarantee).

The trainers' ``population=`` mode consumes this through three calls:
``next_cohort()`` (the scheduled, prefetched round batch),
``device_batch(idx)`` (ad-hoc gathers, e.g. cold-start pre-training — a
subset of the live cohort is sliced on device for free), and
``eval_batches(params-independent test blocks)``. The compiled round
program (``fed.rounds.make_round_executor`` / ``fed.parallel
.make_sharded_executor``) is exactly the pinned path's — only the feeding
changes.
"""
from __future__ import annotations

import itertools
import queue
import threading
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.fed import parallel as parallel_lib
from repro.fed.store import (SELECT_STREAM, ClientStateTable, ClientStore,
                             ShardedClientStore, shard_cohort_slices)


class _AsyncStateWriter:
    """Single background thread applying host state-table writes in FIFO
    order — the asynchronous half of the per-shard scatter. ``drain()``
    blocks until every enqueued write has landed; readers call it before
    any gather, so the asynchrony never reorders a read past a write and
    streamed results stay bit-identical to the synchronous path."""

    def __init__(self):
        self._q = queue.Queue()
        self._thread = None
        self._err = None

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                fn, args = item
                try:
                    fn(*args)
                except BaseException as e:  # noqa: BLE001 — raised in drain
                    self._err = e
            finally:
                self._q.task_done()

    def submit(self, fn, *args):
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="state-table-writer", daemon=True)
            self._thread.start()
        self._q.put((fn, args))

    def drain(self):
        if self._thread is not None:
            self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async state-table write failed") from err

    def close(self):
        if self._thread is not None:
            self._q.join()                  # pending writes land first —
            self._q.put(None)               # only then stop the worker
            self._thread.join(timeout=5.0)
            self._thread = None
        self.drain()                        # surface any write error


@dataclass
class PopulationConfig:
    """Knobs of the streamed population (sampling, availability, arrivals,
    prefetch, eval). ``seed=None`` inherits the trainer's ``cfg.seed`` so a
    same-seed uniform/always-available population reproduces the pinned
    trainers' selection stream exactly (the equivalence tests rely on it).
    """
    sampler: str = "uniform"        # uniform | size | scripted
    script: list | None = None      # scripted: per-round index arrays
    availability: str = "always"    # always | diurnal
    period: int = 24                # diurnal: rounds per simulated day
    duty: float = 0.5               # diurnal: awake fraction of the day
    initial_active: int | None = None   # None = whole population active
    arrival_rate: float = 0.0       # Poisson mean newcomers per round
    newcomers_join: bool = True     # arrivals are forced into their round's cohort
    prefetch: int = 2               # cohorts in flight (0 = synchronous)
    # eval on a fixed subsample; None = the whole population, which matches
    # pinned-path semantics exactly but costs O(N) per evaluate() — at
    # N >= 10^4 set this (or rely on the grouped trainers' assigned-members
    # eval, which only touches clients that have ever been scheduled)
    eval_clients: int | None = None
    eval_batch: int = 512           # clients per streamed eval block
    seed: int | None = None


@dataclass
class Cohort:
    """One scheduled round batch: ids + device-resident padded arrays."""
    t: int
    idx: np.ndarray                 # (K,) client ids
    x: object                       # (K, max_n, ...) on device
    y: object
    n: object
    n_new: int = 0                  # newcomers activated this round
    _pos: dict = field(default_factory=dict, repr=False)

    def positions(self, ids) -> np.ndarray | None:
        """Cohort-local positions of ``ids`` (None if any id is absent)."""
        if not self._pos:
            self._pos = {int(i): p for p, i in enumerate(self.idx)}
        try:
            return np.asarray([self._pos[int(i)] for i in ids], np.int32)
        except KeyError:
            return None


class Scheduler:
    """Availability-aware cohort selection + the newcomer arrival process.

    The active set starts as ``initial_active`` uniformly random clients
    (or everyone); each round ``select(t, k)`` first activates
    ``Poisson(arrival_rate)`` arrivals (in a fixed random arrival order),
    then samples the cohort from the *available* actives: everyone under
    ``availability='always'``, or the clients whose diurnal phase puts them
    awake at round t (each client keeps a fixed phase; a fraction ``duty``
    of the period is awake — the classic cross-device availability trace).
    Newcomers join their arrival round's cohort (they "report in", which
    is what feeds the eq.-9 cold-start path every round); the rest of the
    cohort fills uniformly or size-weighted without replacement.
    """

    def __init__(self, store: ClientStore, cfg: PopulationConfig, seed: int):
        self.store, self.cfg = store, cfg
        # same derived stream as the pinned trainers' select_rng
        self.rng = np.random.default_rng(
            [cfg.seed if cfg.seed is not None else seed, SELECT_STREAM])
        N = store.n_clients
        if cfg.sampler not in ("uniform", "size", "scripted"):
            raise ValueError(f"unknown sampler {cfg.sampler!r}")
        if cfg.sampler == "scripted" and not cfg.script:
            raise ValueError("scripted sampler needs cfg.script")
        self.active = np.ones(N, bool)
        self._arrival_queue = np.empty(0, np.int64)
        if cfg.initial_active is not None and cfg.initial_active < N:
            perm = self.rng.permutation(N)
            self.active[:] = False
            self.active[perm[:cfg.initial_active]] = True
            self._arrival_queue = perm[cfg.initial_active:]
        self.phase = (self.rng.integers(0, cfg.period, N)
                      if cfg.availability == "diurnal" else None)
        self.last_arrivals = np.empty(0, np.int64)
        self.rounds_scheduled = 0

    # -- availability ------------------------------------------------------
    def available_mask(self, t: int) -> np.ndarray:
        avail = self.active.copy()
        if self.phase is not None:
            awake = ((t + self.phase) % self.cfg.period) < \
                self.cfg.duty * self.cfg.period
            avail &= awake
        return avail

    def active_ids(self) -> np.ndarray:
        return np.where(self.active)[0]

    # -- arrivals ----------------------------------------------------------
    def _arrive(self) -> np.ndarray:
        cfg = self.cfg
        if cfg.arrival_rate <= 0 or len(self._arrival_queue) == 0:
            self.last_arrivals = np.empty(0, np.int64)
            return self.last_arrivals
        k = min(int(self.rng.poisson(cfg.arrival_rate)),
                len(self._arrival_queue))
        new, self._arrival_queue = (self._arrival_queue[:k],
                                    self._arrival_queue[k:])
        self.active[new] = True
        self.last_arrivals = new
        return new

    # -- selection ---------------------------------------------------------
    def select(self, t: int, k: int, dropout_rate: float = 0.0):
        """-> (cohort ids (K,), n_new). Sequential in t (the prefetcher is
        the only caller); all randomness comes from the scheduler rng."""
        cfg = self.cfg
        if cfg.sampler == "scripted":
            idx = np.asarray(cfg.script[t % len(cfg.script)], np.int64)
            self.rounds_scheduled += 1
            return idx, 0
        new = self._arrive()
        avail = self.available_mask(t)
        pool = np.where(avail)[0]
        if cfg.sampler == "uniform" and len(new) == 0 and \
                len(pool) == self.store.n_clients:
            # bit-compatible with the pinned trainers' selection: same
            # rng.choice(n, k) call when the whole population is available
            idx = self.rng.choice(self.store.n_clients,
                                  min(k, self.store.n_clients),
                                  replace=False)
        else:
            forced = new[:k] if cfg.newcomers_join else np.empty(0, np.int64)
            rest = pool[~np.isin(pool, forced)]
            want = min(k, len(rest) + len(forced)) - len(forced)
            if want > 0 and len(rest) > 0:
                if cfg.sampler == "size":
                    w = self.store.n_train[rest].astype(np.float64)
                    p = w / max(w.sum(), 1e-12)
                    fill = self.rng.choice(rest, want, replace=False, p=p)
                else:
                    fill = self.rng.choice(rest, want, replace=False)
            else:
                fill = np.empty(0, np.int64)
            idx = np.concatenate([forced, fill])
        if len(idx) == 0:
            # every active client is asleep this round — the round executor
            # needs >=1 client (the pinned dropout path keeps the same
            # floor), so wake one active client uniformly
            actives = np.where(self.active)[0]
            if len(actives) == 0:
                raise RuntimeError(
                    "population has no active clients to schedule "
                    "(initial_active=0 and no arrivals yet)")
            idx = self.rng.choice(actives, 1)
        if dropout_rate > 0.0 and len(idx):
            alive = self.rng.random(len(idx)) >= dropout_rate
            if not alive.any():
                alive[self.rng.integers(len(idx))] = True
            idx = idx[alive]
        self.rounds_scheduled += 1
        return idx, len(new)


class Population:
    """Store + scheduler + state table + prefetcher, bound to one trainer.

    Construct with a store and a ``PopulationConfig``, pass as the
    trainers' ``population=``; the trainer calls ``attach`` with its
    ``FedConfig`` (cohort size, dropout, seed default) and mesh. The
    prefetch thread starts on the first ``next_cohort``.
    """

    # Streamed populations never fuse into round blocks
    # (``FedConfig.block_size``): the arrival process and the cohort
    # prefetcher must be observed by the host between rounds (newcomer
    # activation feeds eq.-9 cold start round by round), so ``engine.run``
    # falls back to the per-round path whenever a population is attached —
    # the "population streaming" block-break event.
    block_stageable = False

    def __init__(self, store: ClientStore, cfg: PopulationConfig | None = None):
        self.store = store
        self.cfg = cfg or PopulationConfig()
        self.state = ClientStateTable(store.n_clients)
        self.scheduler = None
        self.mesh = None
        self._k = None
        self._dropout = 0.0
        self._queue = None
        self._thread = None
        self._stop = threading.Event()
        self._producer_error = None
        self._writer = _AsyncStateWriter()
        self._warned_eval_scale = False
        self._cohort = None            # live (most recently consumed) cohort
        self._eval_ids = None
        self.rounds_streamed = 0

    # -- trainer binding ---------------------------------------------------
    def attach(self, fed_cfg, mesh=None):
        if self.scheduler is not None:
            raise RuntimeError("Population is already attached to a trainer")
        self.scheduler = Scheduler(self.store, self.cfg, seed=fed_cfg.seed)
        self.mesh = mesh
        self._k = fed_cfg.clients_per_round
        self._dropout = fed_cfg.dropout_rate
        if self.cfg.eval_clients is not None and \
                self.cfg.eval_clients < self.store.n_clients:
            eval_rng = np.random.default_rng(
                (self.cfg.seed if self.cfg.seed is not None
                 else fed_cfg.seed) + 0x5EED)
            self._eval_ids = np.sort(eval_rng.choice(
                self.store.n_clients, self.cfg.eval_clients, replace=False))
        else:
            self._eval_ids = np.arange(self.store.n_clients)

    # -- device placement --------------------------------------------------
    def _put(self, arrays):
        """Start the H2D transfer (sharded over the trainer mesh when one
        is present; plain async device_put otherwise)."""
        return parallel_lib.shard_client_axis(self.mesh, arrays)

    def _n_shards(self) -> int:
        return parallel_lib.mesh_data_shards(self.mesh)

    def _gather_put(self, split: str, idx):
        """Store gather + H2D for a cohort. Over a ``ShardedClientStore``
        + a mesh this goes per shard: each data slice's rows are gathered
        and device_put separately, then assembled into one global array
        (``fed.parallel.put_sharded_cohort``) — no host-side concatenation
        of the full cohort, which is what a real multi-host deployment
        cannot do. Everything else takes the single-gather path."""
        store = self.store
        if self.mesh is not None and isinstance(store, ShardedClientStore):
            parts = store._gather_shards(split, idx, self._n_shards())
            if parts is not None:
                return parallel_lib.put_sharded_cohort(self.mesh, parts)
        return self._put(store._gather(split, np.asarray(idx, np.int64)))

    def device_batch(self, idx):
        """(x, y, n) on device for an arbitrary id set. Ids inside the live
        cohort are sliced from its already-transferred arrays (the cold-
        start subset case); anything else is a fresh store gather."""
        idx = np.asarray(idx)
        c = self._cohort
        if c is not None:
            pos = c.positions(idx)
            if pos is not None:
                if len(pos) == len(c.idx) and np.all(pos == np.arange(len(pos))):
                    return c.x, c.y, c.n
                return c.x[pos], c.y[pos], c.n[pos]
        return self._gather_put("train", idx)

    # -- persistent state (per-shard async scatter) ------------------------
    def gather_local_flat(self, idx) -> np.ndarray:
        """Cohort rows of FeSEM's host ``local_flat`` table. Drains the
        async writer first, so a gather always observes every earlier
        scatter — the read side of the determinism guarantee."""
        self._writer.drain()
        return self.state.gather_local_flat(idx)

    def scatter_local_flat(self, idx, rows):
        """Write the cohort's updated ``local_flat`` rows back into the
        host table, split into per-data-shard slices and applied on the
        background writer thread — the round's host-side bookkeeping
        overlaps evaluation and the next cohort's gather instead of
        blocking the training loop (on multi-host, each host scatters
        its own slice)."""
        idx = np.asarray(idx)
        rows = np.asarray(rows)
        slices = shard_cohort_slices(len(idx), self._n_shards()) \
            or [(0, len(idx))]
        for lo, hi in slices:
            self._writer.submit(self.state.scatter_local_flat,
                                idx[lo:hi].copy(), rows[lo:hi])

    # -- streamed cohorts --------------------------------------------------
    def _produce(self):
        try:
            for t in itertools.count():
                if self._stop.is_set():
                    return
                idx, n_new = self.scheduler.select(t, self._k, self._dropout)
                x, y, n = self._gather_put("train", idx)
                cohort = Cohort(t, np.asarray(idx), x, y, n, n_new)
                while not self._stop.is_set():
                    try:
                        self._queue.put(cohort, timeout=0.2)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # noqa: BLE001 — surfaced by next_cohort
            self._producer_error = e
            while not self._stop.is_set():
                try:                    # wake a blocked consumer
                    self._queue.put(None, timeout=0.2)
                    break
                except queue.Full:
                    continue

    def next_cohort(self) -> Cohort:
        """The next scheduled round batch, already on (or in flight to) the
        device. With ``prefetch=0`` selection+gather run synchronously —
        the no-overlap baseline the population bench compares against."""
        if self.scheduler is None:
            raise RuntimeError("attach() a trainer first")
        if self._stop.is_set():
            raise RuntimeError("population was close()d — the cohort "
                               "stream cannot be resumed")
        if self.cfg.prefetch <= 0:
            t = self.rounds_streamed
            idx, n_new = self.scheduler.select(t, self._k, self._dropout)
            cohort = Cohort(t, np.asarray(idx),
                            *self._gather_put("train", idx), n_new)
        else:
            if self._thread is None:
                self._queue = queue.Queue(maxsize=self.cfg.prefetch)
                self._thread = threading.Thread(
                    target=self._produce, name="population-prefetch",
                    daemon=True)
                self._thread.start()
            cohort = self._queue.get()
            if cohort is None:          # producer died — re-raise its error
                raise RuntimeError(
                    "population prefetch thread failed"
                ) from self._producer_error
        self.rounds_streamed += 1
        self._cohort = cohort
        return cohort

    def close(self):
        self._stop.set()
        if self._thread is not None:
            # drain so a producer blocked on put() can observe the stop flag
            try:
                while True:
                    self._queue.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None
        # flush + stop the async state writer (pending scatters land first)
        self._writer.close()

    # -- streamed evaluation ----------------------------------------------
    def eval_ids(self) -> np.ndarray:
        return self._eval_ids if self._eval_ids is not None \
            else np.arange(self.store.n_clients)

    def eval_batches(self, idx=None):
        """Yield device-resident (x_test, y_test, n_test) blocks of at most
        ``eval_batch`` clients — full-population eval without a full-
        population device allocation."""
        idx = self.eval_ids() if idx is None else np.asarray(idx)
        if len(idx) > 20_000 and not self._warned_eval_scale:
            self._warned_eval_scale = True
            import warnings
            warnings.warn(
                f"streaming evaluation over {len(idx)} clients every "
                f"round is O(N) host gather — set "
                f"PopulationConfig.eval_clients to subsample (grouped "
                f"trainers' eval only touches assigned members)",
                stacklevel=2)
        B = max(int(self.cfg.eval_batch), 1)
        for lo in range(0, len(idx), B):
            block = idx[lo:lo + B]
            x, y, n = self._gather_put("test", block)
            yield block, x, y, n

"""Population engine: streamed round cohorts over a host-resident store.

The pinned trainers upload the whole padded population at init; this module
is the large-N replacement. A ``Population`` bundles

  * a ``ClientStore`` (``fed.store``) holding the population host-resident,
  * a ``Scheduler`` with pluggable cohort samplers — uniform (bit-identical
    to the pinned trainers' selection under the same seed), size-weighted,
    diurnal availability traces, scripted replay — plus a newcomer *arrival
    process* that activates previously unseen clients every round, so
    FedGroup's eq.-9 client cold start runs continuously instead of once,
  * a ``ClientStateTable`` (membership / cold flags / FeSEM local_flat rows
    / cached pre-training directions) gathered and scattered per cohort,
  * a double-buffered *prefetcher*: a producer thread selects round t+1's
    cohort, gathers its padded arrays from the store, and starts the H2D
    transfer (``jax.device_put`` is asynchronous) while the device is still
    executing round t's compiled executor — the transfer hides behind
    compute instead of serializing with it. Over a
    ``fed.store.ShardedClientStore`` + a mesh the prefetcher goes
    *per-shard*: each data-axis slice's rows are gathered and device_put
    separately and assembled into one global cohort array with
    ``jax.make_array_from_single_device_arrays``
    (``fed.parallel.put_sharded_cohort``) — the multi-host feeding path,
    simulated on one machine,
  * an *async state writer*: FeSEM's per-cohort ``local_flat`` rows are
    scattered back into the host state table split per shard on a
    background thread; any reader drains the write queue first, so the
    asynchrony is invisible to program semantics (streamed results stay
    bit-identical to pinned — docs/scaling.md spells out the guarantee).

The population is also the runtime's *distribution-shift stage*: a
``ShiftConfig`` next to the diurnal/fault traces scripts label-swap and
gradual concept-drift scenarios (``ShiftSpec``) — pure deterministic
functions of (round, client id, seed) applied to the host label arrays on
every gather path (train cohorts, ad-hoc ``device_batch`` gathers, eval
blocks) before fault corruption and the H2D put, so streamed, prefetched
and resumed runs all see bit-identical shifted data and checkpoints need
carry nothing new:

>>> import numpy as np
>>> from repro.fed.population import ShiftConfig, ShiftSpec, apply_shift
>>> sh = ShiftConfig([ShiftSpec(at=2, classes=(0, 2))])
>>> y = np.array([[0, 1, 2]])
>>> apply_shift(sh, 4, 3, 1, np.array([0]), y).tolist()   # before t=2
[[0, 1, 2]]
>>> apply_shift(sh, 4, 3, 2, np.array([0]), y).tolist()   # 0<->2 swapped
[[2, 1, 0]]

It is also the runtime's *failure domain*: a ``FaultConfig``
next to the diurnal traces scripts per-round scenarios (mid-round client
death, straggler delays, corrupted NaN/Inf/blown-up payloads, a killed
writer thread) against exactly the production code paths;
``PopulationConfig.deadline`` bounds how long ``next_cohort()`` waits for
stragglers before degrading to the staged prefix of the cohort; and
``ckpt_state()``/``ckpt_restore()`` capture the scheduler stream + state
table for the engine's bit-identical checkpoint/restore
(docs/architecture.md, "Failure domains & recovery").

The trainers' ``population=`` mode consumes this through three calls:
``next_cohort()`` (the scheduled, prefetched round batch),
``device_batch(idx)`` (ad-hoc gathers, e.g. cold-start pre-training — a
subset of the live cohort is sliced on device for free), and
``eval_batches(params-independent test blocks)``. The compiled round
program (``fed.rounds.make_round_executor`` / ``fed.parallel
.make_sharded_executor``) is exactly the pinned path's — only the feeding
changes.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.fed import parallel as parallel_lib
from repro.fed.store import (SELECT_STREAM, ClientStateTable, ClientStore,
                             ShardedClientStore, shard_cohort_slices)
from repro.obs import metrics as obs_metrics
from repro.obs import telemetry as obs_lib
from repro.obs import trace as obs_trace

# fault-injection sentinel: makes the writer worker return without
# completing its pending item — the observable state of a thread killed
# mid-write (dead, pending count still up), with no traceback noise
_CRASH = object()

# canonical zero state of Population.stats — THE single source of truth
# for the population degradation schema: the registry's ``pop.*`` metric
# declarations and the back-compat ``Population.stats`` view are both
# derived from it (async counters are fed by the engine's scheduler loop;
# writer_retries mirrors _AsyncStateWriter.retries)
_STATS_ZERO = {"deadline_rounds": 0, "deadline_dropped_clients": 0,
               "killed_clients": 0, "corrupted_clients": 0,
               "writer_crashes": 0, "writer_retries": 0,
               "lease_expiries": 0, "requeues": 0}


def pop_metric_specs():
    """The registry schema derived from ``_STATS_ZERO`` — tests assert the
    two never drift apart."""
    return [obs_metrics.MetricSpec(f"pop.{k}", obs_metrics.COUNTER,
                                   "population degradation counter")
            for k in _STATS_ZERO]


# spans from writers constructed outside a Population (unit tests) go
# nowhere: a permanently-disabled tracer whose span() is the no-op path
_NULL_TRACER = obs_trace.Tracer(enabled=False)


class _AsyncStateWriter:
    """Single background thread applying host state-table writes in FIFO
    order — the asynchronous half of the per-shard scatter. ``drain()``
    blocks until every enqueued write has landed; readers call it before
    any gather, so the asynchrony never reorders a read past a write and
    streamed results stay bit-identical to the synchronous path.

    Waits are *bounded*: completion is tracked with an own pending counter
    + condition variable instead of ``Queue.join()`` (which has no timeout
    and deadlocks forever if the worker hangs or dies mid-write). A drain
    that outlives ``timeout`` raises ``RuntimeError`` naming the write in
    flight; a dead worker with writes still pending is detected and
    surfaced instead of waited on.

    Transient write failures are retried in place: a write that raises is
    re-attempted up to ``max_retries`` times with capped exponential
    backoff (``backoff * 2^attempt``, at most ``backoff_cap`` seconds per
    sleep) before the error is recorded and surfaced by the next
    ``drain()`` — one flaky disk write no longer kills the worker thread.
    ``retries`` counts the recovered attempts (surfaced in
    ``Population.stats`` as ``writer_retries``)."""

    def __init__(self, timeout: float = 60.0, max_retries: int = 3,
                 backoff: float = 0.02, backoff_cap: float = 1.0,
                 tracer=None):
        self.timeout = timeout
        self._tracer = tracer if tracer is not None else _NULL_TRACER
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self.backoff_cap = float(backoff_cap)
        self.retries = 0                # failed attempts that later recovered
        self._q = queue.Queue()
        self._thread = None
        self._err = None
        self._cond = threading.Condition()
        self._pending = 0
        self._label = None              # description of the in-flight write

    def _attempt(self, fn, args, label):
        """Run one write with bounded retry + capped exponential backoff;
        records the terminal error for drain() after retries exhaust."""
        for attempt in range(self.max_retries + 1):
            try:
                fn(*args)
                if attempt:
                    self.retries += attempt
                return
            except BaseException as e:  # noqa: BLE001 — raised in drain
                if attempt == self.max_retries:
                    self._err = e
                    return
                time.sleep(min(self.backoff * (2.0 ** attempt),
                               self.backoff_cap))

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, args, label = item
            with self._cond:
                self._label = label
            if fn is _CRASH:
                return                  # injected fault: die, pending stays
            with self._tracer.span("state-write", label=label):
                self._attempt(fn, args, label)
            with self._cond:
                self._pending -= 1
                self._label = None
                self._cond.notify_all()

    def submit(self, fn, *args, label: str | None = None):
        with self._cond:
            self._pending += 1
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="state-table-writer", daemon=True)
            self._thread.start()
        # a dead thread is NOT restarted: the pending count stays up and the
        # next drain()/close() reports the crash instead of hiding it
        self._q.put((fn, args, label or getattr(fn, "__name__", "write")))

    def drain(self, timeout: float | None = None):
        """Block until every enqueued write has landed — bounded. Raises
        ``RuntimeError`` naming the pending write if it does not complete
        within ``timeout`` (default: the writer's construction timeout), or
        immediately if the worker thread died with writes pending."""
        deadline = time.monotonic() + \
            (self.timeout if timeout is None else timeout)
        with self._cond:
            while self._pending > 0:
                if self._thread is not None and not self._thread.is_alive():
                    raise RuntimeError(
                        f"state-table writer thread died with "
                        f"{self._pending} write(s) pending (in flight: "
                        f"{self._label or 'queued, never started'})")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise RuntimeError(
                        f"state-table write did not complete within "
                        f"{self.timeout if timeout is None else timeout:.1f}s"
                        f": {self._pending} pending (in flight: "
                        f"{self._label!r})")
                self._cond.wait(min(remaining, 0.1))
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async state-table write failed") from err

    def close(self, timeout: float | None = None):
        # pending writes land first (bounded — a stuck or dead worker
        # raises here instead of deadlocking shutdown), then stop the worker
        self.drain(timeout)
        if self._thread is not None:
            self._q.put(None)
            self._thread.join(timeout=5.0)
            self._thread = None

    def inject_thread_crash(self):
        """Fault injection: make the worker exit *without* completing a
        pending write — the observable signature of a writer thread killed
        mid-scatter. Subsequent ``drain()``/``close()`` calls raise the
        dead-thread ``RuntimeError`` instead of hanging."""
        with self._cond:
            self._pending += 1
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="state-table-writer", daemon=True)
            self._thread.start()
        self._q.put((_CRASH, (), "<injected writer-thread crash>"))


@dataclass
class FaultSpec:
    """What goes wrong in one round (all effects compose).

    kill            clients that die mid-round *after* selection: the tail
                    of the cohort drops (forced newcomers stage first and
                    survive), floored at 1 survivor — the round proceeds
                    with the remainder, re-weighted by the segment-sum.
    straggle        extra staging wall-clock (seconds) for this round's
                    cohort, spread across the gather chunks — the knob the
                    ``PopulationConfig.deadline`` path degrades against.
    corrupt         clients whose *payload* arrives poisoned: ``corrupt``
                    rng-chosen cohort lanes have their train features
                    overwritten per ``corrupt_mode`` before the H2D put,
                    producing NaN/Inf/blown-up local updates for the
                    quarantine screen to catch.
    corrupt_mode    "nan" | "inf" | "scale" (multiply features by
                    ``corrupt_scale`` — finite but norm-outlier updates).
    writer_crash    kill the async state-table writer thread mid-write this
                    round (the next drain surfaces it, see
                    ``_AsyncStateWriter.inject_thread_crash``).

    The ``worker_*``/``msg_*``/``heartbeat_delay`` fields are *fleet*
    faults — process-level chaos consumed by ``launch.coordinator`` (the
    population itself ignores them):

    worker_kill     SIGKILL (process transport) or hard-stop (in-process
                    transport) one worker while it holds this round's
                    lease; the coordinator detects the death via missed
                    heartbeats, requeues the lease, and re-dispatches.
    heartbeat_delay suppress a worker's heartbeats for this many seconds
                    starting at this round — long enough and the
                    coordinator declares the worker dead (a late
                    heartbeat resurrects it).
    msg_drop        drop this round's first result message in transit
                    (the lease times out and requeues).
    msg_dup         deliver this round's result message twice (the stale
                    duplicate must be ignored by job id).
    msg_reorder     hold this round's result back until another message
                    passes it (delivery-order chaos).
    """
    kill: int = 0
    straggle: float = 0.0
    corrupt: int = 0
    corrupt_mode: str = "nan"
    corrupt_scale: float = 64.0
    writer_crash: bool = False
    worker_kill: bool = False
    heartbeat_delay: float = 0.0
    msg_drop: bool = False
    msg_dup: bool = False
    msg_reorder: bool = False


@dataclass
class FaultConfig:
    """Scripted per-round fault scenarios, configured next to the diurnal
    traces (``PopulationConfig.faults``): ``rounds`` maps round t to the
    ``FaultSpec`` injected that round; ``seed`` drives the corrupt-lane
    choice so a scenario replays identically."""
    rounds: dict
    seed: int = 0

    def spec(self, t: int) -> FaultSpec | None:
        return self.rounds.get(int(t))


@dataclass
class ShiftSpec:
    """One scripted distribution shift over the client population.

    at          first round the shift is live (train cohorts gathered for
                round ``at`` and eval blocks from round ``at`` on see it).
    kind        "label_swap" — every affected client's labels are remapped
                through one cycle of ``classes`` at once (the classic
                abrupt concept shift); "drift" — the remap phases in
                sample-by-sample over ``duration`` rounds (gradual concept
                drift): each sample flips at a fixed deterministic point of
                the ramp, so the set of remapped samples grows
                monotonically and any given round is reproducible.
    frac        fraction of clients affected (chosen by a seeded hash of
                the client id — the same clients every round / replay).
    classes     label cycle, e.g. ``(0, 2)`` swaps 0<->2 and ``(1, 2, 3)``
                rotates 1->2->3->1; None cycles *all* classes.
    duration    drift ramp length in rounds (ignored for label_swap).
    """
    at: int
    kind: str = "label_swap"
    frac: float = 1.0
    classes: tuple | None = None
    duration: int = 0


@dataclass
class ShiftConfig:
    """Scripted distribution-shift scenarios (``PopulationConfig.shift``):
    every ``ShiftSpec`` in ``specs`` composes, in order, onto the host
    label arrays of each gather; ``seed`` drives the affected-client and
    per-sample drift choices so a scenario replays identically across
    prefetch depths, restarts and checkpoint resumes (the transform is a
    pure function of (round, client id, seed) — nothing is persisted)."""
    specs: list
    seed: int = 0


def shift_client_mask(n_clients: int, seed: int, spec_index: int,
                      frac: float) -> np.ndarray:
    """(N,) bool mask of the clients a spec affects — a fixed seeded draw,
    identical every round, so a shifted client stays shifted."""
    if frac >= 1.0:
        return np.ones(n_clients, bool)
    rng = np.random.default_rng([int(seed), 0x5F1F7, int(spec_index)])
    return rng.random(n_clients) < frac


def shift_label_map(n_classes: int, classes) -> np.ndarray:
    """Label permutation for one spec: cycle ``classes`` by one position
    (identity elsewhere); ``classes=None`` cycles all labels."""
    mapping = np.arange(int(n_classes), dtype=np.int64)
    cyc = np.asarray(classes if classes is not None
                     else np.arange(int(n_classes)), np.int64)
    if len(cyc) >= 2:
        mapping[cyc] = np.roll(cyc, -1)
    return mapping


def apply_shift(cfg: "ShiftConfig | None", n_clients: int, n_classes: int,
                t, idx, y):
    """Apply every live spec of ``cfg`` to the (K, max_n) label block ``y``
    of clients ``idx`` as seen at round ``t``. Pure and deterministic:
    a copy is returned only when something actually changes. Padding rows
    beyond each client's ``n`` are remapped too, harmlessly — every
    consumer masks by the sample counts."""
    if cfg is None or t is None or int(t) < 0 or not cfg.specs:
        return y
    t = int(t)
    idx = np.asarray(idx, np.int64)
    out = None
    for si, spec in enumerate(cfg.specs):
        if t < spec.at:
            continue
        mask = shift_client_mask(n_clients, cfg.seed, si, spec.frac)
        rows = np.where(mask[idx])[0]
        if len(rows) == 0:
            continue
        if out is None:
            out = np.array(y, copy=True)
        mapping = shift_label_map(n_classes, spec.classes)
        if spec.kind == "label_swap":
            out[rows] = mapping[out[rows]]
        elif spec.kind == "drift":
            p = 1.0 if spec.duration <= 0 else \
                min(max((t - spec.at + 1) / spec.duration, 0.0), 1.0)
            for r in rows:
                u = np.random.default_rng(
                    [int(cfg.seed), 0xD51F7, si, int(idx[r])]
                ).random(out.shape[1])
                sel = u < p
                out[r, sel] = mapping[out[r, sel]]
        else:
            raise ValueError(f"unknown shift kind {spec.kind!r}")
    return y if out is None else out


@dataclass
class PopulationConfig:
    """Knobs of the streamed population (sampling, availability, arrivals,
    prefetch, eval). ``seed=None`` inherits the trainer's ``cfg.seed`` so a
    same-seed uniform/always-available population reproduces the pinned
    trainers' selection stream exactly (the equivalence tests rely on it).
    """
    sampler: str = "uniform"        # uniform | size | scripted
    script: list | None = None      # scripted: per-round index arrays
    availability: str = "always"    # always | diurnal
    period: int = 24                # diurnal: rounds per simulated day
    duty: float = 0.5               # diurnal: awake fraction of the day
    initial_active: int | None = None   # None = whole population active
    arrival_rate: float = 0.0       # Poisson mean newcomers per round
    newcomers_join: bool = True     # arrivals are forced into their round's cohort
    prefetch: int = 2               # cohorts in flight (0 = synchronous)
    # eval on a fixed subsample; None = the whole population, which matches
    # pinned-path semantics exactly but costs O(N) per evaluate() — at
    # N >= 10^4 set this (or rely on the grouped trainers' assigned-members
    # eval, which only touches clients that have ever been scheduled)
    eval_clients: int | None = None
    eval_batch: int = 512           # clients per streamed eval block
    seed: int | None = None
    # straggler deadline (seconds): how long next_cohort() waits for the
    # full cohort before proceeding with whatever clients have staged
    # (>= 1), re-weighting the segment-sum instead of barriering. None =
    # wait forever (the pre-existing behaviour, byte-identical feeding
    # path). With a deadline the cohort stages in ``stage_chunks`` pieces
    # so a partial prefix exists to degrade to.
    deadline: float | None = None
    stage_chunks: int = 8
    faults: FaultConfig | None = None   # scripted per-round fault scenarios
    shift: ShiftConfig | None = None    # scripted distribution shifts


@dataclass
class Cohort:
    """One scheduled round batch: ids + device-resident padded arrays."""
    t: int
    idx: np.ndarray                 # (K,) client ids
    x: object                       # (K, max_n, ...) on device
    y: object
    n: object
    n_new: int = 0                  # newcomers activated this round
    # scheduler snapshot taken right after this cohort's select() — what a
    # checkpoint at round t must persist so the resumed scheduler re-draws
    # round t+1 identically (the live scheduler may already be several
    # prefetched rounds ahead). Only populated when the attached trainer
    # checkpoints (``Population.attach`` enables tracking).
    sched_state: dict | None = None
    _pos: dict = field(default_factory=dict, repr=False)

    def positions(self, ids) -> np.ndarray | None:
        """Cohort-local positions of ``ids`` (None if any id is absent)."""
        if not self._pos:
            self._pos = {int(i): p for p, i in enumerate(self.idx)}
        try:
            return np.asarray([self._pos[int(i)] for i in ids], np.int32)
        except KeyError:
            return None


class Scheduler:
    """Availability-aware cohort selection + the newcomer arrival process.

    The active set starts as ``initial_active`` uniformly random clients
    (or everyone); each round ``select(t, k)`` first activates
    ``Poisson(arrival_rate)`` arrivals (in a fixed random arrival order),
    then samples the cohort from the *available* actives: everyone under
    ``availability='always'``, or the clients whose diurnal phase puts them
    awake at round t (each client keeps a fixed phase; a fraction ``duty``
    of the period is awake — the classic cross-device availability trace).
    Newcomers join their arrival round's cohort (they "report in", which
    is what feeds the eq.-9 cold-start path every round); the rest of the
    cohort fills uniformly or size-weighted without replacement.
    """

    def __init__(self, store: ClientStore, cfg: PopulationConfig, seed: int):
        self.store, self.cfg = store, cfg
        # same derived stream as the pinned trainers' select_rng
        self.rng = np.random.default_rng(
            [cfg.seed if cfg.seed is not None else seed, SELECT_STREAM])
        N = store.n_clients
        if cfg.sampler not in ("uniform", "size", "scripted"):
            raise ValueError(f"unknown sampler {cfg.sampler!r}")
        if cfg.sampler == "scripted" and not cfg.script:
            raise ValueError("scripted sampler needs cfg.script")
        self.active = np.ones(N, bool)
        self._arrival_queue = np.empty(0, np.int64)
        if cfg.initial_active is not None and cfg.initial_active < N:
            perm = self.rng.permutation(N)
            self.active[:] = False
            self.active[perm[:cfg.initial_active]] = True
            self._arrival_queue = perm[cfg.initial_active:]
        self.phase = (self.rng.integers(0, cfg.period, N)
                      if cfg.availability == "diurnal" else None)
        self.last_arrivals = np.empty(0, np.int64)
        self.rounds_scheduled = 0

    # -- availability ------------------------------------------------------
    def available_mask(self, t: int) -> np.ndarray:
        avail = self.active.copy()
        if self.phase is not None:
            awake = ((t + self.phase) % self.cfg.period) < \
                self.cfg.duty * self.cfg.period
            avail &= awake
        return avail

    def active_ids(self) -> np.ndarray:
        return np.where(self.active)[0]

    # -- arrivals ----------------------------------------------------------
    def _arrive(self) -> np.ndarray:
        cfg = self.cfg
        if cfg.arrival_rate <= 0 or len(self._arrival_queue) == 0:
            self.last_arrivals = np.empty(0, np.int64)
            return self.last_arrivals
        k = min(int(self.rng.poisson(cfg.arrival_rate)),
                len(self._arrival_queue))
        new, self._arrival_queue = (self._arrival_queue[:k],
                                    self._arrival_queue[k:])
        self.active[new] = True
        self.last_arrivals = new
        return new

    # -- selection ---------------------------------------------------------
    def select(self, t: int, k: int, dropout_rate: float = 0.0):
        """-> (cohort ids (K,), n_new). Sequential in t (the prefetcher is
        the only caller); all randomness comes from the scheduler rng."""
        cfg = self.cfg
        if cfg.sampler == "scripted":
            idx = np.asarray(cfg.script[t % len(cfg.script)], np.int64)
            self.rounds_scheduled += 1
            return idx, 0
        new = self._arrive()
        avail = self.available_mask(t)
        pool = np.where(avail)[0]
        if cfg.sampler == "uniform" and len(new) == 0 and \
                len(pool) == self.store.n_clients:
            # bit-compatible with the pinned trainers' selection: same
            # rng.choice(n, k) call when the whole population is available
            idx = self.rng.choice(self.store.n_clients,
                                  min(k, self.store.n_clients),
                                  replace=False)
        else:
            forced = new[:k] if cfg.newcomers_join else np.empty(0, np.int64)
            rest = pool[~np.isin(pool, forced)]
            want = min(k, len(rest) + len(forced)) - len(forced)
            if want > 0 and len(rest) > 0:
                if cfg.sampler == "size":
                    w = self.store.n_train[rest].astype(np.float64)
                    p = w / max(w.sum(), 1e-12)
                    fill = self.rng.choice(rest, want, replace=False, p=p)
                else:
                    fill = self.rng.choice(rest, want, replace=False)
            else:
                fill = np.empty(0, np.int64)
            idx = np.concatenate([forced, fill])
        if len(idx) == 0:
            # every active client is asleep this round — the round executor
            # needs >=1 client (the pinned dropout path keeps the same
            # floor), so wake one active client uniformly
            actives = np.where(self.active)[0]
            if len(actives) == 0:
                raise RuntimeError(
                    "population has no active clients to schedule "
                    "(initial_active=0 and no arrivals yet)")
            idx = self.rng.choice(actives, 1)
        if dropout_rate > 0.0 and len(idx):
            alive = self.rng.random(len(idx)) >= dropout_rate
            if not alive.any():
                alive[self.rng.integers(len(idx))] = True
            idx = idx[alive]
        self.rounds_scheduled += 1
        return idx, len(new)

    # -- checkpointing ------------------------------------------------------
    def snapshot(self) -> dict:
        """Everything ``select`` depends on besides t: rng stream, active
        set, pending arrival order. ``phase`` is deliberately absent — it
        is drawn once at construction, so a same-config fresh scheduler
        regenerates it before ``restore`` rewinds the rng."""
        return {"rng_state": self.rng.bit_generator.state,
                "active": self.active.copy(),
                "arrival_queue": self._arrival_queue.copy(),
                "last_arrivals": self.last_arrivals.copy(),
                "rounds_scheduled": int(self.rounds_scheduled)}

    def restore(self, snap: dict):
        self.rng.bit_generator.state = snap["rng_state"]
        self.active[:] = np.asarray(snap["active"], bool)
        self._arrival_queue = np.asarray(snap["arrival_queue"],
                                         np.int64).copy()
        self.last_arrivals = np.asarray(snap["last_arrivals"],
                                        np.int64).copy()
        self.rounds_scheduled = int(snap["rounds_scheduled"])


class _Staging:
    """Progress record of one cohort's chunked host gather, shared between
    the producer and a deadline-bounded consumer. The producer appends
    chunk arrays under ``cond``; a consumer whose deadline fired claims the
    staged prefix (``claimed``), after which the producer abandons the
    round. ``done`` flips when every chunk staged — set and checked under
    the same lock as ``claimed``, so exactly one side owns the cohort."""

    def __init__(self, t: int, idx: np.ndarray, n_new: int,
                 sched_state: dict | None):
        self.t = t
        self.idx = idx
        self.n_new = n_new
        self.sched_state = sched_state
        self.parts = []                 # per-chunk (x, y, n) host tuples
        self.n_staged = 0
        self.done = False
        self.claimed = False
        self.cond = threading.Condition()

    def take_prefix(self):
        """(idx, x, y, n) host arrays of the staged prefix — call with
        ``cond`` held and at least one chunk staged."""
        xs, ys, ns = zip(*self.parts)
        return (self.idx[:self.n_staged], np.concatenate(xs),
                np.concatenate(ys), np.concatenate(ns))


class Population:
    """Store + scheduler + state table + prefetcher, bound to one trainer.

    Construct with a store and a ``PopulationConfig``, pass as the
    trainers' ``population=``; the trainer calls ``attach`` with its
    ``FedConfig`` (cohort size, dropout, seed default) and mesh. The
    prefetch thread starts on the first ``next_cohort``.
    """

    # Streamed populations never fuse into round blocks
    # (``FedConfig.block_size``): the arrival process and the cohort
    # prefetcher must be observed by the host between rounds (newcomer
    # activation feeds eq.-9 cold start round by round), so ``engine.run``
    # falls back to the per-round path whenever a population is attached —
    # the "population streaming" block-break event.
    block_stageable = False

    def __init__(self, store: ClientStore, cfg: PopulationConfig | None = None):
        self.store = store
        self.cfg = cfg or PopulationConfig()
        self.state = ClientStateTable(store.n_clients)
        self.scheduler = None
        self.mesh = None
        self._k = None
        self._dropout = 0.0
        self._queue = None
        self._thread = None
        self._stop = threading.Event()
        self._producer_error = None
        # per-population telemetry bundle: the registry is OWN (counters
        # must not bleed across populations) but the tracer is shared with
        # the process default when a harness installed one (repro.obs)
        self.obs = obs_lib.from_config(None)
        self.obs.registry.declare(pop_metric_specs())
        self._writer = _AsyncStateWriter(tracer=self.obs.tracer)
        self._warned_eval_scale = False
        self._cohort = None            # live (most recently consumed) cohort
        self._eval_ids = None
        self.rounds_streamed = 0
        self._staging = None           # in-flight chunked gather (deadline)
        self._track_sched = False      # capture per-cohort scheduler snaps
        self._consumed_sched = None    # snapshot of the last consumed round
        # robustness counters: fault-injection effects + deadline
        # degradation + async-runtime lease churn. Registry-backed view
        # keyed by the legacy short names (``pop.*`` metrics underneath);
        # reset per run() (reset_stats) and carried through checkpoints so
        # a resumed run reports totals consistent with an uninterrupted one.
        self.stats = self.obs.registry.view(
            {k: f"pop.{k}" for k in _STATS_ZERO})

    def reset_stats(self):
        """Zero the robustness counters (called by the engine at the start
        of a *fresh* run — a checkpoint-resumed run keeps the restored
        totals so interrupted and uninterrupted runs report alike)."""
        self.obs.registry.reset([f"pop.{k}" for k in _STATS_ZERO])
        self._writer.retries = 0

    # -- trainer binding ---------------------------------------------------
    def attach(self, fed_cfg, mesh=None):
        if self.scheduler is not None:
            raise RuntimeError("Population is already attached to a trainer")
        self.scheduler = Scheduler(self.store, self.cfg, seed=fed_cfg.seed)
        self.mesh = mesh
        self._k = fed_cfg.clients_per_round
        self._dropout = fed_cfg.dropout_rate
        # a checkpointing trainer needs the *consumed* round's scheduler
        # state, not the live one (the prefetcher runs ahead) — capture a
        # snapshot per cohort at select time
        self._track_sched = bool(getattr(fed_cfg, "checkpoint_every", 0)
                                 or getattr(fed_cfg, "checkpoint_dir", None))
        if getattr(fed_cfg, "telemetry_dir", None):
            self.obs.configure(fed_cfg.telemetry_dir)
        if self.cfg.eval_clients is not None and \
                self.cfg.eval_clients < self.store.n_clients:
            eval_rng = np.random.default_rng(
                (self.cfg.seed if self.cfg.seed is not None
                 else fed_cfg.seed) + 0x5EED)
            self._eval_ids = np.sort(eval_rng.choice(
                self.store.n_clients, self.cfg.eval_clients, replace=False))
        else:
            self._eval_ids = np.arange(self.store.n_clients)

    # -- device placement --------------------------------------------------
    def _put(self, arrays):
        """Start the H2D transfer (sharded over the trainer mesh when one
        is present; plain async device_put otherwise). The span measures
        the *enqueue* — device_put is asynchronous — so long h2d spans
        mean host-side staging pressure, not transfer bandwidth."""
        with self.obs.span("h2d", rows=int(len(arrays[-1]))):
            return parallel_lib.shard_client_axis(self.mesh, arrays)

    def _n_shards(self) -> int:
        return parallel_lib.mesh_data_shards(self.mesh)

    def _shift_host(self, t, idx, arrays):
        """Apply the scripted distribution shift (if any) to one gathered
        host block — always before fault corruption and the H2D put."""
        if self.cfg.shift is None:
            return arrays
        x, y, n = arrays
        return (x, apply_shift(self.cfg.shift, self.store.n_clients,
                               self.store.n_classes, t, idx, y), n)

    def _gather_put(self, split: str, idx, t=None):
        """Store gather + H2D for a cohort. Over a ``ShardedClientStore``
        + a mesh this goes per shard: each data slice's rows are gathered
        and device_put separately, then assembled into one global array
        (``fed.parallel.put_sharded_cohort``) — no host-side concatenation
        of the full cohort, which is what a real multi-host deployment
        cannot do. Everything else takes the single-gather path. ``t`` is
        the shift clock of the round this gather feeds (None = no shift)."""
        store = self.store
        idx = np.asarray(idx, np.int64)
        if self.mesh is not None and isinstance(store, ShardedClientStore):
            parts = store._gather_shards(split, idx, self._n_shards())
            if parts is not None:
                if self.cfg.shift is not None:
                    slices = shard_cohort_slices(len(idx), self._n_shards())
                    parts = [self._shift_host(t, idx[lo:hi], p)
                             for (lo, hi), p in zip(slices, parts)]
                with self.obs.span("h2d", rows=int(len(idx))):
                    return parallel_lib.put_sharded_cohort(self.mesh, parts)
        return self._put(self._shift_host(t, idx, store._gather(split, idx)))

    def device_batch(self, idx):
        """(x, y, n) on device for an arbitrary id set. Ids inside the live
        cohort are sliced from its already-transferred arrays (the cold-
        start subset case); anything else is a fresh store gather (at the
        live cohort's shift clock)."""
        idx = np.asarray(idx)
        c = self._cohort
        if c is not None:
            pos = c.positions(idx)
            if pos is not None:
                if len(pos) == len(c.idx) and np.all(pos == np.arange(len(pos))):
                    return c.x, c.y, c.n
                return c.x[pos], c.y[pos], c.n[pos]
        return self._gather_put("train", idx, t=self.rounds_streamed - 1)

    # -- persistent state (per-shard async scatter) ------------------------
    def gather_local_flat(self, idx) -> np.ndarray:
        """Cohort rows of FeSEM's host ``local_flat`` table. Drains the
        async writer first, so a gather always observes every earlier
        scatter — the read side of the determinism guarantee."""
        self._writer.drain()
        return self.state.gather_local_flat(idx)

    def scatter_local_flat(self, idx, rows):
        """Write the cohort's updated ``local_flat`` rows back into the
        host table, split into per-data-shard slices and applied on the
        background writer thread — the round's host-side bookkeeping
        overlaps evaluation and the next cohort's gather instead of
        blocking the training loop (on multi-host, each host scatters
        its own slice)."""
        idx = np.asarray(idx)
        rows = np.asarray(rows)
        slices = shard_cohort_slices(len(idx), self._n_shards()) \
            or [(0, len(idx))]
        for lo, hi in slices:
            self._writer.submit(self.state.scatter_local_flat,
                                idx[lo:hi].copy(), rows[lo:hi],
                                label=f"scatter_local_flat[{hi - lo} rows]")

    # -- fault injection ---------------------------------------------------
    def _fault_spec(self, t: int) -> FaultSpec | None:
        return self.cfg.faults.spec(t) if self.cfg.faults is not None \
            else None

    def _apply_kill(self, spec: FaultSpec | None, idx: np.ndarray):
        """Mid-round client death: the cohort tail drops (forced newcomers
        stage first and survive), floored at one survivor so the round
        executor's >=1-client guarantee holds."""
        if spec is None or spec.kill <= 0 or len(idx) <= 1:
            return idx
        keep = max(len(idx) - int(spec.kill), 1)
        self.stats["killed_clients"] += len(idx) - keep
        return idx[:keep]

    def _corrupt(self, t: int, spec: FaultSpec | None, arrays,
                 lane0: int, total: int):
        """Poison the train features of this round's rng-chosen cohort
        lanes that fall inside [lane0, lane0 + chunk) — applied on the host
        arrays before the H2D put, so the device sees exactly what a
        byzantine / bit-flipped client upload would produce."""
        if spec is None or spec.corrupt <= 0:
            return arrays
        rng = np.random.default_rng([self.cfg.faults.seed, 0xFA017, t])
        lanes = rng.choice(total, min(int(spec.corrupt), total),
                           replace=False)
        x, y, n = arrays
        hit = lanes[(lanes >= lane0) & (lanes < lane0 + len(n))] - lane0
        if len(hit) == 0:
            return arrays
        x = np.asarray(x).copy()
        if spec.corrupt_mode == "nan":
            x[hit] = np.nan
        elif spec.corrupt_mode == "inf":
            x[hit] = np.inf
        elif spec.corrupt_mode == "scale":
            x[hit] *= spec.corrupt_scale
        else:
            raise ValueError(f"unknown corrupt_mode {spec.corrupt_mode!r}")
        self.stats["corrupted_clients"] += len(hit)
        return (x, y, n)

    def _pre_round_faults(self, t: int):
        """select + the pre-gather fault effects shared by the producer and
        the synchronous path -> (idx, n_new, spec, sched snapshot)."""
        idx, n_new = self.scheduler.select(t, self._k, self._dropout)
        snap = self.scheduler.snapshot() if self._track_sched else None
        spec = self._fault_spec(t)
        idx = self._apply_kill(spec, np.asarray(idx, np.int64))
        if spec is not None and spec.writer_crash:
            self.stats["writer_crashes"] += 1
            self._writer.inject_thread_crash()
        return idx, min(n_new, len(idx)), spec, snap

    def _stage_chunks(self, n: int):
        """Chunk step of an n-client staged gather."""
        return max(-(-n // max(int(self.cfg.stage_chunks), 1)), 1)

    # -- streamed cohorts --------------------------------------------------
    def _gather_staged(self, t: int, idx: np.ndarray, spec,
                       n_new: int = 0, snap: dict | None = None):
        """Producer-side chunked gather for the deadline path: host chunks
        land in a shared ``_Staging`` record so a consumer whose deadline
        fired can claim the staged prefix. Returns the full cohort's device
        arrays, or None when the consumer claimed (the producer abandons
        the round — the prefix is already being trained on)."""
        st = _Staging(t, idx, n_new, snap)
        step = self._stage_chunks(len(idx))
        n_chunks = -(-len(idx) // step)
        delay = spec.straggle / n_chunks \
            if spec is not None and spec.straggle > 0 else 0.0
        self._staging = st
        for lo in range(0, len(idx), step):
            if delay:
                time.sleep(delay)
            part = self._shift_host(
                t, idx[lo:lo + step],
                self.store._gather("train", idx[lo:lo + step]))
            part = self._corrupt(t, spec, part, lo, len(idx))
            with st.cond:
                if st.claimed:
                    return None
                st.parts.append(part)
                st.n_staged += len(part[2])
                st.cond.notify_all()
        with st.cond:
            if st.claimed:
                return None
            st.done = True
        return self._put(tuple(np.concatenate([p[i] for p in st.parts])
                               for i in range(3)))

    def _produce(self):
        try:
            for t in itertools.count(self.rounds_streamed):
                if self._stop.is_set():
                    return
                with self.obs.span("stage", t=t):
                    idx, n_new, spec, snap = self._pre_round_faults(t)
                    if self.cfg.deadline is not None:
                        arrays = self._gather_staged(t, idx, spec, n_new,
                                                     snap)
                        if arrays is None:  # consumer claimed the prefix
                            continue
                        x, y, n = arrays
                    elif spec is not None and (spec.straggle > 0 or
                                               spec.corrupt > 0):
                        if spec.straggle > 0:
                            time.sleep(spec.straggle)
                        host = self._shift_host(
                            t, idx, self.store._gather("train", idx))
                        x, y, n = self._put(
                            self._corrupt(t, spec, host, 0, len(idx)))
                    else:
                        x, y, n = self._gather_put("train", idx, t=t)
                    cohort = Cohort(t, idx, x, y, n, n_new,
                                    sched_state=snap)
                while not self._stop.is_set():
                    try:
                        self._queue.put(cohort, timeout=0.2)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # noqa: BLE001 — surfaced by next_cohort
            self._producer_error = e
            while not self._stop.is_set():
                try:                    # wake a blocked consumer
                    self._queue.put(None, timeout=0.2)
                    break
                except queue.Full:
                    continue

    def _claim_degraded(self, t: int, st: _Staging) -> Cohort | None:
        """Deadline fired and round t's staging record is live: claim the
        staged prefix (waiting, bounded only by chunk progress, for the
        >=1-client floor) and assemble a truncated cohort. Returns None if
        the producer finished the full cohort first (it is on the queue)."""
        with st.cond:
            while not st.done and not st.claimed and st.n_staged == 0:
                st.cond.wait(0.05)
            if st.done:
                return None
            st.claimed = True
            idx, x, y, n = st.take_prefix()
        dropped = len(st.idx) - len(idx)
        self.stats["deadline_rounds"] += 1
        self.stats["deadline_dropped_clients"] += dropped
        xd, yd, nd = self._put((x, y, n))
        return Cohort(t, idx, xd, yd, nd, min(st.n_new, len(idx)),
                      sched_state=st.sched_state)

    def _get_with_deadline(self, t: int) -> Cohort | None:
        """Prefetch-path queue get bounded by ``cfg.deadline``: when the
        full cohort is not ready in time, degrade to the staged prefix of
        the in-flight gather instead of barriering on the stragglers."""
        end = time.monotonic() + self.cfg.deadline
        while True:
            remaining = end - time.monotonic()
            if remaining <= 0:
                break
            try:
                return self._queue.get(timeout=min(remaining, 0.05))
            except queue.Empty:
                continue
        while True:
            st = self._staging
            if st is not None and st.t == t:
                cohort = self._claim_degraded(t, st)
                if cohort is not None:
                    return cohort
                return self._queue.get()    # full cohort won the race
            # staging for round t not visible yet (producer between
            # rounds, or the cohort is already enqueued)
            try:
                return self._queue.get(timeout=0.05)
            except queue.Empty:
                continue

    def _sync_cohort(self, t: int) -> Cohort:
        """The prefetch=0 path: selection + gather inline, with the same
        fault injection and (chunked) deadline degradation as the
        producer."""
        with self.obs.span("stage", t=t):
            idx, n_new, spec, snap = self._pre_round_faults(t)
            if self.cfg.deadline is None:
                if spec is not None and (spec.straggle > 0 or
                                         spec.corrupt > 0):
                    if spec.straggle > 0:
                        time.sleep(spec.straggle)
                    host = self._shift_host(
                        t, idx, self.store._gather("train", idx))
                    arrays = self._put(
                        self._corrupt(t, spec, host, 0, len(idx)))
                else:
                    arrays = self._gather_put("train", idx, t=t)
                return Cohort(t, idx, *arrays, n_new, sched_state=snap)
            step = self._stage_chunks(len(idx))
            n_chunks = -(-len(idx) // step)
            delay = spec.straggle / n_chunks \
                if spec is not None and spec.straggle > 0 else 0.0
            end = time.monotonic() + self.cfg.deadline
            parts, staged = [], 0
            for lo in range(0, len(idx), step):
                if staged > 0 and time.monotonic() >= end:
                    self.stats["deadline_rounds"] += 1
                    self.stats["deadline_dropped_clients"] += \
                        len(idx) - staged
                    idx = idx[:staged]
                    break
                if delay:
                    time.sleep(delay)
                part = self._shift_host(
                    t, idx[lo:lo + step],
                    self.store._gather("train", idx[lo:lo + step]))
                parts.append(self._corrupt(t, spec, part, lo, len(idx)))
                staged += len(part[2])
            arrays = self._put(tuple(np.concatenate([p[i] for p in parts])
                                     for i in range(3)))
            return Cohort(t, idx, *arrays, min(n_new, len(idx)),
                          sched_state=snap)

    def next_cohort(self) -> Cohort:
        """The next scheduled round batch, already on (or in flight to) the
        device. With ``prefetch=0`` selection+gather run synchronously —
        the no-overlap baseline the population bench compares against.
        With ``cfg.deadline`` set, the wait for the full cohort is bounded:
        past the deadline the round proceeds with the staged prefix
        (>= 1 client) and the dropped stragglers simply carry zero weight
        in the segment-sum (``stats`` counts the degraded rounds)."""
        if self.scheduler is None:
            raise RuntimeError("attach() a trainer first")
        if self._stop.is_set():
            raise RuntimeError("population was close()d — the cohort "
                               "stream cannot be resumed")
        if self.cfg.prefetch <= 0:
            cohort = self._sync_cohort(self.rounds_streamed)
        else:
            if self._thread is None:
                self._queue = queue.Queue(maxsize=self.cfg.prefetch)
                self._thread = threading.Thread(
                    target=self._produce, name="population-prefetch",
                    daemon=True)
                self._thread.start()
            if self.cfg.deadline is not None:
                cohort = self._get_with_deadline(self.rounds_streamed)
            else:
                cohort = self._queue.get()
            if cohort is None:          # producer died — re-raise its error
                raise RuntimeError(
                    "population prefetch thread failed"
                ) from self._producer_error
        self.rounds_streamed += 1
        self._cohort = cohort
        self._consumed_sched = cohort.sched_state
        return cohort

    def close(self):
        self._stop.set()
        if self._thread is not None:
            # drain so a producer blocked on put() can observe the stop flag
            try:
                while True:
                    self._queue.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None
        # flush + stop the async state writer (pending scatters land first;
        # bounded — a writer killed by a fault raises here instead of
        # deadlocking shutdown)
        self._writer.close()

    # -- checkpointing ------------------------------------------------------
    def ckpt_state(self):
        """(arrays, meta) snapshot of the streamed-population runtime state
        as of the last *consumed* round: scheduler stream (rng, active set,
        pending arrivals), lazy state-table rows, round counters. Drains
        the async writer first so every scatter is visible. Membership is
        excluded — the trainer checkpoints it (shared array)."""
        if self.scheduler is None:
            raise RuntimeError("attach() a trainer first")
        self._writer.drain()
        self.stats["writer_retries"] = self._writer.retries
        snap = self._consumed_sched
        if snap is None:
            if self.rounds_streamed and self.cfg.prefetch > 0 \
                    and not self._track_sched:
                raise RuntimeError(
                    "cannot checkpoint a prefetching population whose "
                    "trainer was attached without checkpointing enabled "
                    "(FedConfig.checkpoint_every / checkpoint_dir): the "
                    "live scheduler stream is already ahead of the "
                    "consumed round")
            # nothing consumed yet (or synchronous path): the live
            # scheduler state is exactly the post-consumed state
            snap = self.scheduler.snapshot()
        arrays = {"sched_active": snap["active"],
                  "sched_arrival_queue": np.asarray(snap["arrival_queue"],
                                                    np.int64),
                  "sched_last_arrivals": np.asarray(snap["last_arrivals"],
                                                    np.int64)}
        arrays.update(self.state.ckpt_arrays())
        meta = {"sched_rng": snap["rng_state"],
                "sched_rounds_scheduled": int(snap["rounds_scheduled"]),
                "rounds_streamed": int(self.rounds_streamed),
                "stats": {k: int(v) for k, v in self.stats.items()}}
        return arrays, meta

    def ckpt_restore(self, arrays: dict, meta: dict):
        """Rewind a *fresh* (attached, never-streamed) population to a
        ``ckpt_state`` snapshot: the prefetcher's next select re-draws the
        checkpointed run's next cohort bit for bit."""
        if self.scheduler is None:
            raise RuntimeError("attach() a trainer first, then restore")
        if self._thread is not None or self.rounds_streamed:
            raise RuntimeError(
                "checkpoint restore needs a fresh population — this one "
                "has already streamed cohorts")
        self.scheduler.restore({
            "rng_state": meta["sched_rng"],
            "active": np.asarray(arrays["sched_active"], bool),
            "arrival_queue": np.asarray(arrays["sched_arrival_queue"],
                                        np.int64),
            "last_arrivals": np.asarray(arrays["sched_last_arrivals"],
                                        np.int64),
            "rounds_scheduled": meta["sched_rounds_scheduled"]})
        self.state.ckpt_restore(arrays)
        self.rounds_streamed = int(meta["rounds_streamed"])
        # restored totals replace the fresh zeros (missing = old snapshot
        # schema inside a current-format archive: keep zeros for new keys);
        # the engine's registry restore then overwrites with the same
        # values from the unified "obs" snapshot when one is present
        self.obs.registry.reset([f"pop.{k}" for k in _STATS_ZERO])
        self.stats.update(meta.get("stats", {}))
        self._consumed_sched = self.scheduler.snapshot() \
            if self._track_sched else None

    # -- streamed evaluation ----------------------------------------------
    def eval_ids(self) -> np.ndarray:
        return self._eval_ids if self._eval_ids is not None \
            else np.arange(self.store.n_clients)

    def eval_batches(self, idx=None):
        """Yield device-resident (x_test, y_test, n_test) blocks of at most
        ``eval_batch`` clients — full-population eval without a full-
        population device allocation."""
        idx = self.eval_ids() if idx is None else np.asarray(idx)
        if len(idx) > 20_000 and not self._warned_eval_scale:
            self._warned_eval_scale = True
            import warnings
            warnings.warn(
                f"streaming evaluation over {len(idx)} clients every "
                f"round is O(N) host gather — set "
                f"PopulationConfig.eval_clients to subsample (grouped "
                f"trainers' eval only touches assigned members)",
                stacklevel=2)
        B = max(int(self.cfg.eval_batch), 1)
        for lo in range(0, len(idx), B):
            block = idx[lo:lo + B]
            x, y, n = self._gather_put("test", block,
                                       t=self.rounds_streamed - 1)
            yield block, x, y, n

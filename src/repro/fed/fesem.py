"""FeSEM (Xie et al. 2020, "Multi-Center Federated Learning").

ℓ2-distance stochastic EM: the server keeps m centers; each participating
client is assigned (E-step) to the center minimizing ||w_i − w_g||₂ between
its *local model* and the center, trains from that center, and centers are
recomputed (M-step) as weighted averages of their members' local models.

The ℓ2 distance on flattened HDLSS parameters is exactly what the paper's
EDC measure is designed to beat (distance concentration, §2.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import server as server_lib
from repro.fed.engine import FedAvgTrainer, FedConfig, RoundMetrics
from repro.models.modules import flatten_updates


class FeSEMTrainer(FedAvgTrainer):
    framework = "fesem"

    def __init__(self, model, data, cfg: FedConfig):
        super().__init__(model, data, cfg)
        self.m = cfg.n_groups
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed + 29), self.m)
        self.group_params = [model.init(k) for k in keys]
        self.membership = np.full(data.n_clients, -1, np.int64)
        # local models last seen per client (lazily initialized to center 0)
        self.local_flat = None

    def _flat(self, params):
        return np.asarray(flatten_updates(params))

    def round(self, t: int) -> RoundMetrics:
        idx = self._select()
        # FeSEM: server-side E-step, then 1 center down + 1 model up
        self.comm_params += 2 * len(idx) * self.model_size
        centers = np.stack([self._flat(p) for p in self.group_params])

        if self.local_flat is None:
            self.local_flat = np.zeros((self.data.n_clients,
                                        centers.shape[1]), np.float32)
            self.local_flat[:] = centers[0]

        # E-step: nearest center in ℓ2 over flattened parameters
        d2 = ((self.local_flat[idx][:, None, :] - centers[None]) ** 2).sum(-1)
        assign = d2.argmin(1)
        self.membership[idx] = assign

        disc_sum, disc_n = 0.0, 0
        new_flats = {}
        for j in range(self.m):
            members = idx[assign == j]
            if len(members) == 0:
                continue
            deltas, finals, n = self._solve(self.group_params[j], members)
            # M-step: center = weighted average of members' local models
            w = np.asarray(n, np.float64)
            w /= w.sum()
            avg = jax.tree_util.tree_map(
                lambda f: jnp.sum(f * jnp.asarray(w).reshape(
                    (-1,) + (1,) * (f.ndim - 1)), axis=0), finals)
            self.group_params[j] = avg
            flats = np.asarray(jax.vmap(flatten_updates)(finals))
            for mi, fi in zip(members, flats):
                new_flats[int(mi)] = fi
            diffs = jax.vmap(lambda f: server_lib.tree_norm(
                server_lib.tree_sub(f, avg)))(finals)
            disc_sum += float(jnp.sum(diffs))
            disc_n += len(members)
        for mi, fi in new_flats.items():
            self.local_flat[mi] = fi

        acc = self.evaluate_groups()
        m = RoundMetrics(t, acc, 0.0, disc_sum / max(disc_n, 1))
        self.history.add(m)
        return m

    def evaluate_groups(self) -> float:
        total_correct, total_n = 0, 0
        d = self.data
        for j in range(self.m):
            members = np.where(self.membership == j)[0]
            if len(members) == 0:
                continue
            correct = self.eval_fn(self.group_params[j],
                                   jnp.asarray(d.x_test[members]),
                                   jnp.asarray(d.y_test[members]),
                                   jnp.asarray(d.n_test[members]))
            total_correct += int(np.sum(np.asarray(correct)))
            total_n += int(d.n_test[members].sum())
        return total_correct / max(total_n, 1)

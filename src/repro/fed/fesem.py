"""FeSEM (Xie et al. 2020, "Multi-Center Federated Learning").

ℓ2-distance stochastic EM: the server keeps m centers; each participating
client is assigned (E-step) to the center minimizing ||w_i − w_g||₂ between
its *local model* and the center, trains from that center, and centers are
recomputed (M-step) as weighted averages of their members' local models.

The ℓ2 distance on flattened HDLSS parameters is exactly what the paper's
EDC measure is designed to beat (distance concentration, §2.2).

Both EM halves are fused into the round executor's single dispatch: the
E-step is the in-program assignment stage (``make_fesem_assign``) over
flattened centers, and the M-step is the executor's intra-group FedAvg
(center + avg_w(Δ) ≡ avg_w of the members' final local models). The
per-client flattened-model matrix ``local_flat`` is a persistent device
array updated by an in-program scatter (``fesem_state_update``) — the seed
implementation's host numpy matrix rebuilt through ``_flat()`` round-trips
every round survives only as ``fed.rounds.serial_fesem_round``.

In ``population=`` mode the (N, d_w) matrix stays host-resident in the
``ClientStateTable`` (lazy rows); each round gathers only the cohort's
(K, d_w) rows to device, runs the *same* compiled round with cohort-local
ids, and scatters the updated rows back — dynamic assignment keeps working
when the population no longer fits on device. The write-back goes through
``Population.scatter_local_flat``: split per data shard and applied on a
background writer thread (drained before any gather), so on a 2-D
``(data, model)`` mesh each simulated host scatters only its cohort
slice — see docs/scaling.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import rounds as rounds_lib
from repro.fed.engine import FedConfig, GroupedTrainer, RoundMetrics
from repro.models.modules import flatten_updates


def make_fesem_assign():
    """Assignment stage: argmin-ℓ2 E-step of each selected client's last
    local model against the flattened group centers. state:
    {"local_flat": (n_clients, d_w), "idx": (K,) selected client ids}."""
    def assign(group_params, X, Y, n, state):
        centers = jax.vmap(flatten_updates)(group_params)       # (m, d_w)
        local = state["local_flat"][state["idx"]]               # (K, d_w)
        d2 = jnp.sum(jnp.square(local[:, None, :] - centers[None]), -1)
        return jnp.argmin(d2, axis=1)

    return assign


def fesem_state_update(state, membership, deltas, finals):
    """Scatter the selected clients' new flattened local models back into
    the persistent (n_clients, d_w) device matrix — no host round-trip."""
    flat = jax.vmap(flatten_updates)(finals)                    # (K, d_w)
    return {"idx": state["idx"],
            "local_flat": state["local_flat"].at[state["idx"]].set(flat)}


class FeSEMTrainer(GroupedTrainer):
    framework = "fesem"

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        super().__init__(model, data, cfg, mesh=mesh, population=population)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed + 29), self.m)
        self.group_params = rounds_lib.stack_trees(
            [model.init(k) for k in keys])
        # local models last seen per client, initialized to center 0
        flat0 = flatten_updates(self.group_param(0))
        if population is not None:
            # population scale: the (N, d_w) matrix stays HOST-resident in
            # the state table (lazy rows, default = init center 0); only
            # the cohort's (K, d_w) rows are gathered to device per round
            self.local_flat = None
            population.state.init_local_flat(np.asarray(flat0))
        else:
            # pinned: lives on device for the in-program E-step gather /
            # M-step scatter
            self.local_flat = jnp.tile(flat0[None], (self.n_clients, 1))

    def _exec_spec(self) -> dict:
        return {"n_groups": self.m, "eta_g": 0.0,
                "assign_fn": make_fesem_assign(),
                "state_update_fn": fesem_state_update}

    # -- round-block carry: the (N, d_w) local-model matrix rides along ----
    def _block_kwargs(self) -> dict:
        kw = dict(self._exec_spec())
        # per-step E-step state from the carried matrix (idx already
        # redirected to the trash row for zero-weight padded lanes), and
        # the updated matrix back out of the M-step scatter
        kw["make_state"] = lambda aux, idx, mem: {"local_flat": aux,
                                                  "idx": idx}
        kw["state_to_aux"] = lambda st: st["local_flat"]
        return kw

    def _carry_aux(self):
        d_w = self.local_flat.shape[1]
        return jnp.concatenate(
            [self.local_flat, jnp.zeros((1, d_w), self.local_flat.dtype)])

    def _carry_refs(self, carry: dict):
        super()._carry_refs(carry)
        if carry["aux"] is not None:
            self.local_flat = carry["aux"][:-1]

    # -- async streaming: the E-step state rides each staged dispatch ------
    def _async_stream_arg(self, idx):
        # stage-time gather (drains the async writer, so every earlier
        # fold's scatter is visible) — the rows a real async client would
        # have trained from at dispatch time
        rows = jnp.asarray(self.population.gather_local_flat(idx))
        return {"local_flat": rows,
                "idx": jnp.arange(len(idx), dtype=jnp.int32)}

    def _async_adopt(self, out, idx, folded_groups, folded_global):
        super()._async_adopt(out, idx, folded_groups, folded_global)
        self.population.scatter_local_flat(
            idx, np.asarray(out.assign_state["local_flat"]))

    def round(self, t: int, idx=None) -> RoundMetrics:
        if idx is None:
            idx = self._select()
        # FeSEM: server-side E-step, then 1 center down + 1 model up
        self.comm_params += 2 * len(idx) * self.model_size
        x, y, n = self._client_batch(idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(idx))
        if self.population is not None:
            # state-table gather: cohort rows with cohort-local ids — the
            # executor program is byte-identical to the pinned one, the
            # E-step gather/M-step scatter just act on (K, d_w) instead of
            # the full (N, d_w). The population gather drains the async
            # writer first, so last round's per-shard scatters are visible.
            rows = jnp.asarray(self.population.gather_local_flat(idx))
            state = {"local_flat": rows,
                     "idx": jnp.arange(len(idx), dtype=jnp.int32)}
        else:
            state = {"local_flat": self.local_flat,
                     "idx": jnp.asarray(np.asarray(idx, np.int32))}
        out = self._round_executor()(self.group_params, state, x, y, n, keys)
        self.group_params = out.group_params
        if self.population is not None:
            # async per-shard write-back: overlaps evaluation + the next
            # cohort's H2D; the next gather_local_flat drains it first
            self.population.scatter_local_flat(
                idx, np.asarray(out.assign_state["local_flat"]))
        else:
            self.local_flat = out.assign_state["local_flat"]
        self._adopt_membership(idx, out.membership)
        acc = self._round_eval(t)
        self._fold_alive = len(idx)
        m = RoundMetrics(t, acc, float(out.mean_loss), float(out.discrepancy),
                         int(out.n_quarantined))
        self.history.add(m)
        return m

    # -- checkpointing: + the pinned (N, d_w) local-model matrix ------------
    # (population mode keeps the rows host-resident in the state table,
    # which checkpoints itself via Population.ckpt_state)
    def _ckpt_model_tree(self) -> dict:
        tree = super()._ckpt_model_tree()
        if self.local_flat is not None:
            tree["local_flat"] = self.local_flat
        return tree

    def _ckpt_load_model(self, tree: dict):
        super()._ckpt_load_model(tree)
        if "local_flat" in tree:
            self.local_flat = tree["local_flat"]

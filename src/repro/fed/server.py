"""Server-side aggregation primitives shared by every framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_delta(deltas_stacked, weights):
    """FedAvg aggregation: sum_i (n_i/n) Δw_i over a stacked client axis.

    deltas_stacked: pytree with leading client axis K; weights: (K,) raw
    (e.g. sample counts) — normalized here.
    """
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def agg(d):
        wb = w.reshape((-1,) + (1,) * (d.ndim - 1))
        return jnp.sum(d * wb, axis=0)

    return jax.tree_util.tree_map(agg, deltas_stacked)


def apply_delta(params, delta, scale: float = 1.0):
    return jax.tree_util.tree_map(lambda p, d: p + scale * d, params, delta)


def tree_mean(trees):
    """Plain average of a list of pytrees (the auxiliary global model)."""
    n = len(trees)
    return jax.tree_util.tree_map(lambda *xs: sum(xs) / n, *trees)


def tree_index(group_params, j: int):
    """j-th group's parameters from either a list of pytrees (IFCA/FeSEM)
    or an m-stacked pytree (FedGroup / the shared round executor)."""
    if isinstance(group_params, (list, tuple)):
        return group_params[j]
    return jax.tree_util.tree_map(lambda g: g[j], group_params)


def tree_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l))
                        for l in jax.tree_util.tree_leaves(tree)))


def tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_add(a, b):
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def inter_group_aggregate(group_params: list, eta_g: float):
    """Algorithm 2 lines 17-19: w_g <- w̃_g + η_G Σ_{l≠g} w̃_l / ||w̃_l||."""
    if eta_g <= 0.0 or len(group_params) == 1:
        return group_params
    norms = [tree_norm(p) for p in group_params]
    normed = [tree_scale(p, 1.0 / jnp.maximum(n, 1e-12))
              for p, n in zip(group_params, norms)]
    total = jax.tree_util.tree_map(lambda *xs: sum(xs), *normed)
    out = []
    for p, nm in zip(group_params, normed):
        others = tree_sub(total, nm)
        out.append(tree_add(p, tree_scale(others, eta_g)))
    return out

"""Host-resident client population store + persistent per-client state table.

The pinned trainers cap population size by device memory: they upload the
entire padded (N, max_n, ...) train/test stacks at init. The stores here
keep the population on the *host* — either as materialized numpy arrays
(``ArrayClientStore``, the small-N case and the equivalence oracle's
backing) or as a *virtual* population (``VirtualClientStore``) whose
per-client shards are generated lazily from a deterministic per-client
seed and optionally persisted as memory-mapped ``.npy`` shard files — and
expose one operation the streamed engine needs: ``gather_train/gather_test``
over an arbitrary cohort of client ids, returning padded host arrays ready
for one H2D transfer. Nothing the size of the population ever reaches the
device; only O(cohort) arrays do (see ``fed.population`` for the scheduler
and the double-buffered prefetcher that overlaps that transfer with the
running round).

``ClientStateTable`` is the persistent per-client state the dynamic
frameworks need once the population no longer fits on device: group
membership / cold flags (FedGroup eq. 9), FeSEM's flattened local models
(one (d_w,) row per *touched* client, default row elsewhere — the E-step
gathers cohort rows, the M-step scatter writes them back), and the cached
pre-training directions of cold-started clients. Rows are materialized
lazily so memory scales with the number of clients ever touched, not N.

``ShardedClientStore`` is the multi-host story (docs/scaling.md): it wraps
any inner store and decomposes every cohort gather into ``n_shards``
contiguous slices — shard ``s`` gathers exactly the rows the mesh's s-th
data-axis slice will hold, so on a real deployment each host touches only
its own slice (here the slices are simulated on one machine). The slice
arithmetic is a pure function:

>>> from repro.fed.store import shard_cohort_slices
>>> shard_cohort_slices(8, 4)                     # K=8 cohort, 4 shards
[(0, 2), (2, 4), (4, 6), (6, 8)]
>>> shard_cohort_slices(7, 4) is None             # non-divisible: fall back
True

``fed.parallel.put_sharded_cohort`` consumes the per-shard gathers (one
H2D put per shard into ``jax.make_array_from_single_device_arrays``), and
``fed.population`` scatters state-table writes back per shard
asynchronously.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict

import numpy as np

from repro.data.federated import FederatedData

# Seed-derivation tag for the cohort-selection rng stream. Both the pinned
# trainers' ``select_rng`` and the population ``Scheduler`` draw from
# ``default_rng([seed, SELECT_STREAM])`` — the same stream (streamed ==
# pinned bit-equivalence) but decorrelated from the trainers' cold-start /
# ablation ``default_rng(seed)`` stream, so the pre-training pool and the
# round-0 cohort are not the same deterministic draw.
SELECT_STREAM = 0x5E1EC7


class ClientStore:
    """Interface: a host-resident population of ``n_clients`` padded clients.

    Concrete stores implement ``_gather(split, idx)`` returning padded host
    arrays ``(x (K, max_n, *feat), y (K, max_n), n (K,))`` for a cohort.
    ``n_train`` / ``n_test`` are full (N,) host size vectors — cheap even at
    N=10^6 and needed by size-weighted sampling and weighted accuracy.
    """

    name: str
    n_clients: int
    n_classes: int
    max_train: int
    max_test: int
    feat: tuple
    n_train: np.ndarray
    n_test: np.ndarray

    def gather_train(self, idx):
        return self._gather("train", np.asarray(idx, np.int64))

    def gather_test(self, idx):
        return self._gather("test", np.asarray(idx, np.int64))

    def _gather(self, split, idx):
        raise NotImplementedError

    def materialize(self, name: str | None = None) -> FederatedData:
        """Full population as pinned-path ``FederatedData`` (small N only —
        this is exactly the materialization the streamed path avoids)."""
        ids = np.arange(self.n_clients)
        xt, yt, nt = self.gather_train(ids)
        xe, ye, ne = self.gather_test(ids)
        return FederatedData(name or self.name, xt, yt, nt, xe, ye, ne,
                             self.n_classes, {"store": self.name})


class ArrayClientStore(ClientStore):
    """A materialized ``FederatedData`` population behind the store API —
    the small-N backing and the streamed-vs-pinned equivalence oracle."""

    def __init__(self, data: FederatedData):
        self.data = data
        self.name = data.name
        self.n_clients = data.n_clients
        self.n_classes = data.n_classes
        self.max_train = data.x_train.shape[1]
        self.max_test = data.x_test.shape[1]
        self.feat = tuple(data.x_train.shape[2:])
        self.n_train = np.asarray(data.n_train)
        self.n_test = np.asarray(data.n_test)

    def _gather(self, split, idx):
        d = self.data
        if split == "train":
            return d.x_train[idx], d.y_train[idx], d.n_train[idx]
        return d.x_test[idx], d.y_test[idx], d.n_test[idx]


class VirtualClientStore(ClientStore):
    """Lazily generated population: client ``i``'s data is a pure function
    of ``i`` (``client_fn(i) -> {x, y, x_test, y_test}`` unpadded), so a
    10^5–10^6 client population costs only its (N,) size vectors until
    sampled. Two caching backends:

      * ``memmap_dir=None``: per-client LRU of the last ``cache_clients``
        generated clients (a revisited cohort is free, a cold one costs K
        generations).
      * ``memmap_dir=...``: clients are materialized in shard files of
        ``shard_clients`` clients as ``np.lib.format.open_memmap`` arrays
        the first time any member is touched; later gathers read the mapped
        rows — the population lives on disk, not in RAM.
    """

    def __init__(self, name: str, n_clients: int, client_fn, *,
                 max_train: int, max_test: int, feat: tuple, n_classes: int,
                 n_train: np.ndarray, n_test: np.ndarray,
                 memmap_dir: str | None = None, shard_clients: int = 64,
                 cache_clients: int = 4096, x_dtype=np.float32):
        self.name = name
        self.n_clients = int(n_clients)
        self.client_fn = client_fn
        self.max_train, self.max_test = int(max_train), int(max_test)
        self.feat = tuple(feat)
        self.n_classes = int(n_classes)
        self.n_train = np.asarray(n_train, np.int32)
        self.n_test = np.asarray(n_test, np.int32)
        assert self.n_train.shape == (self.n_clients,)
        assert int(self.n_train.max(initial=0)) <= self.max_train
        assert int(self.n_test.max(initial=0)) <= self.max_test
        self.x_dtype = x_dtype
        self.memmap_dir = memmap_dir
        self.shard_clients = int(shard_clients)
        self._shards = {}                      # shard id -> memmap arrays
        self._shard_locks = {}                 # shard id -> build lock
        self._cache = OrderedDict()            # client id -> padded tuple
        self.cache_clients = int(cache_clients)
        self._generated_ids = set()            # observability: lazy cost
        # the population prefetch thread gathers train cohorts while the
        # main thread's streamed eval gathers test blocks — serialize the
        # mutable backends (LRU dict, shard check-then-create)
        self._lock = threading.Lock()

    @property
    def generated_clients(self) -> int:
        """Distinct clients ever generated (the lazy-population cost —
        concurrent duplicate generation of one client counts once)."""
        return len(self._generated_ids)

    # -- per-client generation --------------------------------------------
    def _padded_client(self, i: int):
        c = self.client_fn(int(i))
        nt, ne = len(c["y"]), len(c["y_test"])
        if nt != self.n_train[i] or ne != self.n_test[i]:
            raise ValueError(
                f"client_fn({i}) produced {nt}/{ne} train/test samples, "
                f"size table says {self.n_train[i]}/{self.n_test[i]}")
        xt = np.zeros((self.max_train,) + self.feat, self.x_dtype)
        yt = np.zeros((self.max_train,), np.int32)
        xe = np.zeros((self.max_test,) + self.feat, self.x_dtype)
        ye = np.zeros((self.max_test,), np.int32)
        xt[:nt], yt[:nt] = c["x"], c["y"]
        if ne:
            xe[:ne], ye[:ne] = c["x_test"], c["y_test"]
        with self._lock:
            self._generated_ids.add(int(i))
        return xt, yt, xe, ye

    def _client(self, i: int):
        with self._lock:
            hit = self._cache.get(i)
            if hit is not None:
                self._cache.move_to_end(i)
                return hit
        out = self._padded_client(i)
        with self._lock:
            self._cache[i] = out
            while len(self._cache) > self.cache_clients:
                self._cache.popitem(last=False)
        return out

    # -- memmap shard backend ---------------------------------------------
    def _shard(self, s: int):
        """Materialize (or open) shard ``s`` of ``shard_clients`` clients.

        Freshness is decided by a ``done`` marker written only after the
        fill loop flushed (open_memmap('w+') creates the full-size .npy up
        front, so file existence alone would treat a shard half-written by
        a killed process as complete and serve zeros). Generation holds a
        per-shard lock only — concurrent gathers of other shards and the
        client LRU path are not serialized behind it.
        """
        with self._lock:
            arrs = self._shards.get(s)
            if arrs is not None:
                return arrs
            slock = self._shard_locks.setdefault(s, threading.Lock())
        with slock:
            with self._lock:
                arrs = self._shards.get(s)
                if arrs is not None:
                    return arrs
            arrs = self._open_or_build_shard(s)     # global lock NOT held
            with self._lock:
                self._shards[s] = arrs
        return arrs

    def _open_or_build_shard(self, s: int):
        lo = s * self.shard_clients
        hi = min(lo + self.shard_clients, self.n_clients)
        rows = hi - lo
        os.makedirs(self.memmap_dir, exist_ok=True)
        paths = {k: os.path.join(self.memmap_dir, f"{k}_{s:06d}.npy")
                 for k in ("xt", "yt", "xe", "ye")}
        done = os.path.join(self.memmap_dir, f"done_{s:06d}")
        shapes = {"xt": (rows, self.max_train) + self.feat,
                  "yt": (rows, self.max_train),
                  "xe": (rows, self.max_test) + self.feat,
                  "ye": (rows, self.max_test)}
        dtypes = {"xt": self.x_dtype, "yt": np.int32,
                  "xe": self.x_dtype, "ye": np.int32}
        fresh = not os.path.exists(done)
        mode = "w+" if fresh else "r"
        arrs = {k: np.lib.format.open_memmap(
            paths[k], mode=mode, dtype=dtypes[k], shape=shapes[k] if fresh
            else None) for k in paths}
        if fresh:
            for r, i in enumerate(range(lo, hi)):
                xt, yt, xe, ye = self._padded_client(i)
                arrs["xt"][r], arrs["yt"][r] = xt, yt
                arrs["xe"][r], arrs["ye"][r] = xe, ye
            for a in arrs.values():
                a.flush()
            with open(done, "w") as f:          # marks the shard complete
                f.write("ok\n")
        return arrs

    def _gather(self, split, idx):
        K = len(idx)
        xk, yk = ("xt", "yt") if split == "train" else ("xe", "ye")
        max_n = self.max_train if split == "train" else self.max_test
        x = np.empty((K, max_n) + self.feat, self.x_dtype)
        y = np.empty((K, max_n), np.int32)
        if self.memmap_dir is not None:
            for r, i in enumerate(idx):
                arrs = self._shard(int(i) // self.shard_clients)
                row = int(i) % self.shard_clients
                x[r], y[r] = arrs[xk][row], arrs[yk][row]
        else:
            pick = {"xt": 0, "yt": 1, "xe": 2, "ye": 3}
            for r, i in enumerate(idx):
                c = self._client(int(i))
                x[r], y[r] = c[pick[xk]], c[pick[yk]]
        n = (self.n_train if split == "train" else self.n_test)[idx]
        return x, y, n


def shard_cohort_slices(K: int, n_shards: int):
    """Contiguous equal (lo, hi) cohort slices, one per data shard — the
    exact row blocks a leading-axis NamedSharding over the data axes
    assigns to each slice. None when ``n_shards`` does not divide ``K``
    (callers then fall back to the replicated single-gather path, matching
    ``fed.parallel.shard_client_axis``'s non-divisible degradation)."""
    if n_shards <= 0 or K % n_shards:
        return None
    block = K // n_shards
    return [(s * block, (s + 1) * block) for s in range(n_shards)]


class ShardedClientStore(ClientStore):
    """Host-sharded population view: ``n_shards`` simulated hosts, each
    gathering only its cohort slice.

    Wraps any inner ``ClientStore`` (materialized, virtual, memmapped) and
    keeps its metadata/size vectors; the one behavioural change is that
    gathers decompose per shard. ``gather_train_shards`` /
    ``gather_test_shards`` return the per-shard padded host arrays (shard
    ``s`` covers cohort rows ``[s*K/S, (s+1)*K/S)`` — the rows the mesh's
    s-th data slice owns, so each simulated host's gather is exactly what
    that host would fetch from its local store partition), and the plain
    ``ClientStore`` API is the concatenation of the shard gathers — a
    ``ShardedClientStore`` is drop-in wherever a store is accepted, with
    bit-identical cohorts (tests/test_mesh2d.py proves the round trip).
    """

    def __init__(self, inner: ClientStore, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.inner = inner
        self.n_shards = int(n_shards)
        self.name = f"{inner.name}@sharded{n_shards}"
        self.n_clients = inner.n_clients
        self.n_classes = inner.n_classes
        self.max_train = inner.max_train
        self.max_test = inner.max_test
        self.feat = inner.feat
        self.n_train = inner.n_train
        self.n_test = inner.n_test

    def _gather_shards(self, split: str, idx, n_shards: int | None = None):
        """-> list of per-shard (x, y, n) host tuples, or None when the
        shard count does not divide the cohort size."""
        idx = np.asarray(idx, np.int64)
        slices = shard_cohort_slices(len(idx),
                                     n_shards or self.n_shards)
        if slices is None:
            return None
        return [self.inner._gather(split, idx[lo:hi]) for lo, hi in slices]

    def gather_train_shards(self, idx, n_shards: int | None = None):
        return self._gather_shards("train", idx, n_shards)

    def gather_test_shards(self, idx, n_shards: int | None = None):
        return self._gather_shards("test", idx, n_shards)

    def _gather(self, split, idx):
        parts = self._gather_shards(split, idx)
        if parts is None:                     # non-divisible cohort
            return self.inner._gather(split, idx)
        return tuple(np.concatenate([p[i] for p in parts])
                     for i in range(3))


class _LazyRows:
    """(N, d) row table materialized per touched row: a shared default row
    plus an id -> row dict — FeSEM's local_flat and the pre-training-
    direction cache at population scale (memory ∝ clients touched)."""

    def __init__(self, default_row: np.ndarray):
        self.default_row = np.asarray(default_row, np.float32)
        self.rows = {}

    def gather(self, idx) -> np.ndarray:
        out = np.empty((len(idx),) + self.default_row.shape, np.float32)
        for r, i in enumerate(np.asarray(idx)):
            row = self.rows.get(int(i))
            out[r] = self.default_row if row is None else row
        return out

    def scatter(self, idx, rows):
        rows = np.asarray(rows, np.float32)
        for r, i in enumerate(np.asarray(idx)):
            self.rows[int(i)] = rows[r].copy()

    def delete(self, idx):
        """Drop materialized rows (untouched ids are a no-op) — the shift
        detector's cache invalidation: a deleted row reads back as the
        default until the next scatter re-materializes it."""
        for i in np.asarray(idx).ravel():
            self.rows.pop(int(i), None)

    def has(self, idx) -> np.ndarray:
        """(len(idx),) bool — which ids have a materialized (non-default)
        row."""
        return np.array([int(i) in self.rows for i in np.asarray(idx)],
                        bool)

    def __len__(self):
        return len(self.rows)

    # -- checkpointing ------------------------------------------------------
    def ckpt_arrays(self) -> dict:
        """Dense snapshot {ids, rows, default} — the touched-row count is
        only known at save time, so restorers rebuild the load template
        from ``checkpoint.io.saved_array_specs``."""
        ids = np.fromiter(self.rows.keys(), np.int64, len(self.rows))
        order = np.argsort(ids)
        ids = ids[order]
        rows = (np.stack([self.rows[int(i)] for i in ids])
                if len(ids) else
                np.zeros((0,) + self.default_row.shape, np.float32))
        return {"ids": ids, "rows": rows, "default": self.default_row}

    @classmethod
    def from_ckpt(cls, arrays: dict) -> "_LazyRows":
        table = cls(np.asarray(arrays["default"], np.float32))
        rows = np.asarray(arrays["rows"], np.float32)
        for r, i in enumerate(np.asarray(arrays["ids"])):
            table.rows[int(i)] = rows[r].copy()
        return table


class ClientStateTable:
    """Persistent per-client state, gathered/scattered per cohort.

    membership  (N,) int64 group id, -1 = cold (never assigned) — shared by
                reference with the trainer so existing in-place writes
                (``trainer.membership[idx] = ...``) persist across cohorts.
    local_flat  lazy (N, d_w) rows: FeSEM's per-client flattened local
                models (host-resident replacement for the pinned device
                matrix).
    pretrain_dir lazy (N, d_w) rows: the eq.-9 pre-training update
                direction cached at client cold start (newcomer analytics /
                re-clustering reuse it without re-running pre-training).
    """

    def __init__(self, n_clients: int):
        self.n_clients = int(n_clients)
        self.membership = np.full(self.n_clients, -1, np.int64)
        self._local_flat = None
        self._pretrain_dir = None
        self.group_version = None      # (m,) int64 per-group staleness clock

    # -- per-group staleness clocks (async runtime) -------------------------
    def init_group_version(self, m: int) -> np.ndarray:
        """Lazily create (and share by reference with the trainer, like
        ``membership``) the per-group version counters the async runtime's
        staleness weighting reads: version[g] increments every time a fold
        lands clients in group g, and a dispatch's staleness is the gap
        between the clock at stage time and at fold time."""
        if self.group_version is None:
            self.group_version = np.zeros(int(m), np.int64)
        return self.group_version

    # -- cold flags --------------------------------------------------------
    def cold_mask(self) -> np.ndarray:
        return self.membership < 0

    def cold_ids(self, idx) -> np.ndarray:
        idx = np.asarray(idx)
        return idx[self.membership[idx] < 0]

    # -- FeSEM local models ------------------------------------------------
    def init_local_flat(self, default_row: np.ndarray):
        if self._local_flat is None:
            self._local_flat = _LazyRows(default_row)

    def gather_local_flat(self, idx) -> np.ndarray:
        assert self._local_flat is not None, "init_local_flat first"
        return self._local_flat.gather(idx)

    def scatter_local_flat(self, idx, rows):
        self._local_flat.scatter(idx, rows)

    # -- cached pre-training directions -------------------------------------
    def set_pretrain_dir(self, idx, rows):
        rows = np.asarray(rows, np.float32)
        if self._pretrain_dir is None:
            self._pretrain_dir = _LazyRows(np.zeros(rows.shape[-1]))
        self._pretrain_dir.scatter(idx, rows)

    def get_pretrain_dir(self, idx) -> np.ndarray | None:
        if self._pretrain_dir is None:
            return None
        return self._pretrain_dir.gather(idx)

    def has_pretrain_dir(self, idx) -> np.ndarray:
        """(len(idx),) bool — which clients have a cached eq.-9 direction."""
        if self._pretrain_dir is None:
            return np.zeros(len(np.asarray(idx)), bool)
        return self._pretrain_dir.has(idx)

    def invalidate_pretrain_dir(self, idx):
        """Drop cached eq.-9 directions (shift migration / re-cold-start):
        a migrated client's next cold start must recompute its direction
        from fresh pre-training instead of reusing the stale cached row."""
        if self._pretrain_dir is not None:
            self._pretrain_dir.delete(idx)

    def touched_rows(self) -> int:
        return sum(len(t) for t in (self._local_flat, self._pretrain_dir)
                   if t is not None)

    # -- checkpointing ------------------------------------------------------
    _CKPT_TABLES = (("local_flat", "_local_flat"),
                    ("pretrain_dir", "_pretrain_dir"))

    def ckpt_arrays(self) -> dict:
        """Flat array dict of the lazy row tables, prefixed per table.
        Membership is checkpointed by the trainer, which shares the array
        by reference, so it is deliberately absent here."""
        out = {}
        for name, attr in self._CKPT_TABLES:
            table = getattr(self, attr)
            if table is not None:
                for k, v in table.ckpt_arrays().items():
                    out[f"{name}_{k}"] = v
        return out

    def ckpt_restore(self, arrays: dict):
        """Rebuild the lazy row tables from a ``ckpt_arrays`` snapshot
        (tables absent from the snapshot were never initialised at save
        time and are left as-is)."""
        for name, attr in self._CKPT_TABLES:
            if f"{name}_ids" in arrays:
                sub = {k: np.asarray(arrays[f"{name}_{k}"])
                       for k in ("ids", "rows", "default")}
                setattr(self, attr, _LazyRows.from_ckpt(sub))

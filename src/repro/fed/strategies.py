"""Pluggable assignment-strategy zoo behind the fused round's
``assign_fn``/``state_update_fn`` stage.

The round executor (``fed.rounds``) already runs IFCA's argmin-loss and
FeSEM's argmin-ℓ2 cluster estimation *inside* the compiled round; this
module turns that stage into a registry of strategies and adds two more
measures from the follow-up literature — both sharing the same compiled
fused round, no new dispatches:

  fedclust  partial-weight cosine similarity (FedClust, arXiv 2403.04144):
            each client is assigned to the group whose flattened center is
            most cosine-similar on the *trailing* ``d_head`` coordinates of
            the flattened weights (the classifier head under the repo's
            flatten order — the layer FedClust finds most label-skew
            sensitive). Rides FeSEM's persistent per-client ``local_flat``
            state (E-step gather / M-step scatter) unchanged.
  lcfl      local-loss assignment with hysteresis (LCFL, arXiv
            2407.09360): per-client loss under all m stacked models like
            IFCA, but a client *keeps* its current group unless a rival
            beats it by more than a multiplicative ``margin`` — loss-driven
            clustering without IFCA's churn near decision boundaries. The
            assignment state is the cohort's current membership row, so
            the strategy is stateful but carries nothing new.

Every strategy registers a :class:`StrategySpec`; the registry is the
single source the tests iterate for the generic invariance properties
(permutation equivariance over clients, group-relabel invariance) and the
serial-oracle equivalence checks:

>>> from repro.fed import strategies
>>> strategies.available_strategies()
['fedclust', 'fesem', 'ifca', 'lcfl', 'static']
>>> strategies.get_strategy('lcfl').state_kind
'membership'

Serial host references (``serial_fedclust_round`` / ``serial_lcfl_round``)
mirror ``fed.rounds.serial_ifca_round`` / ``serial_fesem_round``: numpy
assignment + the retired per-group solver loop, kept as the equivalence
oracles for tests/test_strategies.py.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures
from repro.fed import client as client_lib
from repro.fed import rounds as rounds_lib
from repro.fed.engine import FedConfig, GroupedTrainer, RoundMetrics
from repro.fed.fesem import FeSEMTrainer, fesem_state_update
from repro.models.modules import flatten_updates


# ---------------------------------------------------------------------------
# FedClust: partial-weight cosine similarity
# ---------------------------------------------------------------------------
def fedclust_head_dim(d_w: int, frac: float) -> int:
    """Static head width: the trailing ``frac`` of the ``d_w`` flattened
    coordinates, at least 1 (``FedConfig.fedclust_frac``)."""
    return max(1, min(int(d_w), int(float(frac) * int(d_w))))


def make_fedclust_assign(d_head: int):
    """Assignment stage: argmax cosine similarity between each selected
    client's local model and the group centers, compared on the trailing
    ``d_head`` flattened coordinates only. Same state as FeSEM:
    {"local_flat": (n_clients, d_w), "idx": (K,) selected client ids}."""
    def assign(group_params, X, Y, n, state):
        centers = jax.vmap(flatten_updates)(group_params)   # (m, d_w)
        local = state["local_flat"][state["idx"]]           # (K, d_w)
        sim = measures.cosine_similarity_matrix(
            local[:, -d_head:], centers[:, -d_head:])       # (K, m)
        return jnp.argmax(sim, axis=1)

    return assign


def serial_fedclust_assign(centers, local_flat, d_head: int) -> np.ndarray:
    """Host numpy oracle of ``make_fedclust_assign``: row-normalized
    (epsilon-guarded, exactly ``measures.row_normalize``) trailing-head
    cosine argmax."""
    c = np.asarray(centers, np.float32)[:, -d_head:]
    l = np.asarray(local_flat, np.float32)[:, -d_head:]
    cn = c / np.maximum(np.linalg.norm(c, axis=1, keepdims=True), 1e-12)
    ln = l / np.maximum(np.linalg.norm(l, axis=1, keepdims=True), 1e-12)
    sim = np.clip(ln @ cn.T, -1.0, 1.0)
    return sim.argmax(1)


def serial_fedclust_round(batch_solver, group_params_list, local_flat,
                          X, Y, n, keys, *, d_head: int):
    """The would-be-retired FedClust round: host partial-weight cosine
    E-step, one solver launch per non-empty cluster, host rebuild of the
    per-client flattened-model matrix — the equivalence oracle for the
    fused strategy (mirrors ``fed.rounds.serial_fesem_round``)."""
    centers = np.stack([np.asarray(flatten_updates(p))
                        for p in group_params_list])
    membership = serial_fedclust_assign(centers, local_flat, d_head)
    new_list, disc, finals_by_client = rounds_lib._serial_group_update(
        batch_solver, group_params_list, membership, X, Y, n, keys,
        collect_finals=True)
    new_local = np.asarray(local_flat).copy()
    for mi, fi in finals_by_client.items():
        new_local[mi] = fi
    return new_list, membership, new_local, disc


class FedClustTrainer(FeSEMTrainer):
    """FedClust = FeSEM's persistent local-model state + partial-weight
    cosine assignment. Everything else — the pinned device matrix vs the
    population's lazy host rows, the block carry, the async stream state,
    checkpointing — is inherited unchanged."""

    framework = "fedclust"

    def _exec_spec(self) -> dict:
        return {"n_groups": self.m, "eta_g": 0.0,
                "assign_fn": make_fedclust_assign(
                    fedclust_head_dim(self.model_size,
                                      self.cfg.fedclust_frac)),
                "state_update_fn": fesem_state_update}


# ---------------------------------------------------------------------------
# LCFL: local-loss assignment with hysteresis
# ---------------------------------------------------------------------------
def make_lcfl_assign(model, margin: float):
    """Assignment stage: per-client loss under all m stacked models (like
    IFCA), but a client with a current group keeps it unless the best
    rival's loss undercuts it by more than the multiplicative ``margin``
    (``FedConfig.lcfl_margin``). state: the cohort's (K,) current group
    ids, -1 = never assigned (always takes the argmin)."""
    loss_one = client_lib.client_mean_loss(model)

    def assign(group_params, X, Y, n, state):
        per_client = jax.vmap(loss_one, in_axes=(None, 0, 0, 0))
        losses = jax.vmap(lambda gp: per_client(gp, X, Y, n))(group_params)
        m = losses.shape[0]                                  # (m, K)
        best = jnp.argmin(losses, axis=0).astype(jnp.int32)
        best_loss = jnp.min(losses, axis=0)
        cur = state.astype(jnp.int32)
        valid = (cur >= 0) & (cur < m)
        cur_c = jnp.clip(cur, 0, m - 1)
        cur_loss = jnp.take_along_axis(losses, cur_c[None, :], axis=0)[0]
        keep = valid & (cur_loss <= best_loss * (1.0 + margin))
        return jnp.where(keep, cur_c, best)

    return assign


def serial_lcfl_assign(losses, cur, margin: float) -> np.ndarray:
    """Host numpy oracle of the LCFL hysteresis rule. losses: (m, K)
    per-client losses under each group model; cur: (K,) current ids."""
    losses = np.asarray(losses)
    m = losses.shape[0]
    best = losses.argmin(0)
    best_loss = losses.min(0)
    cur = np.asarray(cur)
    valid = (cur >= 0) & (cur < m)
    cur_c = np.clip(cur, 0, m - 1)
    cur_loss = np.take_along_axis(losses, cur_c[None, :], axis=0)[0]
    keep = valid & (cur_loss <= best_loss * (1.0 + margin))
    return np.where(keep, cur_c, best).astype(np.int64)


def serial_lcfl_round(batch_solver, loss_fn, group_params_list, cur,
                      X, Y, n, keys, *, margin: float):
    """The would-be-retired LCFL round: one loss dispatch per group, the
    host hysteresis rule, one solver launch per non-empty cluster — the
    equivalence oracle for the fused strategy (mirrors
    ``fed.rounds.serial_ifca_round``)."""
    losses = np.stack([np.asarray(loss_fn(p, X, Y, n))
                       for p in group_params_list])
    membership = serial_lcfl_assign(losses, cur, margin)
    new_list, disc, _ = rounds_lib._serial_group_update(
        batch_solver, group_params_list, membership, X, Y, n, keys)
    return new_list, membership, disc


class LCFLTrainer(GroupedTrainer):
    """Loss-driven clustering with hysteresis: IFCA's m-model broadcast
    and in-program loss argmin, plus a stickiness margin read from the
    persistent membership column — the assignment state is the cohort's
    current group ids, nothing new is carried."""

    framework = "lcfl"

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        super().__init__(model, data, cfg, mesh=mesh, population=population)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed + 37), self.m)
        # random cluster-center initializations, like IFCA
        self.group_params = rounds_lib.stack_trees(
            [model.init(k) for k in keys])

    def _exec_spec(self) -> dict:
        return {"n_groups": self.m, "eta_g": 0.0,
                "assign_fn": make_lcfl_assign(self.model,
                                              self.cfg.lcfl_margin)}

    def _stage_comm(self, k: int):
        # like IFCA: the client needs every group model to score it
        self.comm_params += (self.m + 1) * k * self.model_size

    def _block_kwargs(self) -> dict:
        kw = dict(self._exec_spec())
        # per-step assignment state = the carried membership's cohort rows
        # (padded lanes are redirected to the trash row, whose -1 reads as
        # "never assigned" — they aggregate with weight 0 regardless)
        kw["make_state"] = lambda aux, idx, mem: mem[idx]
        return kw

    def _async_stream_arg(self, idx):
        return jnp.asarray(self.membership[idx], jnp.int32)

    def round(self, t: int, idx=None) -> RoundMetrics:
        if idx is None:
            idx = self._select()
        self.comm_params += (self.m + 1) * len(idx) * self.model_size
        x, y, n = self._client_batch(idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(idx))
        out = self._round_executor()(
            self.group_params, jnp.asarray(self.membership[idx], jnp.int32),
            x, y, n, keys)
        self.group_params = out.group_params
        self._adopt_membership(idx, out.membership)
        acc = self._round_eval(t)
        self._fold_alive = len(idx)
        m = RoundMetrics(t, acc, float(out.mean_loss), float(out.discrepancy),
                         int(out.n_quarantined))
        self.history.add(m)
        return m


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
class StrategySpec(NamedTuple):
    """One registered assignment strategy.

    state_kind names the shape of the ``assign_fn``'s state argument so
    generic harnesses (the property tests) can build one:
      "static"      no assign_fn — membership is fixed server state
      "none"        assign_fn ignores its state (IFCA)
      "membership"  (K,) int32 current group ids, -1 = cold (LCFL)
      "local_flat"  {"local_flat": (N, d_w), "idx": (K,)} (FeSEM, FedClust)
    """
    name: str
    trainer: type
    state_kind: str
    make_assign: Callable | None    # (model, d_w, cfg) -> assign_fn
    description: str


_REGISTRY: dict[str, StrategySpec] = {}


def register(spec: StrategySpec) -> StrategySpec:
    if spec.state_kind not in ("static", "none", "membership", "local_flat"):
        raise ValueError(f"unknown state_kind {spec.state_kind!r}")
    if spec.name in _REGISTRY:
        raise ValueError(f"strategy {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_strategy(name: str) -> StrategySpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; available: "
                       f"{available_strategies()}") from None


def available_strategies() -> list:
    return sorted(_REGISTRY)


def make_trainer(name: str, model, data, cfg: FedConfig, mesh=None,
                 population=None):
    """Construct the registered strategy's trainer (the zoo entry point)."""
    spec = get_strategy(name)
    return spec.trainer(model, data, cfg, mesh=mesh, population=population)


def _register_builtin():
    from repro.core.fedgroup import FedGroupTrainer
    from repro.fed.fesem import make_fesem_assign
    from repro.fed.ifca import IFCATrainer, make_ifca_assign

    register(StrategySpec(
        "static", FedGroupTrainer, "static", None,
        "FedGroup eq.-9 cold-start assignment, static thereafter "
        "(optionally shift-migrated via FedConfig.shift_threshold)"))
    register(StrategySpec(
        "ifca", IFCATrainer, "none",
        lambda model, d_w, cfg: make_ifca_assign(model),
        "per-round argmin mean local loss over all m models"))
    register(StrategySpec(
        "fesem", FeSEMTrainer, "local_flat",
        lambda model, d_w, cfg: make_fesem_assign(),
        "argmin-l2 E-step of local models against flattened centers"))
    register(StrategySpec(
        "fedclust", FedClustTrainer, "local_flat",
        lambda model, d_w, cfg: make_fedclust_assign(
            fedclust_head_dim(d_w, cfg.fedclust_frac)),
        "argmax partial-weight (trailing-head) cosine similarity"))
    register(StrategySpec(
        "lcfl", LCFLTrainer, "membership",
        lambda model, d_w, cfg: make_lcfl_assign(model, cfg.lcfl_margin),
        "argmin local loss with multiplicative hysteresis margin"))


_register_builtin()

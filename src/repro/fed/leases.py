"""Cohort/job leases: bounded waits with timeout, requeue and capped
exponential backoff — the shared failure-detection primitive of the async
runtime (PR 7) and the coordinator/worker control plane.

A *lease* is the unit of at-least-once work handoff: whoever dispatches a
unit of work (an in-device async cohort dispatch, a fleet worker's round
job) holds a lease with a monotonic-clock deadline. A lease whose result
is not ready by the deadline — or whose holder is declared dead by the
heartbeat monitor — is *abandoned and requeued* with capped exponential
backoff, and re-dispatched against the then-current state. After
``max_retries`` requeues the work is declared unrecoverable (not merely
slow) and the run raises with a clear error instead of retrying forever.

``fed.engine._run_async`` and ``launch.coordinator.Coordinator`` share
this module; the engine's ``_AsyncLease`` is the :class:`Lease` here.

>>> from repro.fed.leases import RetryPolicy, backoff_delay
>>> backoff_delay(0, 0.05, 1.0)
0.05
>>> backoff_delay(10, 0.05, 1.0)          # capped
1.0
>>> RetryPolicy(timeout=30.0, max_retries=3).deadline(100.0)
130.0
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple


@dataclass
class Lease:
    """One in-flight dispatch: the staged inputs (kept so an expired lease
    can be re-dispatched against the then-current state), the per-group
    version clock snapshot taken at dispatch (staleness at fold = clock
    now − snapshot), the result/metric references the loop polls for
    readiness, the monotonic expiry deadline, how many leases for this
    work unit already expired (drives the requeue backoff), and — on the
    fleet path — which worker holds it and under which job id."""
    staged: tuple
    version: object = None
    result: object = None
    metrics: object = None
    deadline: float = 0.0
    attempts: int = 0
    holder: object = None
    job_id: int = -1


class RetryPolicy(NamedTuple):
    """Timeout/requeue/backoff knobs of one lease domain (the engine's
    ``async_lease_timeout``/``async_max_retries``/``async_backoff``/
    ``async_backoff_cap``; the fleet's ``FleetConfig`` equivalents)."""
    timeout: float = 30.0
    max_retries: int = 3
    backoff: float = 0.05
    backoff_cap: float = 1.0

    def deadline(self, now: float) -> float:
        return now + self.timeout


def backoff_delay(attempts: int, backoff: float, cap: float) -> float:
    """Capped exponential backoff: ``min(backoff * 2^attempts, cap)``."""
    return min(backoff * (2.0 ** attempts), cap)


class RequeueBuffer:
    """Expired leases waiting out their backoff before re-dispatch.

    Entries are ``(ready_at, staged, attempts)``; ``pop_ready`` returns
    the first entry whose backoff has elapsed (FIFO among ready ones, so
    re-dispatch order is deterministic), ``earliest`` the soonest
    ready-at time (for sleep-instead-of-spin waits when nothing else is
    in flight)."""

    def __init__(self):
        self._items = []

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def push(self, lease: Lease, policy: RetryPolicy, now: float,
             what: str = "async cohort",
             timeout_key: str = "async_lease_timeout",
             retries_key: str = "async_max_retries") -> float:
        """Requeue an expired lease; returns the backoff delay applied.
        Raises ``RuntimeError`` when the retry budget is exhausted — the
        work unit is unrecoverable, not merely slow. ``timeout_key`` /
        ``retries_key`` name the caller's config knobs in that error
        (the engine's ``async_*`` names by default; the fleet passes its
        ``FleetConfig`` field names)."""
        attempts = lease.attempts + 1
        if attempts > policy.max_retries:
            raise RuntimeError(
                f"{what} lease expired {attempts} times "
                f"({timeout_key}={policy.timeout}s, "
                f"{retries_key}={policy.max_retries}) — the "
                f"{what.split()[-1]} is unrecoverable, not merely slow")
        delay = backoff_delay(lease.attempts, policy.backoff,
                              policy.backoff_cap)
        self._items.append((now + delay, lease.staged, attempts))
        return delay

    def pop_ready(self, now: float):
        """``(staged, attempts)`` of the first backoff-elapsed entry, or
        None when every entry is still backing off (or the buffer is
        empty)."""
        for i, (ready_at, staged, attempts) in enumerate(self._items):
            if ready_at <= now:
                self._items.pop(i)
                return staged, attempts
        return None

    def earliest(self):
        """Soonest ready-at time, or None when empty."""
        return min((r for r, _, _ in self._items), default=None)

"""Client-side local optimization (Algorithm 1 ClientUpdate + FedProx variant).

The solver is built once per (model, hyperparams) and vmapped over a client
axis — on a TPU mesh that axis is sharded over "data" (see fed/parallel.py),
which is the TPU-native replacement for the paper's sequential client loop.

Every client's data is padded to a fixed max size; batches are drawn
uniformly from the valid prefix. The number of SGD steps is
``E * ceil(n_i / B)`` (per the paper: E local epochs of mini-batch SGD),
masked inside a fixed-trip-count ``fori_loop`` so one compiled program serves
all client sizes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.paper_models import ModelSpec


def make_local_solver(model: ModelSpec, *, epochs: int, batch_size: int,
                      lr: float, mu: float = 0.0, max_samples: int):
    """Returns solve(params0, x, y, n_valid, key) -> (delta, final_params)."""
    max_steps = epochs * ((max_samples + batch_size - 1) // batch_size)

    def loss_with_prox(params, params0, xb, yb):
        l = model.loss(params, {"x": xb, "y": yb})
        if mu > 0.0:
            sq = sum(jnp.sum(jnp.square(p - p0)) for p, p0 in zip(
                jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(params0)))
            l = l + 0.5 * mu * sq
        return l

    grad_fn = jax.grad(loss_with_prox)

    def solve(params0, x, y, n_valid, key):
        n_valid = jnp.maximum(n_valid, 1)
        steps = epochs * ((n_valid + batch_size - 1) // batch_size)

        def body(i, carry):
            params, key = carry
            key, sk = jax.random.split(key)
            idx = jax.random.randint(sk, (batch_size,), 0, n_valid)
            g = grad_fn(params, params0, x[idx], y[idx])
            live = (i < steps).astype(jnp.float32)
            params = jax.tree_util.tree_map(
                lambda p, gg: p - lr * live * gg, params, g)
            return params, key

        params, _ = jax.lax.fori_loop(0, max_steps, body, (params0, key))
        delta = jax.tree_util.tree_map(lambda a, b: a - b, params, params0)
        return delta, params

    return solve


def make_batch_solver(model: ModelSpec, *, epochs: int, batch_size: int,
                      lr: float, mu: float = 0.0, max_samples: int):
    """vmapped + jitted solver over a stacked client axis.

    solve_many(params0, X (K,max_n,...), Y (K,max_n), n (K,), keys (K,2))
      -> (deltas stacked over clients, final params stacked)
    """
    solve = make_local_solver(model, epochs=epochs, batch_size=batch_size,
                              lr=lr, mu=mu, max_samples=max_samples)
    return jax.jit(jax.vmap(solve, in_axes=(None, 0, 0, 0, 0)))


def _correct_one(model: ModelSpec):
    """Per-client correct-prediction count (params, x, y, n_valid) -> int32."""
    def one(params, x, y, n_valid):
        logits = model.apply(params, x)
        pred = jnp.argmax(logits, -1)
        ok = (pred == y) & (jnp.arange(y.shape[0]) < n_valid)
        return jnp.sum(ok)
    return one


def make_eval_fn(model: ModelSpec):
    """correct_counts(params, X (K,max_n,...), Y, n) -> (correct (K,), n)."""
    return jax.jit(jax.vmap(_correct_one(model), in_axes=(None, 0, 0, 0)))


def grouped_eval_correct(model: ModelSpec):
    """Un-jitted fused grouped-eval core: ONE program for all m groups.

    fn(group_params, membership, Xt, Yt, nt) -> (correct, total) int32
    scalars. group_params is the m-stacked pytree; membership (N,) routes
    each client's test shard to its group's model (-1 = never assigned,
    excluded from both counts) — the paper's §5.1 weighted accuracy as a
    single dispatch regardless of m, replacing the per-group eval loop
    (m dispatches + host accumulation). Each client gathers its own
    group's parameters (``g[membership]``, the round core's idiom) and is
    scored once — N forward passes total, same FLOPs as the retired loop,
    not m·N; the sums stay integer, so the host-side accuracy division is
    bit-identical to the retired loop's. Jit it at the call site (the
    trainers do); ``fed.rounds.make_block_executor`` runs it inside the
    scanned block at the ``eval_every`` cadence.
    """
    one = _correct_one(model)

    def fn(group_params, membership, Xt, Yt, nt):
        membership = membership.astype(jnp.int32)
        valid = membership >= 0
        m = jax.tree_util.tree_leaves(group_params)[0].shape[0]
        mem = jnp.clip(membership, 0, m - 1)
        my_params = jax.tree_util.tree_map(lambda g: g[mem], group_params)
        per_client = jax.vmap(one)(my_params, Xt, Yt, nt)   # (N,) int32
        correct = jnp.sum(jnp.where(valid, per_client, 0))
        total = jnp.sum(jnp.where(valid, nt.astype(jnp.int32), 0))
        return correct, total

    return fn


def client_mean_loss(model: ModelSpec):
    """Unjitted per-client mean CE loss (params, x, y, n_valid) -> scalar —
    the IFCA cluster-identity score, reused both by the standalone loss
    evaluator below and by the in-program assignment stage of the fused
    round (``fed.ifca.make_ifca_assign``)."""
    def one(params, x, y, n_valid):
        logits = model.apply(params, x)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ce = -jnp.take_along_axis(logp, y.astype(jnp.int32)[:, None], -1)[:, 0]
        mask = jnp.arange(y.shape[0]) < n_valid
        return jnp.sum(ce * mask) / jnp.maximum(n_valid, 1)
    return one


def make_loss_eval_fn(model: ModelSpec):
    """mean train loss per client (used by IFCA cluster estimation)."""
    return jax.jit(jax.vmap(client_mean_loss(model), in_axes=(None, 0, 0, 0)))

"""Shared single-dispatch round executor (Algorithm 2 hot path).

Every framework round is one device dispatch: group parameters live as a
pytree stacked with leading axis ``m``; each selected client gathers its
group's parameters, the local solver runs vmapped over the client axis, and
per-group aggregation is a segment-sum (one-hot matmul). Inter-group
aggregation (η_G, Alg. 2 lines 17-19), the auxiliary global model, the
flattened per-group update directions, and the discrepancy metric (eq. 4)
are all fused into the same program, so

  * ``FedAvgTrainer`` / ``FedProxTrainer`` run it with m=1,
  * ``FedGroupTrainer`` / ``FedGrouProxTrainer`` with m=n_groups,
  * ``IFCATrainer`` / ``FeSEMTrainer`` with m=n_groups plus an in-program
    *assignment stage* (``assign_fn``): IFCA's per-client argmin-loss over
    all m stacked models and FeSEM's argmin-ℓ2 E-step over flattened
    centers run inside the same compiled round, feeding the gather /
    segment-sum directly — no host-side ``np.where`` loops or per-group
    solver launches even for the frameworks that reschedule every round
    (IFCA's m× model broadcast *accounting* is unchanged by the fusion:
    the server still ships all m models per round, we just price it
    without also paying m dispatches), and
  * ``fed.parallel.make_parallel_round`` re-exports it for the mesh path;
    the serial trainers shard the client axis over the mesh's data axes
    through ``fed.parallel.make_sharded_executor`` whenever more than one
    device is visible, and a 2-D ``(data, model)`` mesh additionally
    shards the local solver's parameter dim over "model"
    (``sharding.specs.group_param_pspec``; plain jit is the 1-device
    special case and replication the model-axis-1 one — docs/scaling.md)

— one compiled round instead of the seed's ``m`` solver launches plus a
dozen host-synchronizing aggregation dispatches per round.

``make_block_executor`` goes one step further: it wraps the same fused
round in a ``jax.lax.scan`` over B rounds, so B rounds cost ONE dispatch.
Host-side cohort selection never depends on device results, so the trainer
stages a ``(B, K)`` cohort index matrix, ``(B, K, 2)`` solver keys and a
``(B, K)`` zero-weight ``alive`` mask (``dropout_rate`` cohorts pad to K so
the scan shapes stay static) up front; client batches are gathered
in-program from the pinned stacks, the carry (m-stacked group params +
each framework's assignment state) is *donated* so group state updates in
place, and per-round metrics — including the fused grouped eval — come
back stacked ``(B,)`` and are fetched once per block. The per-round
``make_round_executor`` path survives unchanged as the equivalence oracle
and the streamed-population fallback (``fed.engine.run`` breaks blocks on
events that need the host: group cold start, cold newcomers in a cohort,
population streaming).

``serial_reference_round`` keeps the seed per-group loop alive as the
equivalence oracle for tests and the BENCH_round_exec baseline;
``serial_ifca_round`` / ``serial_fesem_round`` do the same for the retired
estimate-then-loop baselines of the dynamic-assignment frameworks.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import client as client_lib
from repro.fed import server as server_lib
from repro.models.modules import flatten_updates


class RoundOutput(NamedTuple):
    group_params: object      # pytree stacked over m: post-η_G group models
    global_params: object     # auxiliary global model (mean of groups)
    agg_delta: object         # pytree stacked over m: intra-group FedAvg Δ
    group_delta_flat: object  # (m, d_w) flattened w_g^{t+1} − w_g^t
    discrepancy: object       # scalar: mean_i ||w_i^final − w̃_{g(i)}||
    membership: object        # (K,) int32 group id used this round
    assign_state: object      # updated assignment-stage state (None if static)
    mean_loss: object = 0.0   # scalar: n_i-weighted mean local train loss
                              # of the clients' final local models
    n_quarantined: object = 0  # scalar int32: alive clients whose updates
                               # were screened out this round


def stack_trees(trees):
    """List of pytrees -> one pytree with a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _group_norms(stacked, m):
    """Per-group global parameter norm of an m-stacked pytree -> (m,)."""
    sq = sum(jnp.sum(jnp.square(l.reshape(m, -1)), axis=1)
             for l in jax.tree_util.tree_leaves(stacked))
    return jnp.sqrt(sq)


def _make_round_core(model, *, epochs: int, batch_size: int, lr: float,
                     mu: float, n_groups: int, max_samples: int,
                     eta_g: float = 0.0, assign_fn=None,
                     state_update_fn=None, quarantine: bool = False,
                     quarantine_mult: float = 10.0):
    """The fused round as a pure function with an explicit per-client
    ``alive`` weight — shared by ``make_round_executor`` (alive = ones) and
    ``make_block_executor`` (alive = the staged zero-weight padding mask,
    so ``dropout_rate`` cohorts keep static scan shapes). A client with
    ``alive == 0`` still runs the vmapped solver (dead lanes are cheaper
    than dynamic shapes) but contributes nothing to the aggregation, the
    mean loss, or the discrepancy.

    ``quarantine`` adds an in-program update screen on top of the same
    mask: a client whose local delta is non-finite (NaN/Inf anywhere) or
    whose delta norm exceeds ``quarantine_mult`` × the cohort median is
    folded into the zero-weight path — its delta is zeroed, its final
    local model is replaced by its group's round-start parameters (so
    FeSEM's state scatter writes something finite), and its alive weight
    drops to 0 before any reduction. Zero weight alone is NOT enough:
    ``0 * NaN = NaN`` would still poison the segment-sum matmul, the mean
    loss, and the discrepancy, which is why the screen rewrites the
    payloads rather than just down-weighting them."""
    m = n_groups
    solve = client_lib.make_local_solver(
        model, epochs=epochs, batch_size=batch_size, lr=lr, mu=mu,
        max_samples=max_samples)
    loss_one = client_lib.client_mean_loss(model)

    def core(group_params, membership, X, Y, n, keys, alive) -> RoundOutput:
        state = None
        if assign_fn is not None:
            state = membership
            membership = assign_fn(group_params, X, Y, n, state)
        membership = membership.astype(jnp.int32)
        # each client trains from ITS group's parameters (one gather, no loop)
        my_params = jax.tree_util.tree_map(
            lambda g: g[membership], group_params)
        deltas, finals = jax.vmap(solve)(my_params, X, Y, n, keys)

        K = membership.shape[0]
        ok = None
        n_quarantined = jnp.int32(0)
        if quarantine:
            d_sq = sum(jnp.sum(jnp.square(d.reshape(K, -1)), axis=1)
                       for d in jax.tree_util.tree_leaves(deltas))
            finite = jnp.isfinite(d_sq)
            norms = jnp.sqrt(jnp.where(finite, d_sq, 0.0))
            # median over the alive, finite updates; NaN comparisons are
            # False, so an all-poisoned cohort quarantines on finiteness
            # alone rather than on the (undefined) outlier threshold
            med = jnp.nanmedian(jnp.where((alive > 0) & finite, norms,
                                          jnp.nan))
            outlier = norms > quarantine_mult * jnp.maximum(med, 1e-12)
            ok = finite & ~outlier
            n_quarantined = jnp.sum((alive > 0) & ~ok).astype(jnp.int32)
            okb = lambda t: ok.reshape((-1,) + (1,) * (t.ndim - 1))
            deltas = jax.tree_util.tree_map(
                lambda d: jnp.where(okb(d), d, 0.0), deltas)
            finals = jax.tree_util.tree_map(
                lambda f, p: jnp.where(okb(f), f, p), finals, my_params)
            alive = alive * ok.astype(alive.dtype)

        # intra-group FedAvg (Alg. 2): segment-sum with n_i weights
        # normalized within each group
        onehot = jax.nn.one_hot(membership, m, dtype=jnp.float32)  # (K, m)
        w = n.astype(jnp.float32) * alive
        group_tot = onehot.T @ w                                   # (m,)
        norm_w = w[:, None] * onehot / jnp.maximum(group_tot[None], 1e-9)

        def agg(d):
            flat = d.reshape(d.shape[0], -1)                       # (K, p)
            return (norm_w.T @ flat).reshape((m,) + d.shape[1:])

        agg_delta = jax.tree_util.tree_map(agg, deltas)
        occupied = (group_tot > 0).astype(jnp.float32)
        tilde = jax.tree_util.tree_map(
            lambda gp, gd: gp + occupied.reshape(
                (-1,) + (1,) * (gp.ndim - 1)) * gd,
            group_params, agg_delta)

        # mean local training loss of the final local models (what History
        # reports as mean_loss — one extra forward pass, n_i-weighted)
        per_client_loss = jax.vmap(loss_one)(finals, X, Y, n)
        if ok is not None:
            # a quarantined client's batch may itself be poisoned, so even
            # the sanitized finals can evaluate to NaN on it
            per_client_loss = jnp.where(ok, per_client_loss, 0.0)
        mean_loss = jnp.sum(per_client_loss * w) / jnp.maximum(jnp.sum(w), 1e-9)

        # eq. 4 discrepancy: each client vs its group's intra-aggregated model
        tilde_mine = jax.tree_util.tree_map(lambda t: t[membership], tilde)
        disc_sq = sum(jnp.sum(jnp.square((f - t).reshape(K, -1)), axis=1)
                      for f, t in zip(jax.tree_util.tree_leaves(finals),
                                      jax.tree_util.tree_leaves(tilde_mine)))
        discrepancy = jnp.sum(jnp.sqrt(disc_sq) * alive) / \
            jnp.maximum(jnp.sum(alive), 1e-9)

        # inter-group aggregation (Alg. 2 lines 17-19), stacked form
        if eta_g > 0.0 and m > 1:
            norms = jnp.maximum(_group_norms(tilde, m), 1e-12)

            def inter(t):
                nm = t / norms.reshape((-1,) + (1,) * (t.ndim - 1))
                return t + eta_g * (jnp.sum(nm, 0, keepdims=True) - nm)

            new_groups = jax.tree_util.tree_map(inter, tilde)
        else:
            new_groups = tilde

        global_params = jax.tree_util.tree_map(
            lambda g: jnp.mean(g, axis=0), new_groups)
        group_delta_flat = jax.vmap(flatten_updates)(
            jax.tree_util.tree_map(lambda a, b: a - b,
                                   new_groups, group_params))
        if assign_fn is not None and state_update_fn is not None:
            state = state_update_fn(state, membership, deltas, finals)
        return RoundOutput(new_groups, global_params, agg_delta,
                           group_delta_flat, discrepancy, membership, state,
                           mean_loss, n_quarantined)

    return core


def make_round_executor(model, *, epochs: int, batch_size: int, lr: float,
                        mu: float, n_groups: int, max_samples: int,
                        eta_g: float = 0.0, assign_fn=None,
                        state_update_fn=None, quarantine: bool = False,
                        quarantine_mult: float = 10.0):
    """Returns round_fn(group_params, membership, X, Y, n, keys) -> RoundOutput.

    group_params: pytree with leading axis m; membership: (K,) int group id
    per selected client; X: (K, max_n, ...); Y: (K, max_n); n: (K,);
    keys: (K, 2) uint32. Pure function of arrays — jit/pjit it at the call
    site (the trainers jit it; the mesh dry-run lowers it under pjit).

    Dynamic assignment (IFCA / FeSEM): pass
      assign_fn(group_params, X, Y, n, state) -> (K,) int membership
    and the second positional argument of round_fn becomes the opaque
    assignment *state* pytree instead of a membership vector — the cluster
    estimate is computed inside the compiled round and fed straight into the
    gather/segment-sum. An optional
      state_update_fn(state, membership, deltas, finals) -> new state
    keeps per-client state (e.g. FeSEM's flattened local models) on device
    across rounds via an in-program scatter; the updated state is returned
    in ``RoundOutput.assign_state``.

    ``quarantine=True`` screens non-finite / norm-outlier client updates
    into the zero-weight path (see ``_make_round_core``) and reports the
    count in ``RoundOutput.n_quarantined``.
    """
    core = _make_round_core(
        model, epochs=epochs, batch_size=batch_size, lr=lr, mu=mu,
        n_groups=n_groups, max_samples=max_samples, eta_g=eta_g,
        assign_fn=assign_fn, state_update_fn=state_update_fn,
        quarantine=quarantine, quarantine_mult=quarantine_mult)

    def round_fn(group_params, membership, X, Y, n, keys) -> RoundOutput:
        return core(group_params, membership, X, Y, n, keys,
                    jnp.ones(n.shape[0], jnp.float32))

    return round_fn


def make_block_executor(model, *, epochs: int, batch_size: int, lr: float,
                        mu: float, n_groups: int, max_samples: int,
                        eta_g: float = 0.0, assign_fn=None,
                        state_update_fn=None, make_state=None,
                        state_to_aux=None, quarantine: bool = False,
                        quarantine_mult: float = 10.0):
    """Returns block_fn(carry, train_stack, test_stack, idx, keys, alive,
    do_eval) -> (carry, (mean_loss, discrepancy, correct, total,
    n_quarantined)) — B fused rounds as ONE ``jax.lax.scan`` dispatch over
    the pinned stacks.

    carry (the donated round-to-round state):
      ``group_params``  m-stacked pytree, updated in place round to round
      ``global_params`` auxiliary global model (mean of groups)
      ``group_delta``   (m, d_w) latest flattened update directions (eq. 9)
      ``membership``    (N+1,) int32 — every client's group id (-1 = cold),
                        row N is the scatter trash row for padded clients
      ``aux``           framework state (FeSEM: (N+1, d_w) local_flat with
                        the same trash row) or None

    train_stack / test_stack: the pinned ``(x, y, n)`` device stacks —
    client batches are gathered *in-program* (``X[idx]``), so no per-round
    H2D. idx: (B, K) int32 staged cohorts; keys: (B, K, 2) uint32; alive:
    (B, K) float32 zero-weight padding mask (``dropout_rate`` survivors
    first, padding after — padded lanes aggregate with weight 0 and scatter
    to the trash row); do_eval: (B,) bool eval-cadence mask
    (``FedConfig.eval_every``). Per-round metrics come back stacked (B,):
    mean_loss, discrepancy, the fused grouped-eval correct/total counts
    (0 where do_eval is False) — ints, so the host-side accuracy division
    reproduces the per-round path bit for bit — and the per-round
    quarantine counts (all 0 when ``quarantine`` is off).

    make_state(aux, idx, membership) builds the per-round assignment state
    from the carried ``aux`` and the carried (N+1,) membership table
    (FeSEM: {"local_flat": aux, "idx": idx}; LCFL gathers the cohort's
    current groups from the membership carry for its hysteresis rule);
    state_to_aux extracts the updated aux from ``RoundOutput.assign_state``.
    With ``assign_fn`` but no ``make_state`` the state is None (IFCA);
    without ``assign_fn`` membership is gathered from the carry (static
    frameworks).

    jit with ``donate_argnums=(0,)`` (``fed.parallel
    .make_sharded_block_executor`` does) so the carry buffers are reused
    instead of reallocated every block.
    """
    core = _make_round_core(
        model, epochs=epochs, batch_size=batch_size, lr=lr, mu=mu,
        n_groups=n_groups, max_samples=max_samples, eta_g=eta_g,
        assign_fn=assign_fn, state_update_fn=state_update_fn,
        quarantine=quarantine, quarantine_mult=quarantine_mult)
    eval_correct = client_lib.grouped_eval_correct(model)

    def block_fn(carry, train_stack, test_stack, idx, keys, alive, do_eval):
        X_all, Y_all, n_all = train_stack
        Xt, Yt, nt = test_stack

        def step(c, xs):
            ix, ks, al, ev = xs
            x, y, n = X_all[ix], Y_all[ix], n_all[ix]
            trash = c["membership"].shape[0] - 1       # row N: padded lanes
            ix_eff = jnp.where(al > 0, ix, trash).astype(jnp.int32)
            if assign_fn is None:
                arg = c["membership"][ix]
            elif make_state is not None:
                arg = make_state(c["aux"], ix_eff, c["membership"])
            else:
                arg = None
            out = core(c["group_params"], arg, x, y, n, ks, al)
            membership = c["membership"].at[ix_eff].set(out.membership)
            aux = c["aux"]
            if state_to_aux is not None:
                aux = state_to_aux(out.assign_state)
            new_c = dict(group_params=out.group_params,
                         global_params=out.global_params,
                         group_delta=out.group_delta_flat,
                         membership=membership, aux=aux)
            correct, total = jax.lax.cond(
                ev,
                lambda gp, mem: eval_correct(gp, mem[:-1], Xt, Yt, nt),
                lambda gp, mem: (jnp.int32(0), jnp.int32(0)),
                out.group_params, membership)
            return new_c, (out.mean_loss, out.discrepancy, correct, total,
                           out.n_quarantined)

        return jax.lax.scan(step, carry, (idx, keys, alive, do_eval))

    return block_fn


def staleness_weight(staleness, *, alpha: float = 1.0, beta: float = 0.0):
    """FedAsync mixing weight w = alpha * (staleness + 1)^(-beta).

    ``staleness`` counts, per group, how many folds landed between this
    dispatch's parameter snapshot and its own fold (0 = fresh). Properties
    the async runtime relies on (tested in tests/test_async.py):

      * s = 0 reduces to exactly ``alpha`` (1^(-beta) == 1.0 in IEEE),
      * monotone non-increasing in s for beta >= 0,
      * alpha = 1, beta = 0 gives exactly 1.0 for every staleness — the
        equivalence mode whose fold is a bitwise passthrough of the
        dispatch result (``make_staleness_fold`` special-cases w == 1).

    Host-side numpy (the weights are (m,) scalars computed at fold time).
    """
    s = np.asarray(staleness, np.float64)
    if np.any(s < 0):
        raise ValueError(f"negative staleness {s}")
    return np.asarray(alpha * (s + 1.0) ** (-float(beta)), np.float32)


def _mix_weighted(weights):
    """Per-leaf convex mix new = (1-w)*cur + w*res over the leading group
    axis, with w == 1.0 an exact bitwise passthrough of ``res`` (0*cur +
    1*res is NOT bit-exact when cur is -0.0 or non-finite, so the
    passthrough is a ``where`` select, not arithmetic)."""
    def mix(cur, res):
        w = weights.reshape((-1,) + (1,) * (res.ndim - 1)).astype(res.dtype)
        return jnp.where(w == 1.0, res, (1.0 - w) * cur + w * res)
    return mix


def make_async_dispatch_executor(model, *, epochs: int, batch_size: int,
                                 lr: float, mu: float, n_groups: int,
                                 max_samples: int, eta_g: float = 0.0,
                                 assign_fn=None, state_update_fn=None,
                                 make_state=None, state_to_aux=None,
                                 quarantine: bool = False,
                                 quarantine_mult: float = 10.0):
    """Returns dispatch_fn(carry, train_stack, idx, keys, alive) ->
    (result_carry, (mean_loss, discrepancy, n_quarantined, membership)) —
    ONE staged round computed against a *snapshot* carry, for the bounded
    in-flight async window (``FedConfig.async_depth``).

    This is exactly one ``make_block_executor`` scan step (same core, same
    in-program gather from the pinned stacks, same trash-row scatter
    convention), minus the in-program eval — the async loop evaluates at
    *fold* time, on the folded parameters, through the same fused grouped
    eval program. The snapshot carry is NOT donated (at depth D > 1 it is
    shared with the server's live params and other in-flight dispatches);
    the *result* carry is per-dispatch and donated into the staleness fold
    (``make_staleness_fold``). The cohort's post-assignment membership
    rides out with the metrics so the fold can bump the touched groups'
    staleness clocks without an extra device fetch.
    """
    core = _make_round_core(
        model, epochs=epochs, batch_size=batch_size, lr=lr, mu=mu,
        n_groups=n_groups, max_samples=max_samples, eta_g=eta_g,
        assign_fn=assign_fn, state_update_fn=state_update_fn,
        quarantine=quarantine, quarantine_mult=quarantine_mult)

    def dispatch_fn(carry, train_stack, idx, keys, alive):
        X_all, Y_all, n_all = train_stack
        x, y, n = X_all[idx], Y_all[idx], n_all[idx]
        trash = carry["membership"].shape[0] - 1
        ix_eff = jnp.where(alive > 0, idx, trash).astype(jnp.int32)
        if assign_fn is None:
            arg = carry["membership"][idx]
        elif make_state is not None:
            arg = make_state(carry["aux"], ix_eff, carry["membership"])
        else:
            arg = None
        out = core(carry["group_params"], arg, x, y, n, keys, alive)
        membership = carry["membership"].at[ix_eff].set(out.membership)
        aux = carry["aux"]
        if state_to_aux is not None:
            aux = state_to_aux(out.assign_state)
        result = dict(group_params=out.group_params,
                      global_params=out.global_params,
                      group_delta=out.group_delta_flat,
                      membership=membership, aux=aux)
        return result, (out.mean_loss, out.discrepancy, out.n_quarantined,
                        out.membership)

    return dispatch_fn


def make_staleness_fold():
    """Returns fold_fn(current, result, idx, alive, weights) -> carry —
    fold a completed async dispatch into the server's *current* carry with
    per-group staleness weights (``staleness_weight``).

      * group_params: per-group convex mix (1-w)·current + w·result, with
        w == 1.0 a bitwise ``where`` passthrough of the result,
      * global_params: the result's own auxiliary model when every weight
        is 1.0 (bitwise — the D=1 equivalence mode), the mean of the
        folded groups otherwise,
      * group_delta: the dispatch's flattened update directions (eq.-9
        cold-start routing keys off the *direction*, not the magnitude),
      * membership / aux: only the cohort's trash-row-redirected lanes are
        scattered from the result, so at depth D > 1 concurrent dispatches
        merge row-wise (last fold wins on overlapping rows) instead of one
        dispatch's full-table snapshot clobbering the other's writes.

    jit with ``donate_argnums=(0, 1)`` (the engine does): the current
    carry and the per-dispatch result are both consumed, so the folded
    carry reuses their buffers — in-flight dispatches already enqueued
    against the old buffers execute before the fold on the device stream.
    """
    def fold_fn(current, result, idx, alive, weights):
        trash = current["membership"].shape[0] - 1
        ix_eff = jnp.where(alive > 0, idx, trash).astype(jnp.int32)
        membership = current["membership"].at[ix_eff].set(
            result["membership"][ix_eff])
        aux = current["aux"]
        if aux is not None:
            aux = aux.at[ix_eff].set(result["aux"][ix_eff])
        mix = _mix_weighted(weights)
        groups = jax.tree_util.tree_map(mix, current["group_params"],
                                        result["group_params"])
        all_one = jnp.all(weights == 1.0)
        global_params = jax.tree_util.tree_map(
            lambda res_g, g: jnp.where(all_one, res_g, jnp.mean(g, axis=0)),
            result["global_params"], groups)
        return dict(group_params=groups, global_params=global_params,
                    group_delta=result["group_delta"],
                    membership=membership, aux=aux)

    return fold_fn


def make_param_fold():
    """Returns fold_fn(current_groups, result_groups, result_global,
    weights) -> (folded_groups, folded_global) — the carry-less staleness
    fold of the *streamed* async path, where membership / FeSEM rows stay
    host-resident and only the m-stacked group parameters live on device.
    Same mixing semantics as ``make_staleness_fold`` (w == 1.0 is a
    bitwise passthrough, matching the synchronous per-round adoption
    ``group_params = out.group_params; params = out.global_params``)."""
    def fold_fn(current_groups, result_groups, result_global, weights):
        mix = _mix_weighted(weights)
        groups = jax.tree_util.tree_map(mix, current_groups, result_groups)
        all_one = jnp.all(weights == 1.0)
        folded_global = jax.tree_util.tree_map(
            lambda res_g, g: jnp.where(all_one, res_g, jnp.mean(g, axis=0)),
            result_global, groups)
        return groups, folded_global

    return fold_fn


def serial_reference_round(batch_solver, group_params_list, membership,
                           X, Y, n, keys, *, eta_g: float = 0.0):
    """The seed per-group round loop — m solver dispatches plus host-side
    aggregation. Kept as the numerical oracle for the single-dispatch
    executor (tests) and as the baseline side of BENCH_round_exec.json.

    batch_solver: ``client.make_batch_solver`` product; group_params_list:
    list of m pytrees; membership: (K,) numpy int array; the rest as in
    ``make_round_executor`` (keys are per-client, shared with the fused path
    so both draw identical mini-batches).
    """
    m = len(group_params_list)
    tilde, disc, _ = _serial_group_update(
        batch_solver, group_params_list, membership, X, Y, n, keys)
    new_list = server_lib.inter_group_aggregate(tilde, eta_g)
    group_delta = jnp.stack([
        flatten_updates(server_lib.tree_sub(new_list[j], group_params_list[j]))
        for j in range(m)])
    global_params = server_lib.tree_mean(new_list)
    return (new_list, global_params, group_delta, disc)


def _serial_group_update(batch_solver, group_params_list, membership,
                         X, Y, n, keys, collect_finals: bool = False):
    """Shared tail of the retired per-group rounds: one solver launch per
    non-empty cluster, weighted intra-group aggregation, host discrepancy.
    collect_finals additionally flattens each member's final local model
    (FeSEM's host-side local_flat rebuild)."""
    m = len(group_params_list)
    new_list = list(group_params_list)
    disc_sum, disc_n = 0.0, 0
    finals_by_client = {}
    for j in range(m):
        members = np.where(np.asarray(membership) == j)[0]
        if len(members) == 0:
            continue
        sel = jnp.asarray(members)
        deltas, finals = batch_solver(group_params_list[j], X[sel], Y[sel],
                                      n[sel], keys[sel])
        agg = server_lib.weighted_delta(deltas, n[sel])
        new_list[j] = server_lib.apply_delta(group_params_list[j], agg)
        diffs = jax.vmap(lambda f: server_lib.tree_norm(
            server_lib.tree_sub(f, new_list[j])))(finals)
        disc_sum += float(jnp.sum(diffs))
        disc_n += len(members)
        if collect_finals:
            flats = np.asarray(jax.vmap(flatten_updates)(finals))
            for mi, fi in zip(members, flats):
                finals_by_client[int(mi)] = fi
    return new_list, disc_sum / max(disc_n, 1), finals_by_client


def serial_ifca_round(batch_solver, loss_fn, group_params_list,
                      X, Y, n, keys):
    """The retired IFCA round: host-side argmin-loss cluster estimation
    (one loss dispatch per group) followed by one solver launch per
    non-empty cluster — kept as the equivalence oracle for the fused
    assignment stage and the baseline side of BENCH_round_exec.json.

    loss_fn: ``client.make_loss_eval_fn`` product. Returns
    (new group list, membership (K,) numpy, discrepancy).
    """
    losses = jnp.stack([loss_fn(p, X, Y, n) for p in group_params_list])
    membership = np.asarray(jnp.argmin(losses, axis=0))
    new_list, disc, _ = _serial_group_update(
        batch_solver, group_params_list, membership, X, Y, n, keys)
    return new_list, membership, disc


def serial_fesem_round(batch_solver, group_params_list, local_flat,
                       X, Y, n, keys):
    """The retired FeSEM round: host numpy ℓ2 E-step over flattened centers,
    per-group M-step (center = weighted average of members' final local
    models), and a host rebuild of the per-client flattened-model matrix.

    local_flat: (K, d_w) flattened local models of the *selected* clients.
    Returns (new group list, membership, new local_flat, discrepancy).
    """
    centers = np.stack([np.asarray(flatten_updates(p))
                        for p in group_params_list])
    lf = np.asarray(local_flat)
    d2 = ((lf[:, None, :] - centers[None]) ** 2).sum(-1)
    membership = d2.argmin(1)
    # M-step ≡ intra-group FedAvg: avg_w(finals) = center + avg_w(deltas)
    new_list, disc, finals_by_client = _serial_group_update(
        batch_solver, group_params_list, membership, X, Y, n, keys,
        collect_finals=True)
    new_local = lf.copy()
    for mi, fi in finals_by_client.items():
        new_local[mi] = fi
    return new_list, membership, new_local, disc

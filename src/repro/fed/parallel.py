"""Mesh-parallel FedGroup — the paper's technique as a first-class
distributed workload (the TPU-native replacement for the per-client loop).

Two jittable entry points, both lowered by the FedGroup dry-run:

  parallel_round      one FedGroup communication round: K clients sharded
                      over the mesh "data" axis, each doing E epochs of local
                      SGD from its group's parameters, followed by per-group
                      weighted aggregation (segment-sum + psum).

  group_cold_start_distributed
                      Algorithm 3 at production scale: the pre-training
                      update matrix ΔW (n_pre × d_w, d_w up to hundreds of
                      millions) is sharded over the "model" axis along d_w;
                      randomized SVD + EDC embedding run as sharded matmuls.
                      ``qr_impl='cholesky'`` replaces tall-skinny QR with
                      CholeskyQR2 (Gram matrix + psum of an (k×k) block) —
                      the beyond-paper collective optimization (§Perf).

Both are pure functions of arrays, so they lower/compile under pjit with
the shardings chosen in launch/fed_dryrun.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.modules import flatten_updates


# ---------------------------------------------------------------------------
# One round, client-parallel
# ---------------------------------------------------------------------------

def make_parallel_round(model, *, epochs: int, batch_size: int, lr: float,
                        mu: float, n_groups: int, max_samples: int):
    """Returns round_fn(group_params_stacked, membership, X, Y, n, keys)
      -> (new group params stacked, auxiliary global params, group deltas).

    group_params_stacked: pytree with leading axis m.
    membership: (K,) int group id per selected client.
    X: (K, max_n, ...), Y: (K, max_n), n: (K,), keys: (K, 2) uint32.
    """
    max_steps = epochs * ((max_samples + batch_size - 1) // batch_size)

    def local_solve(params0, x, y, n_valid, key):
        n_valid = jnp.maximum(n_valid, 1)
        steps = epochs * ((n_valid + batch_size - 1) // batch_size)

        def loss(params, xb, yb):
            l = model.loss(params, {"x": xb, "y": yb})
            if mu > 0:
                l = l + 0.5 * mu * sum(
                    jnp.sum(jnp.square(p - p0)) for p, p0 in zip(
                        jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params0)))
            return l

        def body(i, carry):
            params, key = carry
            key, sk = jax.random.split(key)
            idx = jax.random.randint(sk, (batch_size,), 0, n_valid)
            g = jax.grad(loss)(params, x[idx], y[idx])
            live = (i < steps).astype(jnp.float32)
            return (jax.tree_util.tree_map(
                lambda p, gg: p - lr * live * gg, params, g), key)

        params, _ = jax.lax.fori_loop(0, max_steps, body, (params0, key))
        return jax.tree_util.tree_map(lambda a, b: a - b, params, params0)

    def round_fn(group_params, membership, X, Y, n, keys):
        # each client trains from ITS group's parameters
        my_params = jax.tree_util.tree_map(
            lambda g: g[membership], group_params)
        deltas = jax.vmap(local_solve)(my_params, X, Y, n, keys)

        # per-group weighted aggregation (Alg. 2 intra-group FedAvg):
        # weights n_i normalized within each group
        onehot = jax.nn.one_hot(membership, n_groups, dtype=jnp.float32)
        w = n.astype(jnp.float32)
        group_tot = onehot.T @ w                         # (m,)
        norm_w = w[:, None] * onehot / jnp.maximum(group_tot[None], 1e-9)

        def agg(d):
            flat = d.reshape(d.shape[0], -1)             # (K, p)
            g = norm_w.T @ flat                          # (m, p)
            return g.reshape((n_groups,) + d.shape[1:])

        agg_delta = jax.tree_util.tree_map(agg, deltas)
        occupied = (group_tot > 0).astype(jnp.float32)
        new_groups = jax.tree_util.tree_map(
            lambda gp, gd: gp + occupied.reshape(
                (-1,) + (1,) * (gp.ndim - 1)) * gd,
            group_params, agg_delta)
        global_params = jax.tree_util.tree_map(
            lambda g: jnp.mean(g, axis=0), new_groups)
        return new_groups, global_params, agg_delta

    return round_fn


# ---------------------------------------------------------------------------
# Distributed group cold start (Algorithm 3 at scale)
# ---------------------------------------------------------------------------

def cholesky_qr2(Y):
    """CholeskyQR2: Q from two rounds of Gram-matrix Cholesky.

    For a (d, k) tall-skinny sharded-by-rows Y this needs only two (k, k)
    all-reduces instead of a distributed Householder QR — the beyond-paper
    collective optimization for the cold start.
    """
    def _cqr(A):
        k = A.shape[1]
        G = A.T @ A                                      # (k,k): psum if sharded
        Lc = jnp.linalg.cholesky(G + 1e-8 * jnp.eye(k, dtype=G.dtype))
        # Apply L^-T as a small replicated matmul (NOT solve_triangular on the
        # tall operand — XLA cannot partition that and would all-gather A).
        Linv = jax.scipy.linalg.solve_triangular(
            Lc, jnp.eye(k, dtype=G.dtype), lower=True)   # (k,k) replicated
        Q = A @ Linv.T
        return Q, Lc.T
    Q1, R1 = _cqr(Y)
    Q2, R2 = _cqr(Q1)
    return Q2, R2 @ R1


def rsvd_sharded(dW, m: int, *, n_iter: int = 4, oversample: int = 8,
                 key=None, qr_impl: str = "householder"):
    """Top-m left singular directions of ΔWᵀ, d_w-sharded friendly.

    dW: (n, d_w). All heavy ops are (d_w × small) matmuls; with d_w sharded
    over "model", XLA turns the small Gram products into psums.
    qr_impl: 'householder' (jnp.linalg.qr — baseline) or 'cholesky' (CQR2).
    """
    n, d = dW.shape
    k = min(m + oversample, n)
    if key is None:
        key = jax.random.PRNGKey(0)
    A = dW.astype(jnp.float32).T                         # (d, n)
    qr = jnp.linalg.qr if qr_impl == "householder" \
        else (lambda Y: cholesky_qr2(Y))
    omega = jax.random.normal(key, (n, k), jnp.float32)
    Y = A @ omega
    Q = qr(Y)[0]
    for _ in range(n_iter):
        W = qr(A.T @ Q)[0]
        Q = qr(A @ W)[0]
    B = Q.T @ A                                          # (k, n)
    Ub, s, _ = jnp.linalg.svd(B, full_matrices=False)
    return (Q @ Ub)[:, :m]


def edc_embedding_distributed(dW, m: int, *, key=None,
                              qr_impl: str = "householder",
                              use_kernel: bool = False):
    """ΔW -> (E (n, m) cosine embedding, V). The group-cold-start hot path."""
    V = rsvd_sharded(dW, m, key=key, qr_impl=qr_impl)
    if use_kernel:
        from repro.kernels.ops import cosine_block
        return cosine_block(dW, V), V
    dots = dW.astype(jnp.float32) @ V
    rn = jnp.sqrt(jnp.sum(jnp.square(dW.astype(jnp.float32)), axis=1,
                          keepdims=True))
    cn = jnp.linalg.norm(V, axis=0, keepdims=True)
    return dots / jnp.maximum(rn * cn, 1e-12), V


def kmeans_step(E, centers):
    """One Lloyd iteration on the embedding (jit-friendly)."""
    d2 = jnp.sum(jnp.square(E[:, None, :] - centers[None]), -1)
    assign = jnp.argmin(d2, -1)
    onehot = jax.nn.one_hot(assign, centers.shape[0], dtype=jnp.float32)
    counts = jnp.sum(onehot, 0)
    sums = onehot.T @ E
    new = jnp.where(counts[:, None] > 0,
                    sums / jnp.maximum(counts[:, None], 1), centers)
    return assign, new

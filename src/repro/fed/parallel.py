"""Mesh-parallel FedGroup — the paper's technique as a first-class
distributed workload (the TPU-native replacement for the per-client loop).

The serial trainers' sharding helpers live here too: ``default_data_mesh``
(a 1-D "data" mesh over all visible devices, None on one device) and
``make_sharded_executor`` (jit of a round executor with the client axis of
every K-leading input placed sharded over "data") — so the same fused
round runs client-parallel everywhere, not just under the dry-run below.

Two jittable entry points, both lowered by the FedGroup dry-run:

  parallel_round      one FedGroup communication round: K clients sharded
                      over the mesh "data" axis, each doing E epochs of local
                      SGD from its group's parameters, followed by per-group
                      weighted aggregation (segment-sum + psum).

  group_cold_start_distributed
                      Algorithm 3 at production scale: the pre-training
                      update matrix ΔW (n_pre × d_w, d_w up to hundreds of
                      millions) is sharded over the "model" axis along d_w;
                      randomized SVD + EDC embedding run as sharded matmuls.
                      ``qr_impl='cholesky'`` replaces tall-skinny QR with
                      CholeskyQR2 (Gram matrix + psum of an (k×k) block) —
                      the beyond-paper collective optimization (§Perf).

Both are pure functions of arrays, so they lower/compile under pjit with
the shardings chosen in launch/fed_dryrun.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.modules import flatten_updates


# ---------------------------------------------------------------------------
# Client-axis sharding for the serial trainers
# ---------------------------------------------------------------------------

def default_data_mesh():
    """A 1-D ("data",) mesh over all visible devices, or None on a single
    device — the trainers' auto-detected executor sharding (the 1-device
    None answer selects the plain-jit path)."""
    n = jax.device_count()
    if n <= 1:
        return None
    return jax.make_mesh((n,), ("data",))


def shard_client_axis(mesh, tree):
    """device_put every array leaf with its leading (client) axis sharded
    over the mesh "data" axes when divisible, replicated otherwise.
    ``mesh=None`` degrades to a plain asynchronous ``jax.device_put`` — the
    unified H2D entry the population prefetcher uses, so streamed cohorts
    land pre-placed for the executor on one device and on a mesh alike.

    Works on arbitrary pytrees, so the dynamic-assignment state (e.g.
    FeSEM's {"local_flat", "idx"}) shards leaf-by-leaf: local_flat by rows
    over all clients, idx over the selected-client axis.
    """
    if mesh is None:
        return jax.tree_util.tree_map(
            lambda l: jax.device_put(jnp.asarray(l)), tree)
    total = 1
    for a in mesh.axis_names:
        total *= mesh.shape[a]

    def put(leaf):
        leaf = jnp.asarray(leaf)
        if leaf.ndim >= 1 and leaf.shape[0] % total == 0 and leaf.shape[0]:
            spec = P(mesh.axis_names, *([None] * (leaf.ndim - 1)))
        else:
            spec = P(*([None] * leaf.ndim))
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree)


def make_sharded_executor(round_fn, mesh=None):
    """jit ``round_fn`` (a ``fed.rounds.make_round_executor`` product) with
    its client axis sharded over ``mesh``.

    mesh=None (single device) is the plain-jit special case. With a mesh,
    group parameters are replicated and the K-axis inputs (membership or
    assignment state, X, Y, n, keys) are placed with their leading axis
    sharded over "data" before dispatch — the compiled round then runs
    client-parallel exactly like ``make_parallel_round`` under the dry-run
    mesh, with XLA inserting the segment-sum all-reduces.
    """
    jfn = jax.jit(round_fn)
    if mesh is None:
        return jfn
    replicate = lambda t: jax.tree_util.tree_map(
        lambda l: jax.device_put(
            l, NamedSharding(mesh, P(*([None] * jnp.ndim(l))))), t)

    def call(group_params, assign, X, Y, n, keys):
        group_params = replicate(group_params)
        assign, X, Y, n, keys = (shard_client_axis(mesh, t)
                                 for t in (assign, X, Y, n, keys))
        return jfn(group_params, assign, X, Y, n, keys)

    return call


# ---------------------------------------------------------------------------
# One round, client-parallel
# ---------------------------------------------------------------------------

def make_parallel_round(model, *, epochs: int, batch_size: int, lr: float,
                        mu: float, n_groups: int, max_samples: int):
    """Returns round_fn(group_params_stacked, membership, X, Y, n, keys)
      -> (new group params stacked, auxiliary global params, group deltas).

    group_params_stacked: pytree with leading axis m.
    membership: (K,) int group id per selected client.
    X: (K, max_n, ...), Y: (K, max_n), n: (K,), keys: (K, 2) uint32.

    Thin adapter over ``fed.rounds.make_round_executor`` — the same fused
    round the serial trainers dispatch; only the mesh shardings differ
    (chosen in launch/fed_dryrun.py). The executor's extra outputs
    (discrepancy, flattened group deltas) are dead code here and XLA
    eliminates them when this round_fn is jitted.
    """
    from repro.fed.rounds import make_round_executor
    core = make_round_executor(model, epochs=epochs, batch_size=batch_size,
                               lr=lr, mu=mu, n_groups=n_groups,
                               max_samples=max_samples, eta_g=0.0)

    def round_fn(group_params, membership, X, Y, n, keys):
        out = core(group_params, membership, X, Y, n, keys)
        return out.group_params, out.global_params, out.agg_delta

    return round_fn


# ---------------------------------------------------------------------------
# Distributed group cold start (Algorithm 3 at scale)
# ---------------------------------------------------------------------------

def cholesky_qr2(Y):
    """CholeskyQR2: Q from two rounds of Gram-matrix Cholesky.

    For a (d, k) tall-skinny sharded-by-rows Y this needs only two (k, k)
    all-reduces instead of a distributed Householder QR — the beyond-paper
    collective optimization for the cold start.
    """
    def _cqr(A):
        k = A.shape[1]
        G = A.T @ A                                      # (k,k): psum if sharded
        Lc = jnp.linalg.cholesky(G + 1e-8 * jnp.eye(k, dtype=G.dtype))
        # Apply L^-T as a small replicated matmul (NOT solve_triangular on the
        # tall operand — XLA cannot partition that and would all-gather A).
        Linv = jax.scipy.linalg.solve_triangular(
            Lc, jnp.eye(k, dtype=G.dtype), lower=True)   # (k,k) replicated
        Q = A @ Linv.T
        return Q, Lc.T
    Q1, R1 = _cqr(Y)
    Q2, R2 = _cqr(Q1)
    return Q2, R2 @ R1


def rsvd_sharded(dW, m: int, *, n_iter: int = 4, oversample: int = 8,
                 key=None, qr_impl: str = "householder"):
    """Top-m left singular directions of ΔWᵀ, d_w-sharded friendly.

    dW: (n, d_w). All heavy ops are (d_w × small) matmuls; with d_w sharded
    over "model", XLA turns the small Gram products into psums.
    qr_impl: 'householder' (jnp.linalg.qr — baseline) or 'cholesky' (CQR2).
    """
    n, d = dW.shape
    k = min(m + oversample, n)
    if key is None:
        key = jax.random.PRNGKey(0)
    A = dW.astype(jnp.float32).T                         # (d, n)
    qr = jnp.linalg.qr if qr_impl == "householder" \
        else (lambda Y: cholesky_qr2(Y))
    omega = jax.random.normal(key, (n, k), jnp.float32)
    Y = A @ omega
    Q = qr(Y)[0]
    for _ in range(n_iter):
        W = qr(A.T @ Q)[0]
        Q = qr(A @ W)[0]
    B = Q.T @ A                                          # (k, n)
    Ub, s, _ = jnp.linalg.svd(B, full_matrices=False)
    return (Q @ Ub)[:, :m]


def edc_embedding_distributed(dW, m: int, *, key=None,
                              qr_impl: str = "householder",
                              use_kernel: bool = False):
    """ΔW -> (E (n, m) cosine embedding, V). The group-cold-start hot path."""
    V = rsvd_sharded(dW, m, key=key, qr_impl=qr_impl)
    if use_kernel:
        from repro.kernels.ops import cosine_block
        return cosine_block(dW, V), V
    dots = dW.astype(jnp.float32) @ V
    rn = jnp.sqrt(jnp.sum(jnp.square(dW.astype(jnp.float32)), axis=1,
                          keepdims=True))
    cn = jnp.linalg.norm(V, axis=0, keepdims=True)
    return dots / jnp.maximum(rn * cn, 1e-12), V


def kmeans_step(E, centers):
    """One Lloyd iteration on the embedding (jit-friendly)."""
    d2 = jnp.sum(jnp.square(E[:, None, :] - centers[None]), -1)
    assign = jnp.argmin(d2, -1)
    onehot = jax.nn.one_hot(assign, centers.shape[0], dtype=jnp.float32)
    counts = jnp.sum(onehot, 0)
    sums = onehot.T @ E
    new = jnp.where(counts[:, None] > 0,
                    sums / jnp.maximum(counts[:, None], 1), centers)
    return assign, new

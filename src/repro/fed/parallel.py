"""Mesh-parallel FedGroup — the paper's technique as a first-class
distributed workload (the TPU-native replacement for the per-client loop).

The serial trainers' sharding helpers live here too: ``default_data_mesh``
(a 1-D "data" mesh over all visible devices, None on one device),
``default_fed_mesh`` (its 2-D ``(data, model)`` generalization, picked by
``REPRO_MODEL_AXIS``), and ``make_sharded_executor`` (jit of a round
executor with the client axis of every K-leading input sharded over the
mesh's data axes and — on a 2-D mesh — the m-stacked group parameters
sharded over "model" along the local solver's largest divisible parameter
dim, per ``sharding.specs.group_param_pspec``; a model axis of size 1
replicates, so the 1-device and 1-D paths are special cases) — the same
fused round runs client-parallel everywhere, not just under the dry-run
below. ``put_sharded_cohort`` is the multi-host-style feeding primitive:
per-data-shard host arrays go device-side with one H2D put per shard and
are assembled into a single global array via
``jax.make_array_from_single_device_arrays`` (see docs/scaling.md).

Two jittable entry points, both lowered by the FedGroup dry-run:

  parallel_round      one FedGroup communication round: K clients sharded
                      over the mesh "data" axis, each doing E epochs of local
                      SGD from its group's parameters, followed by per-group
                      weighted aggregation (segment-sum + psum).

  group_cold_start_distributed
                      Algorithm 3 at production scale: the pre-training
                      update matrix ΔW (n_pre × d_w, d_w up to hundreds of
                      millions) is sharded over the "model" axis along d_w;
                      randomized SVD + EDC embedding run as sharded matmuls.
                      ``qr_impl='cholesky'`` replaces tall-skinny QR with
                      CholeskyQR2 (Gram matrix + psum of an (k×k) block) —
                      the beyond-paper collective optimization (§Perf).

Both are pure functions of arrays, so they lower/compile under pjit with
the shardings chosen in launch/fed_dryrun.py.
"""
from __future__ import annotations

import os
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.modules import flatten_updates
from repro.sharding.specs import (MP_AXIS, block_staged_pspec, cohort_pspec,
                                  data_axis_names, group_param_pspec)


# ---------------------------------------------------------------------------
# Client-axis sharding for the serial trainers
# ---------------------------------------------------------------------------

def default_data_mesh():
    """A 1-D ("data",) mesh over all visible devices, or None on a single
    device — the trainers' auto-detected executor sharding (the 1-device
    None answer selects the plain-jit path)."""
    n = jax.device_count()
    if n <= 1:
        return None
    return jax.make_mesh((n,), ("data",))


def default_fed_mesh(model_axis: int | None = None):
    """The trainers' auto-detected mesh, generalized to 2-D.

    ``model_axis`` (default: ``REPRO_MODEL_AXIS`` env var, 1) is the size
    of the "model" axis the local solver's parameter dim shards over;
    the remaining devices form the "data" (client) axis. ``model_axis=1``
    degrades exactly to ``default_data_mesh()`` — the 1-D path (and None
    on a single device) is the special case, so existing behaviour is
    unchanged unless a model axis is asked for.
    """
    if model_axis is None:
        model_axis = int(os.environ.get("REPRO_MODEL_AXIS", "1"))
    if model_axis <= 1:
        return default_data_mesh()
    n = jax.device_count()
    if n % model_axis:
        raise ValueError(f"model_axis={model_axis} does not divide the "
                         f"{n} visible devices")
    return jax.make_mesh((n // model_axis, model_axis), ("data", MP_AXIS))


def mesh_data_shards(mesh) -> int:
    """Number of data-axis slices of ``mesh`` (1 for mesh=None) — the
    shard count of the client axis and of ``fed.store.ShardedClientStore``
    cohort slices."""
    if mesh is None:
        return 1
    total = 1
    for a in data_axis_names(mesh):
        total *= mesh.shape[a]
    return total


def shard_client_axis(mesh, tree):
    """device_put every array leaf with its leading (client) axis sharded
    over the mesh *data* axes when divisible, replicated otherwise.
    ``mesh=None`` degrades to a plain asynchronous ``jax.device_put`` — the
    unified H2D entry the population prefetcher uses, so streamed cohorts
    land pre-placed for the executor on one device and on a mesh alike.
    On a 2-D ``(data, model)`` mesh only the data axes consume the client
    axis; the model axis replicates (it shards parameters, not clients).

    Works on arbitrary pytrees, so the dynamic-assignment state (e.g.
    FeSEM's {"local_flat", "idx"}) shards leaf-by-leaf: local_flat by rows
    over all clients, idx over the selected-client axis.
    """
    if mesh is None:
        return jax.tree_util.tree_map(
            lambda l: jax.device_put(jnp.asarray(l)), tree)
    axes = data_axis_names(mesh)
    total = mesh_data_shards(mesh)

    def put(leaf):
        leaf = jnp.asarray(leaf)
        if leaf.ndim >= 1 and leaf.shape[0] % total == 0 and leaf.shape[0]:
            spec = cohort_pspec(leaf.ndim, data_axes=axes)
        else:
            spec = P(*([None] * leaf.ndim))
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree)


def put_sharded_cohort(mesh, parts):
    """Assemble per-shard host arrays into one mesh-global cohort array.

    ``parts`` is a list of same-structure pytrees, one per data-axis slice
    (``fed.store.ShardedClientStore.gather_train_shards`` output): shard
    ``s`` holds the rows the mesh's s-th data slice will own. Each shard's
    arrays are device_put *onto that slice's devices only* — one H2D
    transfer per shard, never a host-side concatenation of the full cohort
    — and stitched into a single global array with
    ``jax.make_array_from_single_device_arrays``. On one machine this
    simulates the multi-host feeding path exactly: a real multi-pod
    deployment runs the same code with each host contributing only its
    local shard. Falls back to ``shard_client_axis`` over the concatenated
    cohort when the row count does not divide the data axes (replication —
    the same degradation the non-divisible 1-D path takes).
    """
    n_shards = mesh_data_shards(mesh) if mesh is not None else 1
    if mesh is None or n_shards != len(parts):
        merged = jax.tree_util.tree_map(
            lambda *ls: np.concatenate([np.asarray(l) for l in ls]), *parts)
        return shard_client_axis(mesh, merged)
    axes = data_axis_names(mesh)

    def one(*leaf_parts):
        leaf_parts = [np.asarray(l) for l in leaf_parts]
        rows = sum(l.shape[0] for l in leaf_parts)
        block = rows // n_shards
        if block * n_shards != rows or \
                any(l.shape[0] != block for l in leaf_parts):
            return shard_client_axis(mesh, np.concatenate(leaf_parts))
        gshape = (rows,) + leaf_parts[0].shape[1:]
        sharding = NamedSharding(mesh, cohort_pspec(len(gshape), axes))
        arrs = []
        for dev, index in sharding.addressable_devices_indices_map(
                gshape).items():
            r = index[0]
            lo = 0 if r.start is None else int(r.start)
            arrs.append(jax.device_put(leaf_parts[lo // block], dev))
        return jax.make_array_from_single_device_arrays(
            gshape, sharding, arrs)

    return jax.tree_util.tree_map(one, *parts)


def make_sharded_executor(round_fn, mesh=None):
    """jit ``round_fn`` (a ``fed.rounds.make_round_executor`` product) with
    its client axis sharded over ``mesh``.

    mesh=None (single device) is the plain-jit special case. With a mesh,
    the K-axis inputs (membership or assignment state, X, Y, n, keys) are
    placed with their leading axis sharded over the data axes before
    dispatch, and the m-stacked group parameters are placed per
    ``sharding.specs.group_param_pspec``: replicated on a 1-D (or
    model-axis-1) mesh — the PR-2 behaviour — or sharded over "model"
    along the local solver's largest divisible parameter dim on a 2-D
    ``(data, model)`` mesh. The compiled round then runs client-parallel
    over "data" and solver-parallel over "model", with XLA inserting the
    segment-sum and contraction all-reduces.
    """
    jfn = jax.jit(round_fn)
    if mesh is None:
        return jfn
    model_size = dict(mesh.shape).get(MP_AXIS, 1)
    place_groups = lambda t: jax.tree_util.tree_map(
        lambda l: jax.device_put(
            l, NamedSharding(mesh, group_param_pspec(jnp.shape(l),
                                                     model_size))), t)

    def call(group_params, assign, X, Y, n, keys):
        group_params = place_groups(group_params)
        assign, X, Y, n, keys = (shard_client_axis(mesh, t)
                                 for t in (assign, X, Y, n, keys))
        return jfn(group_params, assign, X, Y, n, keys)

    return call


def make_sharded_block_executor(block_fn, mesh=None):
    """jit ``block_fn`` (a ``fed.rounds.make_block_executor`` product) with
    the round-to-round carry DONATED and, on a mesh, the same placement as
    the per-round executor.

    ``donate_argnums=(0,)`` hands the carry's buffers (m-stacked group
    params, membership, FeSEM local_flat) back to XLA, so B rounds of group
    state update in place instead of reallocating every block — the
    steady-state device allocation win the ``round_block`` bench records.

    mesh=None (single device) is the plain donating-jit special case. With
    a mesh, the carry's m-stacked group params follow
    ``sharding.specs.group_param_pspec`` (replicated at model-axis 1), the
    pinned train/test stacks shard their leading (client) axis over the
    data axes when divisible (``shard_client_axis``), and the staged
    ``(B, K, ...)`` tensors shard their *client* axis — axis 1, the scan
    consumes axis 0 — per ``sharding.specs.block_staged_pspec``. The rest
    of the carry (membership, aux, deltas) replicates: it is O(N + m·d_w),
    gathered/scattered by client id in-program.
    """
    jfn = jax.jit(block_fn, donate_argnums=(0,))
    if mesh is None:
        return jfn
    model_size = dict(mesh.shape).get(MP_AXIS, 1)
    axes = data_axis_names(mesh)
    total = mesh_data_shards(mesh)
    replicate = lambda t: jax.tree_util.tree_map(
        lambda l: jax.device_put(jnp.asarray(l), NamedSharding(
            mesh, P(*([None] * jnp.ndim(l))))), t)
    place_groups = lambda t: jax.tree_util.tree_map(
        lambda l: jax.device_put(l, NamedSharding(
            mesh, group_param_pspec(jnp.shape(l), model_size))), t)

    def place_staged(leaf):
        leaf = jnp.asarray(leaf)
        if leaf.ndim >= 2 and leaf.shape[1] % total == 0 and leaf.shape[1]:
            spec = block_staged_pspec(leaf.ndim, data_axes=axes)
        else:
            spec = P(*([None] * leaf.ndim))
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    def call(carry, train_stack, test_stack, idx, keys, alive, do_eval):
        carry = dict(carry,
                     group_params=place_groups(carry["group_params"]),
                     global_params=place_groups(carry["global_params"]),
                     group_delta=replicate(carry["group_delta"]),
                     membership=replicate(carry["membership"]),
                     aux=replicate(carry["aux"]))
        train_stack = shard_client_axis(mesh, train_stack)
        test_stack = shard_client_axis(mesh, test_stack)
        idx, keys, alive = (jax.tree_util.tree_map(place_staged, t)
                            for t in (idx, keys, alive))
        return jfn(carry, train_stack, test_stack, idx, keys, alive,
                   replicate(do_eval))

    return call


def make_async_dispatch_executor(dispatch_fn, mesh=None):
    """jit ``dispatch_fn`` (a ``fed.rounds.make_async_dispatch_executor``
    product) with the block executor's mesh placement but WITHOUT donating
    the snapshot carry.

    The async runtime (``FedConfig.async_depth``) keeps up to D dispatches
    in flight against the *same* current carry, so the dispatch input must
    stay alive — donation moves to the staleness fold instead
    (``make_async_fold``), which consumes both the current carry and the
    per-dispatch result. mesh=None (single device) is the plain-jit
    special case; with a mesh the carry / pinned stacks / staged cohort
    tensors are placed exactly as ``make_sharded_block_executor`` places
    them (group params per ``group_param_pspec``, the (K,)-leading staged
    arrays over the data axes, the rest replicated).
    """
    jfn = jax.jit(dispatch_fn)
    if mesh is None:
        return jfn
    model_size = dict(mesh.shape).get(MP_AXIS, 1)
    replicate = lambda t: jax.tree_util.tree_map(
        lambda l: jax.device_put(jnp.asarray(l), NamedSharding(
            mesh, P(*([None] * jnp.ndim(l))))), t)
    place_groups = lambda t: jax.tree_util.tree_map(
        lambda l: jax.device_put(l, NamedSharding(
            mesh, group_param_pspec(jnp.shape(l), model_size))), t)

    def call(carry, train_stack, idx, keys, alive):
        carry = dict(carry,
                     group_params=place_groups(carry["group_params"]),
                     global_params=place_groups(carry["global_params"]),
                     group_delta=replicate(carry["group_delta"]),
                     membership=replicate(carry["membership"]),
                     aux=replicate(carry["aux"]))
        train_stack = shard_client_axis(mesh, train_stack)
        idx, keys, alive = (shard_client_axis(mesh, t)
                            for t in (idx, keys, alive))
        return jfn(carry, train_stack, idx, keys, alive)

    return call


def make_async_fold(fold_fn):
    """jit a ``fed.rounds.make_staleness_fold`` product with BOTH the
    current carry and the per-dispatch result donated — the fold is the
    single consumer of each dispatch's output buffers, and on the device
    stream every already-enqueued dispatch that reads the old current
    carry executes before the fold reuses it (dispatch, then fold, are
    enqueued in that order by the async loop). Works on mesh and
    single-device alike: the fold's inputs are outputs of earlier placed
    computations, so GSPMD propagates their shardings.

    The weight-1.0 passthrough keeps BOTH fold inputs live in the select,
    so XLA can alias the output to only one of the two donated trees —
    the resulting "donated buffers were not usable" warning is expected
    and silenced here (the aliasable side still is aliased)."""
    jfn = jax.jit(fold_fn, donate_argnums=(0, 1))

    def call(*args):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jfn(*args)

    return call


# ---------------------------------------------------------------------------
# One round, client-parallel
# ---------------------------------------------------------------------------

def make_parallel_round(model, *, epochs: int, batch_size: int, lr: float,
                        mu: float, n_groups: int, max_samples: int,
                        quarantine: bool = False,
                        quarantine_mult: float = 10.0):
    """Returns round_fn(group_params_stacked, membership, X, Y, n, keys)
      -> (new group params stacked, auxiliary global params, group deltas).

    group_params_stacked: pytree with leading axis m.
    membership: (K,) int group id per selected client.
    X: (K, max_n, ...), Y: (K, max_n), n: (K,), keys: (K, 2) uint32.

    Thin adapter over ``fed.rounds.make_round_executor`` — the same fused
    round the serial trainers dispatch; only the mesh shardings differ
    (chosen in launch/fed_dryrun.py). The executor's extra outputs
    (discrepancy, flattened group deltas) are dead code here and XLA
    eliminates them when this round_fn is jitted. ``quarantine`` installs
    the same in-program update screen as the engine path — the per-client
    norm reductions shard over the data axes with the cohort, and the
    median is a cohort-global reduction the partitioner turns into an
    all-gather, so screening costs no extra dispatch on a mesh either.
    """
    from repro.fed.rounds import make_round_executor
    core = make_round_executor(model, epochs=epochs, batch_size=batch_size,
                               lr=lr, mu=mu, n_groups=n_groups,
                               max_samples=max_samples, eta_g=0.0,
                               quarantine=quarantine,
                               quarantine_mult=quarantine_mult)

    def round_fn(group_params, membership, X, Y, n, keys):
        out = core(group_params, membership, X, Y, n, keys)
        return out.group_params, out.global_params, out.agg_delta

    return round_fn


# ---------------------------------------------------------------------------
# Distributed group cold start (Algorithm 3 at scale)
# ---------------------------------------------------------------------------

def cholesky_qr2(Y):
    """CholeskyQR2: Q from two rounds of Gram-matrix Cholesky.

    For a (d, k) tall-skinny sharded-by-rows Y this needs only two (k, k)
    all-reduces instead of a distributed Householder QR — the beyond-paper
    collective optimization for the cold start.
    """
    def _cqr(A):
        k = A.shape[1]
        G = A.T @ A                                      # (k,k): psum if sharded
        Lc = jnp.linalg.cholesky(G + 1e-8 * jnp.eye(k, dtype=G.dtype))
        # Apply L^-T as a small replicated matmul (NOT solve_triangular on the
        # tall operand — XLA cannot partition that and would all-gather A).
        Linv = jax.scipy.linalg.solve_triangular(
            Lc, jnp.eye(k, dtype=G.dtype), lower=True)   # (k,k) replicated
        Q = A @ Linv.T
        return Q, Lc.T
    Q1, R1 = _cqr(Y)
    Q2, R2 = _cqr(Q1)
    return Q2, R2 @ R1


def rsvd_sharded(dW, m: int, *, n_iter: int = 4, oversample: int = 8,
                 key=None, qr_impl: str = "householder"):
    """Top-m left singular directions of ΔWᵀ, d_w-sharded friendly.

    dW: (n, d_w). All heavy ops are (d_w × small) matmuls; with d_w sharded
    over "model", XLA turns the small Gram products into psums.
    qr_impl: 'householder' (jnp.linalg.qr — baseline) or 'cholesky' (CQR2).
    """
    n, d = dW.shape
    k = min(m + oversample, n)
    if key is None:
        key = jax.random.PRNGKey(0)
    A = dW.astype(jnp.float32).T                         # (d, n)
    qr = jnp.linalg.qr if qr_impl == "householder" \
        else (lambda Y: cholesky_qr2(Y))
    omega = jax.random.normal(key, (n, k), jnp.float32)
    Y = A @ omega
    Q = qr(Y)[0]
    for _ in range(n_iter):
        W = qr(A.T @ Q)[0]
        Q = qr(A @ W)[0]
    B = Q.T @ A                                          # (k, n)
    Ub, s, _ = jnp.linalg.svd(B, full_matrices=False)
    return (Q @ Ub)[:, :m]


def edc_embedding_distributed(dW, m: int, *, key=None,
                              qr_impl: str = "householder",
                              use_kernel: bool = False):
    """ΔW -> (E (n, m) cosine embedding, V). The group-cold-start hot path."""
    V = rsvd_sharded(dW, m, key=key, qr_impl=qr_impl)
    if use_kernel:
        from repro.kernels.ops import cosine_block
        return cosine_block(dW, V), V
    dots = dW.astype(jnp.float32) @ V
    rn = jnp.sqrt(jnp.sum(jnp.square(dW.astype(jnp.float32)), axis=1,
                          keepdims=True))
    cn = jnp.linalg.norm(V, axis=0, keepdims=True)
    return dots / jnp.maximum(rn * cn, 1e-12), V


def kmeans_step(E, centers):
    """One Lloyd iteration on the embedding (jit-friendly)."""
    d2 = jnp.sum(jnp.square(E[:, None, :] - centers[None]), -1)
    assign = jnp.argmin(d2, -1)
    onehot = jax.nn.one_hot(assign, centers.shape[0], dtype=jnp.float32)
    counts = jnp.sum(onehot, 0)
    sums = onehot.T @ E
    new = jnp.where(counts[:, None] > 0,
                    sums / jnp.maximum(counts[:, None], 1), centers)
    return assign, new

"""Pytree checkpointing to .npz (flattened key paths), restart-safe."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(path: str, tree, metadata: dict | None = None):
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(kp)] = np.asarray(leaf)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, __meta__=json.dumps(metadata or {}), **flat)


def load_pytree(path: str, template):
    """Restore into the structure of ``template`` (shapes must match)."""
    data = np.load(path, allow_pickle=False)
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, tmpl in leaves_paths:
        key = _path_str(kp)
        arr = data[key]
        if arr.shape != tmpl.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {tmpl.shape}")
        leaves.append(jnp.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    data = np.load(path, allow_pickle=False)
    return json.loads(str(data["__meta__"]))

"""Pytree checkpointing to .npz (flattened key paths), restart-safe.

``save_pytree`` is **atomic**: the archive is written to a temp file in the
target directory and moved into place with ``os.replace``, so a reader (or
a resumed run) never observes a half-written checkpoint — a process killed
mid-save leaves the previous checkpoint intact. The archive is written
through a file handle, so the path given is the path on disk (``np.savez``
would silently append ``.npz`` to a bare string path and a later
``load_pytree(path)`` would miss it).

``load_pytree`` is **strict**: the stored keys must match the template's
flattened key paths exactly — a missing key is corruption, an extra key is
a template/file mismatch (e.g. restoring a FeSEM checkpoint into a FedAvg
trainer), and both raise instead of silently restoring a subset.

The federated engine (``fed/engine.py``) builds its round checkpoints on
these primitives: ``checkpoint_path``/``latest_checkpoint`` name and find
per-round snapshots, and ``saved_array_specs`` lets a restorer build a
template for variable-size state (lazy state-table rows, arrival queues)
straight from the archive.
"""
from __future__ import annotations

import json
import os
import re
import zipfile
import zlib

import jax
import jax.numpy as jnp
import numpy as np

_CKPT_RE = re.compile(r"ckpt_(\d+)\.npz$")

# Archive format version, bumped whenever the checkpoint schema changes
# (v1: PR-6 fault-tolerant runtime; v2: async runtime — per-group staleness
# clocks, async degradation counters and population fault/lease stats in
# the metadata; v3: telemetry — the unified ``repro.obs`` metrics-registry
# snapshot rides the metadata as ``"obs"``, replacing the scattered
# ``async_stats`` dict, so every degradation counter survives
# kill-and-resume through one surface; v4: integrity + fleet — per-array
# CRC32 checksums ride the metadata as ``"__crc__"`` and the coordinator's
# control-plane snapshot as ``"fleet"``). Stored inside the ``__meta__``
# JSON; archives written before versioning existed read back as v1.
# Loaders check the version FIRST, so an old file fails with a clear
# "checkpoint format version X, expected Y" error instead of a raw
# key/shape-mismatch traceback. Versions back to ``_MIN_READ_VERSION``
# still load (a pre-checksum v3 archive simply skips CRC verification —
# both additions are metadata-only, the array schema is unchanged).
CKPT_FORMAT_VERSION = 4
_MIN_READ_VERSION = 3
_FORMAT_KEY = "__ckpt_format__"
_CRC_KEY = "__crc__"


class CheckpointFormatError(ValueError):
    """Archive was written by an incompatible checkpoint format version."""


class CheckpointCorruptError(ValueError):
    """Archive failed an integrity check (torn write / bit flip): a stored
    array's CRC32 does not match the checksum recorded at save time, or
    the zip container itself is damaged."""


def _check_format(path: str, meta: dict):
    version = int(meta.get(_FORMAT_KEY, 1))
    if not _MIN_READ_VERSION <= version <= CKPT_FORMAT_VERSION:
        raise CheckpointFormatError(
            f"{path}: checkpoint format version {version}, expected "
            f"{CKPT_FORMAT_VERSION} (>= {_MIN_READ_VERSION} accepted) — "
            f"re-create the checkpoint with this version of the code (the "
            f"archive schema changed)")


def _crc(arr: np.ndarray) -> int:
    """CRC32 of an array's C-order bytes (dtype/shape are covered by the
    loader's own strict template checks)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _load_npz(path: str):
    """``np.load`` with container damage surfaced as corruption, not a raw
    zipfile traceback."""
    try:
        return np.load(path, allow_pickle=False)
    except (zipfile.BadZipFile, EOFError) as e:
        raise CheckpointCorruptError(
            f"{path}: archive container is damaged ({e}) — torn write or "
            f"truncation; restore from an earlier checkpoint") from e


def _read_array(path: str, data, key: str) -> np.ndarray:
    try:
        return data[key]
    except (zipfile.BadZipFile, EOFError, zlib.error) as e:
        raise CheckpointCorruptError(
            f"{path}: stored array {key!r} is unreadable ({e}) — the "
            f"archive is corrupt; restore from an earlier checkpoint") from e


def _verify_crc(path: str, crcs, key: str, arr: np.ndarray):
    """Check one stored array against the save-time checksum table (a
    pre-v4 archive has no table — verification is skipped)."""
    if crcs is None:
        return
    stored = crcs.get(key)
    if stored is not None and _crc(arr) != int(stored):
        raise CheckpointCorruptError(
            f"{path}: stored array {key!r} failed its CRC32 integrity "
            f"check — the archive is corrupt (bit flip or partial "
            f"overwrite); restore from an earlier checkpoint")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(path: str, tree, metadata: dict | None = None):
    """Atomically write ``tree`` (+ JSON-able ``metadata``) to ``path``."""
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(kp)] = np.asarray(leaf)
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        # a file handle keeps np.savez from appending its implicit ".npz"
        # suffix, so `path` is exactly the file on disk
        meta = dict(metadata or {})
        meta[_FORMAT_KEY] = CKPT_FORMAT_VERSION
        # per-array integrity checksums (format v4): verified on load, so
        # a bit-flipped or partially-overwritten archive raises
        # CheckpointCorruptError instead of silently restoring garbage
        meta[_CRC_KEY] = {k: _crc(v) for k, v in flat.items()}
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_pytree(path: str, template):
    """Restore into the structure of ``template``.

    Strict: the archive's keys and the template's flattened key paths must
    match exactly (no silently ignored extras, no missing leaves), and
    every array shape must match its template leaf. The format version is
    checked FIRST — an archive from another version raises
    ``CheckpointFormatError`` instead of a key/shape mismatch.
    """
    data = _load_npz(path)
    crcs = None
    if "__meta__" in data.files:
        meta = json.loads(str(data["__meta__"]))
        _check_format(path, meta)
        crcs = meta.get(_CRC_KEY)    # absent in pre-v4 archives
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    tmpl_keys = {_path_str(kp) for kp, _ in leaves_paths}
    file_keys = set(data.files) - {"__meta__"}
    missing, extra = sorted(tmpl_keys - file_keys), sorted(file_keys - tmpl_keys)
    if missing or extra:
        raise ValueError(
            f"checkpoint {path} does not match the template: "
            f"missing keys {missing or 'none'}, extra keys {extra or 'none'}")
    leaves = []
    for kp, tmpl in leaves_paths:
        key = _path_str(kp)
        arr = _read_array(path, data, key)
        _verify_crc(path, crcs, key, arr)
        if arr.shape != tmpl.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {tmpl.shape}")
        # a numpy template leaf stays host-side (jnp would truncate int64
        # state arrays under the default x64-disabled config)
        if isinstance(tmpl, np.ndarray):
            leaves.append(np.asarray(arr, dtype=tmpl.dtype))
        else:
            leaves.append(jnp.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    """The archive's JSON metadata. Raises ``CheckpointFormatError`` on a
    format-version mismatch (e.g. a pre-versioning v1 file) — the engine
    calls this before any template matching, so old checkpoints fail with
    the clear version error, never a raw key/shape traceback."""
    data = _load_npz(path)
    meta = json.loads(str(data["__meta__"]))
    _check_format(path, meta)
    meta.pop(_FORMAT_KEY, None)
    meta.pop(_CRC_KEY, None)        # internal, like the format key
    return meta


def saved_array_specs(path: str) -> dict:
    """``{key: (shape, dtype)}`` of every stored array — enough to build a
    ``load_pytree`` template for state whose size is only known at save
    time (lazy state-table rows, scheduler arrival queues)."""
    data = _load_npz(path)
    return {k: (data[k].shape, data[k].dtype)
            for k in data.files if k != "__meta__"}


def checkpoint_path(directory: str, t: int) -> str:
    """Canonical name of the round-``t`` checkpoint in ``directory``."""
    return os.path.join(directory, f"ckpt_{t:08d}.npz")


def prune_checkpoints(directory: str, keep: int) -> list:
    """Delete all but the newest ``keep`` ``ckpt_<t>.npz`` archives in
    ``directory`` (by round number); returns the removed paths. Intended
    to run *after* a successful atomic write — the newest archive always
    survives, so a crash mid-prune can only leave extra (older, intact)
    checkpoints behind, never fewer than ``keep``. Non-checkpoint files
    are untouched; ``keep <= 0`` is a no-op (keep-all)."""
    if keep <= 0:
        return []
    try:
        names = os.listdir(directory)
    except OSError:
        return []
    found = sorted((int(m.group(1)), name) for name in names
                   if (m := _CKPT_RE.fullmatch(name)))
    removed = []
    for _, name in found[:-keep]:
        path = os.path.join(directory, name)
        try:
            os.remove(path)
            removed.append(path)
        except OSError:
            pass    # raced with another pruner / already gone — harmless
    return removed


def latest_checkpoint(directory: str) -> str | None:
    """Path of the highest-round ``ckpt_*.npz`` in ``directory`` (None if
    there is none — e.g. a run killed before its first checkpoint)."""
    best_t, best = -1, None
    try:
        names = os.listdir(directory)
    except OSError:
        return None
    for name in names:
        m = _CKPT_RE.fullmatch(name)
        if m and int(m.group(1)) > best_t:
            best_t, best = int(m.group(1)), os.path.join(directory, name)
    return best

"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def cosine_block_ref(dW, V):
    """E[i, j] = <ΔW_i, V_:,j> / (||ΔW_i|| ||V_:,j||).

    dW: (n, d); V: (d, m) -> (n, m) float32.
    """
    dW32 = dW.astype(jnp.float32)
    V32 = V.astype(jnp.float32)
    dots = dW32 @ V32
    rn = jnp.linalg.norm(dW32, axis=1, keepdims=True)
    cn = jnp.linalg.norm(V32, axis=0, keepdims=True)
    return dots / jnp.maximum(rn * cn, _EPS)


def swa_attention_ref(q, k, v, *, window: int | None, causal: bool = True,
                      scale: float | None = None):
    """Dense masked softmax attention oracle.

    q: (B, Sq, H, hd); k, v: (B, Sk, H, hd). Query i is at absolute position
    i + (Sk - Sq) (decode tail alignment). Returns (B, Sq, H, hd) in fp32.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if scale is None:
        scale = 1.0 / hd ** 0.5
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


def ssd_chunk_ref(X, dtA, B, C):
    """Single-chunk SSD oracle via the sequential recurrence.

    X: (b, q, h, p); dtA: (b, q, h); B, C: (b, q, h, n).
    Returns (Y (b,q,h,p), final_state (b,h,p,n)), all fp32.
    """
    b, q, h, p = X.shape
    n = B.shape[-1]
    X32, A32 = X.astype(jnp.float32), dtA.astype(jnp.float32)
    B32, C32 = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(state, t):
        dec = jnp.exp(A32[:, t])[..., None, None]              # (b,h,1,1)
        state = dec * state + jnp.einsum("bhp,bhn->bhpn", X32[:, t], B32[:, t])
        y = jnp.einsum("bhpn,bhn->bhp", state, C32[:, t])
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(step, state0, jnp.arange(q))
    return ys.transpose(1, 0, 2, 3), final

"""Pallas TPU kernel: Mamba2 SSD intra-chunk block (the hybrid archs' compute
hot spot).

For each (batch·head, chunk) grid cell, computes the two dense pieces of the
chunked SSD algorithm entirely in VMEM:

  Y_diag = (C Bᵀ ⊙ L) X        with L[i,j] = exp(a_i − a_j) for j ≤ i
  state  = (B ⊙ exp(a_Q − a))ᵀ X     (the chunk's contribution to the
                                      inter-chunk recurrence)

a = inclusive cumsum of the per-step log decays (dt·A). The sequential
inter-chunk recurrence stays outside (it is O(seq/Q) tiny updates); this
kernel is the MXU-heavy part. Block shapes are (Q, P) / (Q, N) tiles padded
to the 128-lane boundary by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, *, q: int):
    x = x_ref[...].astype(jnp.float32)          # (Q, P)
    a = a_ref[...].astype(jnp.float32)[:, 0]    # (Q,)
    b = b_ref[...].astype(jnp.float32)          # (Q, N)
    c = c_ref[...].astype(jnp.float32)          # (Q, N)

    diff = a[:, None] - a[None, :]              # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    s = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * L
    y_ref[...] = jax.lax.dot_general(
        s, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    decay_last = jnp.exp(a[-1] - a)             # (Q,)
    bw = b * decay_last[:, None]
    st_ref[...] = jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(X, A_cs, B, C, *, interpret: bool = True):
    """X: (BH, NC, Q, P); A_cs: (BH, NC, Q) inclusive-cumsum log decays;
    B, C: (BH, NC, Q, N). Returns (Y_diag (BH,NC,Q,P) fp32,
    states (BH,NC,N,P) fp32)."""
    BH, NC, Q, P = X.shape
    N = B.shape[-1]
    pp = (P + 127) // 128 * 128
    np_ = (N + 127) // 128 * 128

    Xp = jnp.pad(X, ((0, 0), (0, 0), (0, 0), (0, pp - P)))
    Ap = A_cs[..., None]                                    # (BH,NC,Q,1)
    Bp = jnp.pad(B, ((0, 0), (0, 0), (0, 0), (0, np_ - N)))
    Cp = jnp.pad(C, ((0, 0), (0, 0), (0, 0), (0, np_ - N)))

    grid = (BH, NC)
    y, st = pl.pallas_call(
        functools.partial(_kernel, q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, Q, pp), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, Q, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, Q, np_), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, Q, np_), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, Q, pp), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, np_, pp), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, NC, Q, pp), jnp.float32),
            jax.ShapeDtypeStruct((BH, NC, np_, pp), jnp.float32),
        ],
        interpret=interpret,
    )(Xp, Ap, Bp, Cp)
    return y[..., :P], st[..., :N, :P]

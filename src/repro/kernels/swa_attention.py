"""Pallas TPU kernel: sliding-window flash attention (forward).

Used by the long-context (long_500k) variant of the dense architectures and
by Zamba2's shared attention block. Streaming-softmax over KV blocks with
running (max, denom, acc) in VMEM scratch — the classic flash pattern,
windowed: KV blocks entirely outside [q - window + 1, q] are masked out (the
block-index skipping optimization is a §Perf iteration; the baseline visits
every block and masks).

Layout: heads are folded into the grid's first axis; blocks are
(block_q, head_dim) and (block_k, head_dim) — head_dim is the lane dim and
is padded to 128 by the wrapper when needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, n_kv: int,
            window: int | None, causal: bool, q_offset: int, kv_valid: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale                # (bq, hd)
    k = k_ref[...].astype(jnp.float32)                        # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    ok = kpos < kv_valid                       # mask seq-padding KV slots
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                       # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[...].astype(jnp.float32)                        # (bk, hd)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] /
                        jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "causal", "block_q", "block_k", "interpret"))
def swa_attention(q, k, v, *, window: int | None = None, causal: bool = True,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = True):
    """q: (B, Sq, H, hd); k, v: (B, Sk, H, hd) -> (B, Sq, H, hd).

    Query i sits at absolute position i + (Sk - Sq) (decode-tail alignment,
    matching the jnp oracle).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / hd ** 0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    sq = (Sq + bq - 1) // bq * bq
    sk = (Sk + bk - 1) // bk * bk
    hdp = (hd + 127) // 128 * 128

    # fold (B, H) into one grid axis; pad seq + lane dims
    qf = jnp.pad(q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd),
                 ((0, 0), (0, sq - Sq), (0, hdp - hd)))
    kf = jnp.pad(k.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd),
                 ((0, 0), (0, sk - Sk), (0, hdp - hd)))
    vf = jnp.pad(v.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd),
                 ((0, 0), (0, sk - Sk), (0, hdp - hd)))

    n_kv = sk // bk
    grid = (B * H, sq // bq, n_kv)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=bq, block_k=bk,
                          n_kv=n_kv, window=window, causal=causal,
                          q_offset=Sk - Sq, kv_valid=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, hdp), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, bk, hdp), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bk, hdp), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hdp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, sq, hdp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hdp), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :Sq, :hd].reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return out

"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` mode (the kernel
body executes step-by-step in Python — bitwise-faithful to the TPU grid
semantics); on a real TPU set ``REPRO_PALLAS_COMPILE=1`` to lower them
through Mosaic.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.edc_cosine import edc_cosine
from repro.kernels.madc import madc_block as _madc_block
from repro.kernels.ssd_chunk import ssd_intra_chunk
from repro.kernels.swa_attention import swa_attention

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"

# Measured MADC kernel/reference crossovers (BENCH_clustering.json): below
# these the O(n³)-broadcast reference is faster than the kernel's tiling
# overhead, so measures.madc(use_kernel=True) falls back to it. Interpret
# mode executes the grid step-by-step in Python — there the kernel only
# pays off once the reference's (n, n, n) cube itself becomes the problem
# (n=512 -> 512 MB fp32); through Mosaic the crossover is the tile scale.
MADC_CROSSOVER_COMPILED_N = 128
MADC_CROSSOVER_INTERPRET_N = 512


def madc_crossover_n() -> int:
    """Active kernel-vs-reference crossover for the current backend mode."""
    return (MADC_CROSSOVER_INTERPRET_N if _INTERPRET
            else MADC_CROSSOVER_COMPILED_N)


def cosine_block(dW, V, **kw):
    """Fused cosine-similarity block E = K(ΔW, Vᵀ) (paper eq. 8)."""
    kw.setdefault("interpret", _INTERPRET)
    return edc_cosine(dW, V, **kw)


def madc_block(M, **kw):
    """Blocked MADC proximity matrix (paper eq. 7), O(bn²) memory."""
    kw.setdefault("interpret", _INTERPRET)
    return _madc_block(M, **kw)


def sliding_window_attention(q, k, v, *, window=None, causal=True, **kw):
    """Flash-style sliding-window attention forward."""
    kw.setdefault("interpret", _INTERPRET)
    return swa_attention(q, k, v, window=window, causal=causal, **kw)


def ssd_chunk_block(X, A_cs, B, C, **kw):
    """Mamba2 SSD intra-chunk block (Y_diag + chunk states)."""
    kw.setdefault("interpret", _INTERPRET)
    return ssd_intra_chunk(X, A_cs, B, C, **kw)

"""Pallas TPU kernel: blocked MADC proximity (paper eq. 7).

MADC(i, j) = (1 / (n - 2)) * Σ_{z ≠ i, j} |M_iz − M_jz| for a cosine
similarity matrix M (n, n). The jnp reference broadcasts an (n, n, n)
difference tensor — O(n³) memory — before reducing over z; at the paper's
pre-training scales (n = α·m up to a few hundred) that is already the
dominant allocation of the cold start, and it scales cubically.

This kernel computes the measure tile-by-tile: grid (n/bn, n/bn, n/bz) with
the z axis innermost as the reduction. Each step holds two (bn, bz) row
blocks of M in VMEM and accumulates |M_iz − M_jz| into a (bn, bn) VMEM
scratch, folding the z == i / z == j exclusion (and the padding mask) into
the accumulation instead of materializing and re-masking the full cube.
Peak live memory is O(bn·bz) per step — independent of n — and M is read
from HBM once per (i, j) block row-pair.

The intra-tile broadcast is chunked over ``sub_n`` rows of the i block so
the (sub_n, bn, bz) temporary stays a few hundred KB regardless of the
128-aligned block shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def madc_tiles(n: int) -> tuple:
    """(block_n, block_z) picked from n instead of fixed 128s.

    block_n (sublane) rounds n up to the fp32 tile's 8-row granule, capped
    at 128; block_z (lane) rounds up to the mandatory 128-lane granule,
    capped at 512 (two (bn, bz) input tiles + the (sub, bn, bz) broadcast
    chunk stay well under VMEM at the cap). Small n therefore stops padding
    to a full 128x128 tile — at n=32 the kernel does 16x less tile work
    than the old fixed blocks.
    """
    bn = min(128, -(-n // 8) * 8)
    bz = min(512, -(-n // 128) * 128)
    return bn, bz


def _kernel(mi_ref, mj_ref, out_ref, acc_ref, *, nz: int, n: int,
            block_n: int, block_z: int, sub_n: int):
    i, j, z = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(z == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mi = mi_ref[...].astype(jnp.float32)          # (bn, bz) rows of i block
    mj = mj_ref[...].astype(jnp.float32)          # (bn, bz) rows of j block

    # chunk the (bn, bn, bz) broadcast over sub_n rows of the i block to
    # bound the live temporary at sub_n * bn * bz floats
    for a0 in range(0, block_n, sub_n):
        a1 = min(a0 + sub_n, block_n)
        diff = jnp.abs(mi[a0:a1, None, :] - mj[None, :, :])  # (sub, bn, bz)
        shape = diff.shape
        z_idx = jax.lax.broadcasted_iota(jnp.int32, shape, 2) + z * block_z
        i_idx = (jax.lax.broadcasted_iota(jnp.int32, shape, 0)
                 + i * block_n + a0)
        j_idx = jax.lax.broadcasted_iota(jnp.int32, shape, 1) + j * block_n
        # z exclusion (self-similarity bias, eq. 7) + padding columns
        excl = (z_idx == i_idx) | (z_idx == j_idx) | (z_idx >= n)
        acc_ref[a0:a1, :] += jnp.sum(jnp.where(excl, 0.0, diff), axis=-1)

    @pl.when(z == nz - 1)
    def _finish():
        out_ref[...] = acc_ref[...] / max(n - 2, 1)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_z", "interpret"))
def madc_block(M, *, block_n: int | None = None, block_z: int | None = None,
               interpret: bool = True):
    """M: (n, n) cosine similarities -> (n, n) MADC dissimilarities (fp32).

    Block shapes default to ``madc_tiles(n)`` — sized from n, not fixed
    constants. Wrapper pads rows to block_n and columns to block_z; padded
    rows are sliced away, padded z columns are masked inside the kernel.
    """
    n = M.shape[0]
    tn, tz = madc_tiles(n)
    block_n = tn if block_n is None else block_n
    block_z = tz if block_z is None else block_z
    rn = (n + block_n - 1) // block_n * block_n
    cn = (n + block_z - 1) // block_z * block_z
    Mp = jnp.pad(M.astype(jnp.float32), ((0, rn - n), (0, cn - n)))

    nz = cn // block_z
    grid = (rn // block_n, rn // block_n, nz)
    out = pl.pallas_call(
        functools.partial(_kernel, nz=nz, n=n, block_n=block_n,
                          block_z=block_z, sub_n=min(8, block_n)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_z), lambda i, j, z: (i, z)),
            pl.BlockSpec((block_n, block_z), lambda i, j, z: (j, z)),
        ],
        out_specs=pl.BlockSpec((block_n, block_n), lambda i, j, z: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rn, rn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n, block_n), jnp.float32)],
        interpret=interpret,
    )(Mp, Mp)
    return out[:n, :n]

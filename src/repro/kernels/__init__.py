# Pallas kernels for the repo's measured hot spots. Inventory (all wrapped
# with interpret/compile selection in ops.py, jnp oracles in ref.py):
#   edc_cosine  fused cosine block E = K(dW, V^T)      (paper eq. 8)
#   madc        blocked MADC proximity, O(bn^2) memory (paper eq. 7)
#   swa         sliding-window flash attention forward
#   ssd         Mamba2 SSD intra-chunk block

"""Pallas TPU kernel: fused cosine-similarity block for the EDC measure.

E = K(ΔW, Vᵀ): the paper's eq. 8 inner loop — the perf-critical stage of the
EDC group cold start when d_w is large (ΔW is HDLSS: n ~ α·m clients, d_w up
to hundreds of millions). The MADC branch has its own fused measure kernel
(``kernels.madc.madc_block``, eq. 7); both are exposed via ``kernels.ops``.

Fusion: one HBM pass over ΔW per row-block computes BOTH the dot products
ΔW·V and the row norms ‖ΔW_i‖ (the reference implementation reads ΔW twice).
Tiling: grid (n/bn, d/bd); the d axis is the reduction — partial products
accumulate into VMEM scratch, normalization happens on the last d-step.
Block shapes are MXU-aligned (multiples of 128 on the contracting/lane dims);
m (number of groups) is padded to the 128-lane tile by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-12


def _kernel(dw_ref, v_ref, vnorm_ref, out_ref, acc_ref, nrm_ref, *, nd: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        nrm_ref[...] = jnp.zeros_like(nrm_ref)

    dw = dw_ref[...].astype(jnp.float32)          # (bn, bd)
    v = v_ref[...].astype(jnp.float32)            # (bd, m)
    acc_ref[...] += jax.lax.dot_general(
        dw, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    nrm_ref[...] += jnp.sum(jnp.square(dw), axis=1, keepdims=True)

    @pl.when(j == nd - 1)
    def _finish():
        rn = jnp.sqrt(nrm_ref[...])               # (bn, 1)
        denom = jnp.maximum(rn * vnorm_ref[...], _EPS)
        out_ref[...] = acc_ref[...] / denom


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def edc_cosine(dW, V, *, block_n: int = 128, block_d: int = 512,
               interpret: bool = True):
    """dW: (n, d), V: (d, m) -> (n, m) cosine similarities (fp32).

    Wrapper pads n to block_n, d to block_d and m to the 128-lane tile.
    """
    n, d = dW.shape
    m = V.shape[1]
    mp = (m + 127) // 128 * 128
    np_ = (n + block_n - 1) // block_n * block_n
    dp = (d + block_d - 1) // block_d * block_d

    dWp = jnp.pad(dW, ((0, np_ - n), (0, dp - d)))
    Vp = jnp.pad(V, ((0, dp - d), (0, mp - m)))
    vnorm = jnp.linalg.norm(Vp.astype(jnp.float32), axis=0, keepdims=True)
    vnorm = jnp.maximum(vnorm, _EPS)              # (1, mp)

    nd = dp // block_d
    grid = (np_ // block_n, nd)
    out = pl.pallas_call(
        functools.partial(_kernel, nd=nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_d, mp), lambda i, j: (j, 0)),
            pl.BlockSpec((1, mp), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, mp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_n, mp), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(dWp, Vp, vnorm)
    return out[:n, :m]

from repro.optim.solvers import (adamw_init, adamw_update, sgd_update,
                                 momentum_init, momentum_update,
                                 proximal_grad, cosine_schedule)

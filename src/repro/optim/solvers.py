"""Optimizers (no optax): SGD, momentum, AdamW, the FedProx proximal helper,
and a cosine LR schedule. All operate on pytrees of arrays."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_update(params, grads, lr: float):
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def momentum_init(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)


def momentum_update(params, grads, vel, lr: float, beta: float = 0.9):
    vel = jax.tree_util.tree_map(
        lambda v, g: beta * v + g.astype(jnp.float32), vel, grads)
    params = jax.tree_util.tree_map(
        lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype),
        params, vel)
    return params, vel


def adamw_init(params):
    z = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": z(), "nu": z(), "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt, lr: float, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    step = opt["step"] + 1
    t = step.astype(jnp.float32)
    bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * g32
        nu_n = b2 * nu + (1 - b2) * jnp.square(g32)
        u = (mu_n / bc1) / (jnp.sqrt(nu_n / bc2) + eps)
        p_n = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return p_n.astype(p.dtype), mu_n, nu_n

    out = jax.tree_util.tree_map(upd, params, grads, opt["mu"], opt["nu"])
    new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                   is_leaf=lambda o: isinstance(o, tuple))
    new_mu = jax.tree_util.tree_map(lambda o: o[1], out,
                                    is_leaf=lambda o: isinstance(o, tuple))
    new_nu = jax.tree_util.tree_map(lambda o: o[2], out,
                                    is_leaf=lambda o: isinstance(o, tuple))
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}


def proximal_grad(params, anchor, mu: float):
    """∇ of the FedProx term (μ/2)||w − w0||²."""
    return jax.tree_util.tree_map(lambda p, a: mu * (p - a), params, anchor)


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(step < warmup, warm, cos)

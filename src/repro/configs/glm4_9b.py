"""GLM-4-9B — dense decoder, RoPE, GQA(kv=2), SwiGLU. [hf:THUDM/glm-4-9b]"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=151552,
    mlp_act="silu", mlp_gated=True, qkv_bias=True, rope_theta=10000.0,
    source="hf:THUDM/glm-4-9b",
)

"""HuBERT-XLarge — encoder-only audio transformer (wav2vec2 arch).
[arXiv:2106.07447]

Per the modality carve-out the conv feature extractor is a stub:
``input_specs`` supplies frame embeddings (B, S, 512). The transformer
encoder (bidirectional attention) + frame-classification head are fully
implemented. RoPE stands in for the original conv positional embedding
(TPU adaptation, noted in DESIGN.md). No decode step exists (encoder-only):
decode shapes are skipped.
"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    mlp_act="gelu", mlp_gated=False, causal=False, rope_theta=10000.0,
    frontend="audio", frontend_dim=512,
    source="arXiv:2106.07447",
)

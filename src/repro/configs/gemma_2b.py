"""Gemma-2B — GeGLU, head_dim=256, MQA(kv=1), tied embeddings, embedding
scaling by sqrt(d_model). [arXiv:2403.08295]"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    mlp_act="geglu_gelu", mlp_gated=True, tie_embeddings=True,
    embed_scale=True, rope_theta=10000.0,
    source="arXiv:2403.08295",
)

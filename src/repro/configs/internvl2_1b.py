"""InternVL2-1B — Qwen2-0.5B language backbone + InternViT frontend (stub).
[arXiv:2404.16821]

Per the modality carve-out, the vision encoder is a stub: ``input_specs``
supplies pre-computed patch embeddings (B, 256, 1024); the projector MLP and
the language decoder are fully implemented.
"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151655,
    mlp_act="silu", mlp_gated=True, qkv_bias=True, rope_theta=1000000.0,
    frontend="vision", frontend_dim=1024, n_patches=256,
    source="arXiv:2404.16821",
)

"""Granite-3.0-1B-A400M — 32-expert top-8 MoE decoder.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, moe_d_ff=512, vocab_size=49155,
    n_experts=32, top_k=8,
    mlp_act="silu", mlp_gated=True, rope_theta=10000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

"""Granite-20B-Code — dense decoder, llama-style, MQA(kv=1). [arXiv:2405.04324]"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    mlp_act="silu", mlp_gated=True, rope_theta=10000.0,
    source="arXiv:2405.04324",
)

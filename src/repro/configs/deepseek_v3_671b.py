"""DeepSeek-V3 671B — MLA attention + MoE (1 shared + 256 routed, top-8).
[arXiv:2412.19437]

Deviations (documented in DESIGN.md): all 61 layers are MoE (the real model
keeps the first 3 dense); the MTP auxiliary head is available as the optional
``mtp`` example, not part of the core step.
"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, moe_d_ff=2048, vocab_size=129280,
    n_experts=256, top_k=8, n_shared_experts=1,
    mla=True, q_rank=1536, kv_rank=512, qk_nope=128, qk_rope=64,
    v_head_dim=128,
    mlp_act="silu", mlp_gated=True, rope_theta=10000.0,
    source="arXiv:2412.19437",
)

"""xLSTM-350M — mLSTM blocks with sLSTM blocks interleaved. [arXiv:2405.04517]

d_ff=0 per the assignment: mLSTM blocks carry their own 2x up-projection and
sLSTM blocks a 4/3 gated post-FFN, so there is no standalone transformer FFN.
"""
from repro.models.zoo import ArchConfig

_pattern = tuple("s" if i in (5, 11, 17, 23) else "m" for i in range(24))

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm_pattern=_pattern, mlstm_proj_factor=2, xlstm_chunk=32,
    source="arXiv:2405.04517",
)

"""Architecture registry: ``--arch <id>`` resolution + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from repro.models.zoo import ArchConfig

from repro.configs import (deepseek_v3_671b, gemma_2b, glm4_9b, granite_20b,
                           granite_moe_1b, hubert_xlarge, internvl2_1b,
                           nemotron_4_15b, xlstm_350m, zamba2_1p2b)

ARCHS: dict[str, ArchConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (glm4_9b, granite_20b, deepseek_v3_671b, internvl2_1b,
              zamba2_1p2b, xlstm_350m, granite_moe_1b, gemma_2b,
              hubert_xlarge, nemotron_4_15b)
}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts.

    Used by the per-arch CPU smoke tests (one forward/train step, assert
    shapes + no NaNs). Dim ratios keep each family's structural constraints
    (GQA divisibility, MoE top_k <= n_experts, SSD head divisibility...).
    """
    kw: dict = dict(
        n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        dtype="float32", remat=False, lr=1e-2,
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, moe_d_ff=128,
                  n_heads=4, n_kv_heads=2, head_dim=64)
        if cfg.mla:
            kw.update(q_rank=64, kv_rank=32, qk_nope=32, qk_rope=16,
                      v_head_dim=32)
    elif cfg.family == "hybrid":
        kw.update(n_heads=4, n_kv_heads=4, head_dim=64,
                  ssm_head_dim=32, ssm_state=16, shared_attn_period=2,
                  ssd_chunk=16)
    elif cfg.family == "ssm":
        kw.update(n_heads=4, xlstm_pattern=("m", "s"), xlstm_chunk=8, d_ff=0)
    elif cfg.family == "audio":
        kw.update(n_heads=4, n_kv_heads=4, head_dim=64, frontend_dim=64)
    elif cfg.family == "vlm":
        kw.update(n_heads=4, n_kv_heads=2, head_dim=64, frontend_dim=64,
                  n_patches=16)
    else:  # dense
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=64)
    return dataclasses.replace(cfg, **kw)

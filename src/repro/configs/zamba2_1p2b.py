"""Zamba2-1.2B — Mamba2 backbone + shared attention block. [arXiv:2411.15242]

38 Mamba2 layers; one weight-shared attention+MLP block is applied every 6
layers (each application keeps its own KV cache at decode time). The real
model concatenates original embeddings into the shared block and adds LoRA
per application; we apply the shared block on the residual stream directly
(noted in DESIGN.md).
"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, shared_attn_period=6,
    mlp_act="silu", mlp_gated=True, rope_theta=10000.0,
    source="arXiv:2411.15242",
)

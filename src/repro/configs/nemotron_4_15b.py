"""Nemotron-4-15B — dense decoder, GQA(kv=8), squared-ReLU MLP.
[arXiv:2402.16819]"""
from repro.models.zoo import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    mlp_act="relu2", mlp_gated=False, rope_theta=10000.0,
    source="arXiv:2402.16819",
)

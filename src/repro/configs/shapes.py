"""Assigned input shapes and ShapeDtypeStruct factories for the dry-run.

The four workload shapes assigned to this paper:

  train_4k     seq_len=  4,096  global_batch=256   (training)
  prefill_32k  seq_len= 32,768  global_batch= 32   (inference prefill)
  decode_32k   seq_len= 32,768  global_batch=128   (inference decode: ONE new
                                                    token, KV cache of seq_len)
  long_500k    seq_len=524,288  global_batch=  1   (long-context decode)

``input_specs`` returns pure ``jax.ShapeDtypeStruct`` stand-ins: weak-type
correct, shardable, no device allocation ever happens.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import zoo

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# Sliding window used by full-attention archs for long_500k (see DESIGN.md).
LONG_CONTEXT_WINDOW = 8192


def batch_specs(cfg: zoo.ArchConfig, shape: InputShape):
    """ShapeDtypeStructs for a train/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        return {"frames": SDS((B, S, cfg.frontend_dim), jnp.dtype(cfg.dtype)),
                "labels": SDS((B, S), jnp.int32)}
    if cfg.family == "vlm":
        S_txt = S - cfg.n_patches
        return {"tokens": SDS((B, S_txt), jnp.int32),
                "patch_embeds": SDS((B, cfg.n_patches, cfg.frontend_dim),
                                    jnp.dtype(cfg.dtype)),
                "labels": SDS((B, S_txt), jnp.int32)}
    return {"tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32)}


def decode_specs(cfg: zoo.ArchConfig, shape: InputShape):
    """ShapeDtypeStructs for one serve_step: tokens, positions and the cache.

    For windowed attention the KV ring buffer is ``window`` slots, not
    seq_len — that is the entire point of the sliding-window variant.
    """
    B = shape.global_batch
    max_len = shape.seq_len
    if cfg.window is not None:
        max_len = min(max_len, cfg.window)
    cache = jax.eval_shape(lambda: zoo.init_cache(cfg, B, max_len))
    return {"tokens": SDS((B, 1), jnp.int32),
            "pos": SDS((B,), jnp.int32),
            "cache": cache}


def supported(cfg: zoo.ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is runnable, plus a reason when skipped."""
    if shape.kind == "decode" and cfg.family == "audio":
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k":
        eff = cfg if cfg.family in ("ssm", "hybrid") else cfg
        if cfg.family in ("ssm", "hybrid"):
            return True, "native sub-quadratic"
        return True, f"sliding-window variant (window={LONG_CONTEXT_WINDOW})"
    return True, ""


def config_for(cfg: zoo.ArchConfig, shape: InputShape) -> zoo.ArchConfig:
    """Shape-adjusted config: long_500k switches attention to sliding-window
    for every arch that has attention layers."""
    if shape.name == "long_500k" and cfg.family != "ssm":
        return cfg.with_window(LONG_CONTEXT_WINDOW)
    return cfg

"""Data-driven distance measures for client clustering (paper §3.3).

  cosine_similarity_matrix  M_ij = S(i,j)                      (eq. 5/6)
  madc                      mean abs. diff of pairwise cosines (eq. 7)
  edc_embed / edc           decomposed cosine embedding         (eq. 8)

EDC first truncates ΔWᵀ to its top-m singular directions V, then embeds each
client as its cosine similarities to those directions; the Euclidean distance
of the embeddings ("EDC") approximates MADC at O(m² d_w) instead of
O(n² d_w) and — unlike raw ℓp on HDLSS vectors — does not suffer distance
concentration.

The inner product blocks here delegate to the Pallas kernel wrapper in
``repro.kernels.ops`` when ``use_kernel=True`` (TPU path); the default is the
pure-jnp path that XLA fuses fine on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.svd import randomized_truncated_svd

_EPS = 1e-12


def row_normalize(x):
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, _EPS)


def cosine_similarity_matrix(dw_a, dw_b=None):
    """K(A, B): (n, q) pairwise cosine similarities. dw_*: (n, d) / (q, d)."""
    a = row_normalize(dw_a)
    b = a if dw_b is None else row_normalize(dw_b)
    return jnp.clip(a @ b.T, -1.0, 1.0)


def madc(M, use_kernel: bool = False, min_kernel_n: int | None = None):
    """Mean-of-Absolute-Differences of pairwise Cosines (eq. 7).

    M: (n, n) cosine similarity matrix -> (n, n) dissimilarity matrix.
    The z != i, j exclusion removes the self-similarity observation bias.

    ``use_kernel=True`` delegates to the blocked Pallas kernel
    (``kernels.ops.madc_block``), which streams M in (bn, bz) tiles instead
    of materializing this reference's O(n³) broadcast — but only at or
    above the measured crossover size (``kernels.ops.madc_crossover_n``);
    below it the reference is faster than the kernel's tiling overhead and
    this dispatch automatically falls back to it. ``min_kernel_n``
    overrides the crossover (0 forces the kernel path — tests/benchmarks).
    """
    if use_kernel:
        from repro.kernels.ops import madc_block, madc_crossover_n
        cut = madc_crossover_n() if min_kernel_n is None else min_kernel_n
        if M.shape[0] >= cut:
            return madc_block(M)
    n = M.shape[0]
    diff = jnp.abs(M[:, None, :] - M[None, :, :])        # (n, n, n) over z
    eye = jnp.eye(n, dtype=bool)
    excl = eye[:, None, :] | eye[None, :, :]             # z == i or z == j
    s = jnp.sum(jnp.where(excl, 0.0, diff), axis=-1)
    return s / max(n - 2, 1)


def edc_embed(dW, m: int, key=None, use_kernel: bool = False):
    """Decompose ΔW into m singular directions and embed clients.

    dW: (n, d_w) parameter updates. Returns (E (n, m), V (d_w, m)).
    """
    V = randomized_truncated_svd(dW.T, m, key=key)        # (d_w, m)
    if use_kernel:
        from repro.kernels.ops import cosine_block
        E = cosine_block(dW, V)
    else:
        E = cosine_similarity_matrix(dW, V.T)             # (n, m)
    return E, V


def edc_from_embedding(E, m: int):
    """EDC(i,j) = ||E_i - E_j|| / m (eq. 8)."""
    d2 = jnp.sum(jnp.square(E[:, None, :] - E[None, :, :]), -1)
    return jnp.sqrt(jnp.maximum(d2, 0.0)) / m


def edc(dW, m: int, key=None):
    E, _ = edc_embed(dW, m, key)
    return edc_from_embedding(E, m)


def cosine_dissimilarity(a, b):
    """Normalized cosine dissimilarity in [0, 1] (eq. 9 argument)."""
    num = jnp.vdot(a, b)
    den = jnp.maximum(jnp.linalg.norm(a) * jnp.linalg.norm(b), _EPS)
    return (-num / den + 1.0) / 2.0

"""Randomized truncated SVD (Halko/Martinsson/Tropp) in pure JAX.

The paper uses scipy's truncated SVD on the host; on TPU we want the whole
group-cold-start to stay on device, so the range finder is expressed as
matmuls + QR (MXU-friendly). Complexity O((m+p)² d_w + subspace iterations),
matching the paper's O(2 m² d_w) claim up to the oversampling constant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def randomized_truncated_svd(A, m: int, *, n_iter: int = 4, oversample: int = 8,
                             key=None):
    """Top-m left singular vectors of A (d, n) -> V (d, m), orthonormal cols.

    For the FedGroup use-case A = ΔWᵀ with d = d_w >> n = #pretrain clients,
    so we find the range of A (client-update span) — rank <= n.
    """
    d, n = A.shape
    k = min(m + oversample, n)
    if key is None:
        key = jax.random.PRNGKey(0)
    A32 = A.astype(jnp.float32)
    omega = jax.random.normal(key, (n, k), jnp.float32)
    Y = A32 @ omega                                       # (d, k)
    Q, _ = jnp.linalg.qr(Y)
    for _ in range(n_iter):                               # subspace iteration
        Z = A32.T @ Q                                     # (n, k)
        W, _ = jnp.linalg.qr(Z)
        Y = A32 @ W
        Q, _ = jnp.linalg.qr(Y)
    B = Q.T @ A32                                         # (k, n)
    Ub, s, _ = jnp.linalg.svd(B, full_matrices=False)
    U = Q @ Ub                                            # (d, k)
    return U[:, :m]


def truncated_svd_values(A, m: int, **kw):
    """Convenience: top-m singular values (for validation tests)."""
    d, n = A.shape
    V = randomized_truncated_svd(A, m, **kw)
    B = V.T @ A.astype(jnp.float32)
    return jnp.linalg.norm(B, axis=1)

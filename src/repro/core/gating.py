"""Gate-weighted group-model combination — the paper's stated future work
(§5.2: "we will explore using a gate network to combine group models").

Implemented as a similarity gate: a client's pre-training update direction
is scored against every group's latest update direction (the same eq.-9
cosine machinery as the client cold start); the resulting softmax weights
mix the *logits* of the m group models at evaluation time. Temperature τ
interpolates between hard assignment (τ→0 ≡ vanilla FedGroup) and a uniform
ensemble (τ→∞).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures


def gate_weights(dpre, group_deltas, temperature: float = 0.1):
    """dpre: (c, d_w) client pre-training updates; group_deltas: (m, d_w).
    Returns (c, m) softmax similarity gates."""
    sim = measures.cosine_similarity_matrix(dpre, group_deltas)    # (c, m)
    return jax.nn.softmax(sim / jnp.maximum(temperature, 1e-6), axis=-1)


def mixture_correct_counts(model, group_params: list, weights, x, y, n_valid):
    """Gate-mixed evaluation: logits = Σ_j w_j · logits_j per client.

    weights: (c, m); x: (c, max_n, ...); y: (c, max_n); n_valid: (c,).
    Returns per-client correct counts (c,).
    """
    def per_client(w, xc, yc, nv):
        logit_sum = 0.0
        for j, gp in enumerate(group_params):
            logit_sum = logit_sum + w[j] * model.apply(gp, xc)
        pred = jnp.argmax(logit_sum, -1)
        ok = (pred == yc) & (jnp.arange(yc.shape[0]) < nv)
        return jnp.sum(ok)

    return jax.vmap(per_client, in_axes=(0, 0, 0, 0))(weights, x, y, n_valid)


def evaluate_gated(trainer, temperature: float = 0.1,
                   client_idx=None) -> float:
    """Gate-mixed weighted accuracy over (a subset of) assigned clients.

    Recomputes each client's 1-epoch pre-training update against the
    auxiliary global model (exactly the client-cold-start probe), gates the
    m group models with it, and scores the mixture on the client test set.
    """
    d = trainer.data
    if client_idx is None:
        client_idx = np.where(trainer.membership >= 0)[0]
    client_idx = np.asarray(client_idx)
    if len(client_idx) == 0:
        return 0.0

    x, y, n = trainer._client_batch(client_idx)
    trainer.key, sk = jax.random.split(trainer.key)
    keys = jax.random.split(sk, len(client_idx))
    deltas, _ = trainer.pretrain_solver(trainer.params, x, y, n, keys)
    from repro.fed import server as server_lib
    from repro.models.modules import flatten_updates
    dpre = jax.vmap(flatten_updates)(deltas)
    G = jnp.asarray(trainer.group_delta)        # (m, d_w) update directions
    w = gate_weights(dpre, G, temperature)

    group_list = [server_lib.tree_index(trainer.group_params, j)
                  for j in range(G.shape[0])]
    xt, yt, nt = trainer._test_stack      # pinned on device at trainer init
    sel = jnp.asarray(client_idx.astype(np.int32))
    correct = mixture_correct_counts(
        trainer.model, group_list, w, xt[sel], yt[sel], nt[sel])
    total = d.n_test[client_idx].sum()
    return float(np.sum(np.asarray(correct)) / max(total, 1))

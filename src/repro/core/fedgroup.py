"""FedGroup / FedGrouProx — the paper's contribution (Algorithms 2 & 3).

Key pieces, mapped to the paper:
  * group cold start  (Alg. 3): pre-train α·m clients one ClientUpdate from
    w0, flatten updates into ΔW, then either
      - EDC branch:  V = truncatedSVD(ΔWᵀ, m); embed E = K(ΔW, Vᵀ);
                     K-Means++ on E                     (eq. 8)
      - MADC branch: M = K(ΔW, ΔW); MADC proximity; hierarchical complete
                     linkage                            (eq. 7)
  * client cold start (eq. 9): newcomer takes one pre-training update from
    the *auxiliary global model* and joins argmin_j normalized cosine
    dissimilarity to the group's latest update direction.
  * training round    (Alg. 2): intra-group FedAvg/FedProx, optional
    inter-group aggregation (η_G), global model = plain mean of groups.
  * ablations: RCC (random cluster centres), RAC (randomly assign cold).

Group membership is *static* once assigned (the paper's main efficiency
argument vs IFCA/FeSEM, which reschedule every round) — unless
``FedConfig.shift_threshold`` turns on the FlexCFL-style *shift detector*:
every ``shift_check_every`` rounds, each assigned cohort client with a
cached eq.-9 direction is re-probed with one pre-training pass from the
current auxiliary model, and a client whose fresh direction drifted beyond
the threshold (cosine dissimilarity ``(1 - cos)/2``) is re-routed through
eq. 9 against the current group update directions — a *migration*, counted
into the ``rounds.migrations`` metric. The stale cached direction row is
invalidated before the fresh one is cached, so any later re-cold-start
recomputes rather than reuses it.

Group state is an m-stacked pytree (leading axis = group) and every round is
ONE device dispatch through ``fed.rounds.make_round_executor`` — the serial
per-group solver loop of the seed implementation survives only as the
equivalence/benchmark oracle ``fed.rounds.serial_reference_round``.

In ``population=`` mode (``fed.population``) the trainer streams scheduled
cohorts from a host-resident ``ClientStore``; the newcomer *arrival
process* then feeds the eq.-9 client cold start round after round — the
regime the paper's cold-start mechanism is designed for — with the
pre-training directions cached in the persistent per-client state table.
Both feeding modes ride the executor's mesh placement (1-D client
parallelism, or the 2-D ``(data, model)`` mesh that additionally shards
the local solver's parameter dim — docs/scaling.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cluster as cluster_lib
from repro.core import measures
from repro.fed import client as client_lib
from repro.fed.engine import FedConfig, GroupedTrainer, RoundMetrics
from repro.models.modules import flatten_updates


class FedGroupTrainer(GroupedTrainer):
    framework = "fedgroup"

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        super().__init__(model, data, cfg, mesh=mesh, population=population)
        # group state: pytree stacked over the group axis + (m, d_w) latest
        # flattened update direction Δw^(g)
        self.group_params = jax.tree_util.tree_map(
            lambda p: jnp.stack([p] * self.m), self.params)
        self.group_delta = None
        # 1-epoch pre-training solver for newcomer cold start (the paper:
        # pre-training does not occupy a whole round)
        self.pretrain_solver = client_lib.make_batch_solver(
            model, epochs=1, batch_size=cfg.batch_size, lr=cfg.lr, mu=0.0,
            max_samples=self._max_samples)
        self.cold_started = False
        self.last_cold = 0          # newcomers cold-started last round
        # shift detector (FedConfig.shift_threshold): pinned-mode direction
        # cache (population mode keeps rows in the ClientStateTable), the
        # check-cadence clock, and the last check's (probed, migrated)
        self._pin_dirs = None
        self._shift_tick = 0
        self._shift_last = (0, 0)
        self._last_shifted = np.empty(0, np.int64)

    def _exec_spec(self) -> dict:
        return {"n_groups": self.m, "eta_g": self.cfg.eta_g}

    # ------------------------------------------------------------------
    # Cached eq.-9 directions: one cache API over both feeding modes —
    # the persistent ClientStateTable rows when streaming, a trainer-owned
    # lazy table when pinned (materialized only when the detector needs it)
    # ------------------------------------------------------------------
    def _shift_enabled(self) -> bool:
        return self.cfg.shift_threshold is not None

    def _set_dirs(self, idx, rows):
        rows = np.asarray(rows, np.float32)
        if self.population is not None:
            self.population.state.set_pretrain_dir(idx, rows)
            return
        if self._pin_dirs is None:
            from repro.fed.store import _LazyRows
            self._pin_dirs = _LazyRows(np.zeros(rows.shape[-1], np.float32))
        self._pin_dirs.scatter(idx, rows)

    def _has_dirs(self, idx) -> np.ndarray:
        if self.population is not None:
            return self.population.state.has_pretrain_dir(idx)
        if self._pin_dirs is None:
            return np.zeros(len(np.asarray(idx)), bool)
        return self._pin_dirs.has(idx)

    def _get_dirs(self, idx) -> np.ndarray:
        if self.population is not None:
            return self.population.state.get_pretrain_dir(idx)
        return self._pin_dirs.gather(idx)

    def _invalidate_dirs(self, idx):
        if self.population is not None:
            self.population.state.invalidate_pretrain_dir(idx)
        elif self._pin_dirs is not None:
            self._pin_dirs.delete(idx)

    # ------------------------------------------------------------------
    # Group cold start (Algorithm 3)
    # ------------------------------------------------------------------
    def group_cold_start(self):
        cfg = self.cfg
        if self.population is not None:
            # pre-train from the *currently active* population only — the
            # not-yet-arrived clients are exactly the ones the eq.-9 client
            # cold start will route, round by round, as they appear
            pool = self.population.scheduler.active_ids()
        else:
            pool = self.n_clients
        pool_size = pool if isinstance(pool, int) else len(pool)
        n_pre = min(cfg.pretrain_scale * self.m, pool_size)
        pre_idx = self.rng.choice(pool, n_pre, replace=False)
        deltas, _, _ = self._solve(self.params, pre_idx)
        self.comm_params += 2 * len(pre_idx) * self.model_size
        dW = jax.vmap(flatten_updates)(deltas)                 # (n_pre, d_w)

        if cfg.rcc:                                            # ablation
            labels = self.rng.integers(0, self.m, n_pre)
            self._edc_info = None
        elif cfg.measure == "edc":
            # distinct subkeys: reusing one key for both the randomized
            # SVD's test matrix and the K-Means++ seeding correlates the
            # embedding directions with the seeding draws
            self.key, sk_svd, sk_km = jax.random.split(self.key, 3)
            E, V = measures.edc_embed(dW, self.m, key=sk_svd)
            assign, centers = cluster_lib.kmeans_pp(sk_km, E, self.m)
            labels = np.asarray(assign)
            self._edc_info = {"embedding": np.asarray(E),
                              "inertia": float(cluster_lib.kmeans_inertia(
                                  E, assign, centers))}
        elif cfg.measure == "madc":
            M = measures.cosine_similarity_matrix(dW)
            # blocked Pallas kernel above the measured crossover size,
            # reference broadcast below it (kernels.ops.madc_crossover_n)
            Mp = measures.madc(M, use_kernel=True)
            labels = cluster_lib.hierarchical(np.asarray(Mp), self.m)
            self._edc_info = None
        else:
            raise ValueError(cfg.measure)

        self._adopt_membership(pre_idx, labels)
        # segment mean over pre-trained clients: W[j, i] = 1/|G_j| for
        # members, zero rows for empty groups (they stay at w0 with Δ = 0)
        W = np.zeros((self.m, n_pre), np.float32)
        for j in range(self.m):
            members = np.where(labels == j)[0]
            if len(members):
                W[j, members] = 1.0 / len(members)
        Wj = jnp.asarray(W)
        mean_delta = jax.tree_util.tree_map(
            lambda d: (Wj @ d.reshape(n_pre, -1)).reshape(
                (self.m,) + d.shape[1:]), deltas)
        self.group_params = jax.tree_util.tree_map(
            lambda p, d: p[None] + d, self.params, mean_delta)
        # flattening the already-aggregated per-leaf means equals Wj @ dW
        # without a second pass over the (n_pre, d_w) update matrix
        self.group_delta = jax.vmap(flatten_updates)(mean_delta)  # (m, d_w)
        if self.population is not None or self._shift_enabled():
            # cache the pre-trained clients' update directions too, so the
            # Alg.-3 founders are as shift-detectable as eq.-9 newcomers
            self._set_dirs(pre_idx, np.asarray(dW))
        self.cold_started = True
        return pre_idx, labels

    # ------------------------------------------------------------------
    # Client cold start (eq. 9)
    # ------------------------------------------------------------------
    def client_cold_start(self, cold_idx: np.ndarray):
        cfg = self.cfg
        if len(cold_idx) == 0:
            return
        self.obs.registry.inc("rounds.cold_started", len(cold_idx))
        if cfg.rac:                                            # ablation
            self._adopt_membership(cold_idx,
                                   self.rng.integers(0, self.m,
                                                     len(cold_idx)))
            return
        x, y, n = self._client_batch(cold_idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(cold_idx))
        deltas, _ = self.pretrain_solver(self.params, x, y, n, keys)
        dpre = jax.vmap(flatten_updates)(deltas)               # (c, d_w)
        if self.population is not None or self._shift_enabled():
            # cache the pre-training directions (persistent state table
            # when streaming, trainer-owned rows when pinned): newcomer
            # analytics, re-clustering and the shift detector reuse them
            self._set_dirs(cold_idx, np.asarray(dpre))
        sim = measures.cosine_similarity_matrix(dpre, self.group_delta)
        dis = (-sim + 1.0) / 2.0                               # (c, m)
        self._adopt_membership(cold_idx, np.asarray(jnp.argmin(dis, axis=1)))

    # ------------------------------------------------------------------
    # Shift detection + migration (FlexCFL-style, FedConfig.shift_threshold)
    # ------------------------------------------------------------------
    def _maybe_shift(self, idx):
        """Probe the cohort's assigned, direction-cached clients for
        distribution shift and migrate the drifted ones through eq. 9.

        One pre-training pass from the current auxiliary model per probed
        client (accounted as 1 model down + 1 update up); drift is the
        normalized cosine dissimilarity ``(1 - cos)/2`` between the fresh
        and cached directions. A drifted client's stale cached row is
        *invalidated* first — a later re-cold-start must recompute, never
        reuse it — then the fresh direction is cached and the client is
        re-assigned by eq. 9 against the current group update directions
        (an ``_adopt_membership`` write, so migrations hit the registry).
        Returns the migrated client ids."""
        cfg = self.cfg
        none = np.empty(0, np.int64)
        self._last_shifted = none
        if not self._shift_enabled() or not self.cold_started \
                or self.group_delta is None:
            return none
        tick = self._shift_tick
        self._shift_tick += 1
        if tick % max(int(cfg.shift_check_every), 1) != 0:
            return none
        idx = np.asarray(idx)
        assigned = idx[self.membership[idx] >= 0]
        checked = assigned[self._has_dirs(assigned)]
        self._shift_last = (len(checked), 0)
        if len(checked) == 0:
            return none
        self.obs.registry.inc("rounds.shift_checks", len(checked))
        self.comm_params += 2 * len(checked) * self.model_size
        x, y, n = self._client_batch(checked)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(checked))
        deltas, _ = self.pretrain_solver(self.params, x, y, n, keys)
        fresh = np.asarray(jax.vmap(flatten_updates)(deltas))  # (c, d_w)
        cached = self._get_dirs(checked)
        dot = np.sum(fresh * cached, axis=1)
        den = np.linalg.norm(fresh, axis=1) * np.linalg.norm(cached, axis=1)
        drift = (1.0 - dot / np.maximum(den, 1e-12)) / 2.0
        moved = drift > float(cfg.shift_threshold)
        shifted = checked[moved].astype(np.int64)
        self._shift_last = (len(checked), len(shifted))
        if len(shifted) == 0:
            return none
        self._invalidate_dirs(shifted)
        self._set_dirs(shifted, fresh[moved])
        sim = measures.cosine_similarity_matrix(
            jnp.asarray(fresh[moved]), self.group_delta)
        dis = (-sim + 1.0) / 2.0
        self._adopt_membership(shifted, np.asarray(jnp.argmin(dis, axis=1)))
        self._last_shifted = shifted
        return shifted

    # ------------------------------------------------------------------
    # Round-block staging: blocks break on host events (Alg. 3 cold start,
    # eq.-9 newcomers in a staged cohort) — membership is static otherwise
    # ------------------------------------------------------------------
    def _host_round_pre(self) -> bool:
        # shift detection is host work between every round, so an enabled
        # detector pins the trainer to the per-round path (no scan blocks)
        return not self.cold_started or self._shift_enabled()

    def _needs_host(self, idx) -> bool:
        return bool((self.membership[idx] < 0).any())

    def _carry_group_delta(self):
        # set by group_cold_start — _host_round_pre keeps blocks from
        # staging before it ran
        return self.group_delta

    def _carry_refs(self, carry: dict):
        super()._carry_refs(carry)
        self.group_delta = carry["group_delta"]

    # -- async runtime hooks: Alg. 3 before staging, eq. 9 at stage time ---
    def _async_host_pre(self):
        if not self.cold_started:
            self.group_cold_start()

    def _async_cold(self, idx) -> np.ndarray:
        # the synchronous round()'s cold segment, run at stage time: the
        # newcomers' eq.-9 routing uses the post-last-fold auxiliary
        # global model + update directions (self.params / self.group_delta
        # are re-pointed at the folded carry after every fold)
        idx = np.asarray(idx)
        # shift check precedes the cold segment, exactly as in round();
        # migrated ids ride out with the cold ids so the pinned async loop
        # patches their membership rows into the device carry
        shifted = self._maybe_shift(idx)
        cold = idx[self.membership[idx] < 0]
        self.last_cold = len(cold)
        self.comm_params += 2 * len(cold) * self.model_size
        self.client_cold_start(cold)
        return np.concatenate([shifted, cold]) if len(shifted) else cold

    def _async_stream_arg(self, idx):
        return jnp.asarray(self.membership[idx], jnp.int32)

    def _async_adopt(self, out, idx, folded_groups, folded_global):
        super()._async_adopt(out, idx, folded_groups, folded_global)
        self.group_delta = out.group_delta_flat
        self.params = folded_global

    # ------------------------------------------------------------------
    # Checkpointing: + eq.-9 update directions and the cold-start flags
    # (a resumed trainer must NOT re-run Alg. 3 — membership is static)
    # ------------------------------------------------------------------
    def _ckpt_model_tree(self) -> dict:
        tree = super()._ckpt_model_tree()
        # group_delta is None until group cold start; zeros keep the
        # checkpoint schema fixed and "has_group_delta" in the metadata
        # records which it was
        tree["group_delta"] = self.group_delta \
            if self.group_delta is not None \
            else jnp.zeros((self.m, self.model_size), jnp.float32)
        return tree

    def _ckpt_load_model(self, tree: dict):
        super()._ckpt_load_model(tree)
        self.group_delta = tree["group_delta"]

    def _ckpt_meta_extra(self) -> dict:
        return {"cold_started": bool(self.cold_started),
                "last_cold": int(self.last_cold),
                "has_group_delta": self.group_delta is not None,
                "shift_tick": int(self._shift_tick)}

    def _ckpt_apply_extra(self, extra: dict):
        self.cold_started = bool(extra["cold_started"])
        self.last_cold = int(extra["last_cold"])
        if not extra["has_group_delta"]:
            self.group_delta = None
        self._shift_tick = int(extra.get("shift_tick", 0))

    def _ckpt_state_arrays(self) -> dict:
        # pinned-mode direction cache (population rows checkpoint through
        # the state table); variable row count is fine — the load template
        # is archive-driven
        out = super()._ckpt_state_arrays()
        if self._pin_dirs is not None:
            for k, v in self._pin_dirs.ckpt_arrays().items():
                out[f"fg_dir_{k}"] = v
        return out

    def _ckpt_apply_state(self, arrays: dict):
        super()._ckpt_apply_state(arrays)
        if "fg_dir_ids" in arrays:
            from repro.fed.store import _LazyRows
            self._pin_dirs = _LazyRows.from_ckpt(
                {k: arrays[f"fg_dir_{k}"]
                 for k in ("ids", "rows", "default")})

    def _round_record(self, m) -> dict:
        rec = super()._round_record(m)
        rec["cold"] = int(self.last_cold)
        rec["eta_g"] = float(self.cfg.eta_g)
        if self._shift_enabled():
            checked, migrated = self._shift_last
            rec["shift_checked"] = int(checked)
            rec["shift_migrations"] = int(migrated)
        return rec

    # ------------------------------------------------------------------
    # Round (Algorithm 2) — one fused dispatch over all groups
    # ------------------------------------------------------------------
    def round(self, t: int, idx=None) -> RoundMetrics:
        if not self.cold_started:
            self.group_cold_start()

        if idx is None:
            idx = self._select()
        idx = np.asarray(idx)
        self._maybe_shift(idx)
        cold = idx[self.membership[idx] < 0]
        self.last_cold = len(cold)
        # cold start: 1 global model down + 1 pretrain update up per newcomer
        self.comm_params += 2 * len(cold) * self.model_size
        self.client_cold_start(cold)
        # per-round: 1 group model down + 1 update up per client
        self.comm_params += 2 * len(idx) * self.model_size

        x, y, n = self._client_batch(idx)
        self.key, sk = jax.random.split(self.key)
        keys = jax.random.split(sk, len(idx))
        out = self._round_executor()(
            self.group_params, jnp.asarray(self.membership[idx], jnp.int32),
            x, y, n, keys)
        self.group_params = out.group_params
        self.group_delta = out.group_delta_flat
        # auxiliary global model: unweighted average of group models
        self.params = out.global_params

        acc = self._round_eval(t)
        self._fold_alive = len(idx)
        m = RoundMetrics(t, acc, float(out.mean_loss), float(out.discrepancy),
                         int(out.n_quarantined))
        self.history.add(m)
        return m


class FedGrouProxTrainer(FedGroupTrainer):
    """FedGroup + FedProx local solver (the paper's FedGrouProx)."""
    framework = "fedgrouprox"

    def __init__(self, model, data, cfg: FedConfig, mesh=None,
                 population=None):
        if cfg.mu <= 0:
            cfg = dataclasses.replace(cfg, mu=0.01)
        super().__init__(model, data, cfg, mesh=mesh, population=population)

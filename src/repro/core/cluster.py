"""Clustering backends for the group cold start.

  kmeans_pp      — K-Means++ seeding + Lloyd iterations, pure JAX (used with
                   the EDC embedding, paper Algorithm 3 "EMD branch").
  hierarchical   — agglomerative complete-linkage on a precomputed proximity
                   matrix (the MADC branch). O(n³) host-side numpy: n = α·m
                   pre-training clients only (tens), never the full fleet.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# K-Means++ (JAX)
# ---------------------------------------------------------------------------

def _pp_seed(key, X, k: int):
    """K-Means++ seeding (Arthur & Vassilvitskii 2006)."""
    n = X.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centers0 = jnp.zeros((k, X.shape[1]), X.dtype).at[0].set(X[first])

    def pick(carry, i):
        centers, key = carry
        d2 = jnp.min(jnp.sum(jnp.square(X[:, None, :] - centers[None]), -1)
                     + jnp.where(jnp.arange(k)[None, :] < i, 0.0, jnp.inf),
                     axis=1)
        kk, key = jax.random.split(key)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-12)
        idx = jax.random.categorical(kk, jnp.log(jnp.maximum(probs, 1e-30)))
        centers = centers.at[i].set(X[idx])
        return (centers, key), None

    (centers, _), _ = jax.lax.scan(pick, (centers0, key), jnp.arange(1, k))
    return centers


def kmeans_pp(key, X, k: int, n_iter: int = 50):
    """X: (n, m) -> (assignments (n,), centers (k, m))."""
    X = X.astype(jnp.float32)
    centers = _pp_seed(key, X, k)

    def lloyd(centers, _):
        d2 = jnp.sum(jnp.square(X[:, None, :] - centers[None]), -1)  # (n, k)
        assign = jnp.argmin(d2, -1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)        # (n, k)
        counts = jnp.sum(onehot, 0)                                  # (k,)
        sums = onehot.T @ X                                          # (k, m)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1),
                        centers)
        return new, None

    centers, _ = jax.lax.scan(lloyd, centers, None, length=n_iter)
    assign = jnp.argmin(jnp.sum(jnp.square(X[:, None, :] - centers[None]), -1), -1)
    return assign, centers


def kmeans_inertia(X, assign, centers):
    """Within-cluster sum-of-squares (the paper's clustering validity index)."""
    d2 = jnp.sum(jnp.square(X - centers[assign]), -1)
    return jnp.sum(d2)


# ---------------------------------------------------------------------------
# Hierarchical complete-linkage (numpy, host)
# ---------------------------------------------------------------------------

def hierarchical(proximity, k: int):
    """Agglomerative clustering with complete linkage.

    proximity: (n, n) symmetric dissimilarity matrix (e.g. MADC).
    Returns integer labels (n,) with k clusters.

    Merged-away rows/columns are masked to +inf in the full matrix and the
    next pair is a single ``argmin(D)`` — no ``D[np.ix_(active, active)]``
    submatrix copy (an extra O(n²) allocation per merge) and the linkage
    update is one vectorized ``np.maximum`` row/column write. Tie-breaking
    matches the submatrix version: masked entries are +inf, so row-major
    ``argmin`` order over the full matrix is the submatrix's row-major
    order (the active set stays ascending).
    """
    D = np.array(proximity, dtype=np.float64, copy=True)
    n = D.shape[0]
    np.fill_diagonal(D, np.inf)
    members = {i: [i] for i in range(n)}
    n_active = n
    while n_active > k:
        i, j = np.unravel_index(np.argmin(D), D.shape)
        if j < i:
            i, j = j, i
        # complete linkage: distance to merged = max of distances (masked
        # entries stay +inf under max; the i-th diagonal is re-masked)
        upd = np.maximum(D[i], D[j])
        D[i, :] = D[:, i] = upd
        D[i, i] = np.inf
        D[j, :] = D[:, j] = np.inf
        members[i].extend(members.pop(j))
        n_active -= 1
    labels = np.zeros(n, dtype=np.int32)
    for lbl, root in enumerate(sorted(members)):
        labels[members[root]] = lbl
    return labels

"""Coordinator <-> worker message transport: in-process (thread workers,
payloads by reference) and process-level (spawned workers, pipes), plus the
failure-detection and chaos primitives the control plane builds on.

Two transports, one wire protocol (:class:`Message`):

* :class:`InProcTransport` — every worker is a thread in the coordinator's
  process; each has its own inbox queue and all share the coordinator's
  inbox. Payloads pass **by reference**, so a routed dispatch executes the
  exact same compiled executor on the exact same arrays as a single-process
  run — this is what makes the fleet-size-1 mode *bit-identical* to
  ``engine.run()`` while every message still flows through the transport
  (so leases, heartbeats and chaos injection are exercised in-process).
* :class:`ProcTransport` — every worker is a spawned OS process (its own
  failure domain) connected by a duplex pipe; payloads are pickled numpy
  pytrees. A SIGKILLed worker surfaces as an ``"eof"`` message (closed
  pipe) or as missed heartbeats, whichever the coordinator sees first.

:class:`HeartbeatMonitor` turns per-worker beat timestamps into a
miss-threshold failure detector (dead after ``interval * miss`` seconds of
silence; a late beat resurrects). :class:`ChaosRouter` injects scripted
delivery-order faults — dropped / duplicated / reordered messages and
suppressed heartbeats — on the coordinator's receive path, deterministically
armed per job by the coordinator from ``FaultSpec``'s fleet fields.
"""
from __future__ import annotations

import os
import queue
import signal
import threading
import time
from dataclasses import dataclass, field


@dataclass
class Message:
    """One wire message. ``kind`` is the protocol:

    job        coordinator -> worker: ``payload = (fn_name, args)``
    result     worker -> coordinator: ``payload`` = the executor's return
    error      worker -> coordinator: ``payload`` = formatted traceback
    heartbeat  worker -> coordinator: liveness beat (no payload)
    join       worker -> coordinator: ready to take jobs (sent once the
               worker — for a process worker, its trainer replica — is up)
    leave      worker -> coordinator: graceful departure
    stop       coordinator -> worker: drain and exit
    eof        synthesized by ``ProcTransport.recv`` when a worker's pipe
               closes (the fast path of SIGKILL detection)
    """
    kind: str
    src: str = ""
    job_id: int = -1
    payload: object = None


# ---------------------------------------------------------------------------
# in-process transport (thread workers)
# ---------------------------------------------------------------------------
class InProcEndpoint:
    """A thread worker's view of the transport: ``recv`` its own inbox,
    ``send`` into the coordinator's."""

    def __init__(self, name: str, inbox: queue.Queue, coord: queue.Queue):
        self.name = name
        self._inbox = inbox
        self._coord = coord

    def recv(self, timeout: float):
        try:
            return self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def send(self, msg: Message):
        self._coord.put(msg)


class InProcTransport:
    """Queue-based transport: one inbox per worker, one shared coordinator
    inbox. Everything passes by reference — zero serialization."""

    def __init__(self):
        self._coord: queue.Queue = queue.Queue()
        self._inboxes: dict[str, queue.Queue] = {}

    def add_worker(self, name: str) -> InProcEndpoint:
        if name in self._inboxes:
            raise ValueError(f"worker {name!r} already registered")
        self._inboxes[name] = queue.Queue()
        return InProcEndpoint(name, self._inboxes[name], self._coord)

    def remove_worker(self, name: str):
        self._inboxes.pop(name, None)

    def send(self, name: str, msg: Message) -> bool:
        inbox = self._inboxes.get(name)
        if inbox is None:
            return False
        inbox.put(msg)
        return True

    def recv(self, timeout: float):
        try:
            return self._coord.get(timeout=timeout)
        except queue.Empty:
            return None

    def close(self):
        self._inboxes.clear()


# ---------------------------------------------------------------------------
# process transport (spawned workers, duplex pipes)
# ---------------------------------------------------------------------------
class PipeEndpoint:
    """A process worker's view of its pipe. ``send`` is lock-serialized —
    the job loop and the heartbeat thread share one connection, and
    interleaved writes would tear the pickle stream."""

    def __init__(self, name: str, conn):
        self.name = name
        self._conn = conn
        self._lock = threading.Lock()

    def recv(self, timeout: float):
        if not self._conn.poll(timeout):
            return None
        return self._conn.recv()

    def send(self, msg: Message):
        with self._lock:
            self._conn.send(msg)

    def close(self):
        self._conn.close()


class ProcTransport:
    """Spawned-process transport. The coordinator holds one pipe end per
    worker and multiplexes ``recv`` over all of them with
    ``multiprocessing.connection.wait``; a closed pipe (killed worker)
    surfaces as a synthesized ``eof`` message."""

    def __init__(self):
        import multiprocessing as mp
        self._ctx = mp.get_context("spawn")
        self._procs: dict[str, object] = {}
        self._conns: dict[str, object] = {}

    def add_worker(self, name: str, entry, *args):
        """Spawn ``entry(worker_conn, name, *args)`` as a new process."""
        if name in self._procs:
            raise ValueError(f"worker {name!r} already registered")
        coord_conn, worker_conn = self._ctx.Pipe()
        proc = self._ctx.Process(target=entry, args=(worker_conn, name)
                                 + tuple(args), daemon=True)
        proc.start()
        worker_conn.close()          # the child owns its end now
        self._procs[name] = proc
        self._conns[name] = coord_conn
        return proc

    def remove_worker(self, name: str):
        conn = self._conns.pop(name, None)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass
        proc = self._procs.pop(name, None)
        if proc is not None and proc.is_alive():
            proc.terminate()
            proc.join(timeout=5.0)

    def kill(self, name: str):
        """SIGKILL a worker process — the chaos injection primitive (and
        the hard-stop path of a misbehaving worker)."""
        proc = self._procs.get(name)
        if proc is not None and proc.pid and proc.is_alive():
            os.kill(proc.pid, signal.SIGKILL)

    def send(self, name: str, msg: Message) -> bool:
        conn = self._conns.get(name)
        if conn is None:
            return False
        try:
            conn.send(msg)
            return True
        except (BrokenPipeError, OSError):
            return False

    def recv(self, timeout: float):
        from multiprocessing.connection import wait
        conns = list(self._conns.values())
        if not conns:
            time.sleep(min(timeout, 0.01))
            return None
        ready = wait(conns, timeout=timeout)
        if not ready:
            return None
        conn = ready[0]
        name = next((n for n, c in self._conns.items() if c is conn), "")
        try:
            return conn.recv()
        except (EOFError, OSError):
            return Message("eof", src=name)

    def close(self):
        for name in list(self._procs):
            self.remove_worker(name)


# ---------------------------------------------------------------------------
# heartbeat failure detection
# ---------------------------------------------------------------------------
class HeartbeatMonitor:
    """Miss-threshold failure detector over per-worker beat timestamps:
    a worker silent for longer than ``interval * miss`` seconds is
    declared dead by :meth:`sweep`; a later beat (:meth:`beat` returns
    True) resurrects it — the caller decides whether to re-adopt.

    >>> m = HeartbeatMonitor(interval=1.0, miss=3)
    >>> m.add("w0", now=0.0); m.sweep(now=2.9)
    []
    >>> m.sweep(now=3.1)
    ['w0']
    >>> m.beat("w0", now=3.2)        # late beat: back from the dead
    True
    >>> m.sweep(now=3.3)
    []
    """

    def __init__(self, interval: float, miss: int):
        self.window = float(interval) * int(miss)
        self._last: dict[str, float] = {}
        self._dead: set = set()

    def add(self, name: str, now: float):
        self._last[name] = now
        self._dead.discard(name)

    def remove(self, name: str):
        self._last.pop(name, None)
        self._dead.discard(name)

    def beat(self, name: str, now: float) -> bool:
        """Record a beat; True when it resurrects a declared-dead worker."""
        if name not in self._last and name not in self._dead:
            return False                 # never adopted / already removed
        resurrected = name in self._dead
        self._dead.discard(name)
        self._last[name] = now
        return resurrected

    def is_dead(self, name: str) -> bool:
        return name in self._dead

    def sweep(self, now: float) -> list:
        """Names newly declared dead this sweep (beat older than the
        miss window)."""
        newly = [n for n, t in self._last.items()
                 if n not in self._dead and now - t > self.window]
        for n in newly:
            self._dead.add(n)
        return newly


# ---------------------------------------------------------------------------
# scripted delivery chaos
# ---------------------------------------------------------------------------
@dataclass
class _Armed:
    drop: set = field(default_factory=set)
    dup: set = field(default_factory=set)
    reorder: set = field(default_factory=set)
    hb_mute: dict = field(default_factory=dict)      # worker -> mute-until


class ChaosRouter:
    """Deterministic delivery-order faults on the coordinator's receive
    path, armed per job id from ``FaultSpec``'s fleet fields:

    * ``drop``    — the job's result message is consumed and discarded;
      the job id lands in :attr:`dropped` so the awaiting lease can expire
      immediately (the information-equivalent of a timeout, without
      stalling the test clock) and requeue.
    * ``dup``     — the result is delivered twice; the coordinator must
      ignore the second copy by job id.
    * ``reorder`` — the result is held back until the next message (a
      heartbeat, typically) passes it.
    * ``mute_heartbeats`` — beats from a worker are suppressed until a
      monotonic deadline, driving the miss-threshold detector without
      touching the (healthy) worker.

    ``filter`` maps one received message to the 0..2 messages actually
    delivered. Counters land in the coordinator's metric registry.
    """

    def __init__(self, counters=None):
        self._armed = _Armed()
        self._held: list = []
        self.dropped: set = set()
        self._counters = counters    # MetricsRegistry or None

    def _inc(self, name):
        if self._counters is not None:
            self._counters.inc(name)

    # -- arming (coordinator, at dispatch time) -------------------------
    def arm(self, spec, job_id: int):
        """Arm one job's message faults from a ``FaultSpec`` (no-op when
        the spec is None or carries no fleet message faults)."""
        if spec is None:
            return
        if getattr(spec, "msg_drop", False):
            self._armed.drop.add(job_id)
        if getattr(spec, "msg_dup", False):
            self._armed.dup.add(job_id)
        if getattr(spec, "msg_reorder", False):
            self._armed.reorder.add(job_id)

    def mute_heartbeats(self, worker: str, until: float):
        self._armed.hb_mute[worker] = until

    # -- the receive path ----------------------------------------------
    def filter(self, msg: Message, now: float) -> list:
        """0..2 messages to deliver in place of ``msg``."""
        out = []
        if msg.kind == "heartbeat":
            until = self._armed.hb_mute.get(msg.src)
            if until is not None:
                if now < until:
                    return self._flush(out)       # suppressed
                del self._armed.hb_mute[msg.src]
        if msg.kind == "result":
            if msg.job_id in self._armed.drop:
                self._armed.drop.discard(msg.job_id)
                self.dropped.add(msg.job_id)
                self._inc("fleet.msgs_dropped")
                return self._flush(out)
            if msg.job_id in self._armed.reorder:
                self._armed.reorder.discard(msg.job_id)
                self._held.append(msg)
                self._inc("fleet.msgs_reordered")
                return out                        # held until another passes
            if msg.job_id in self._armed.dup:
                self._armed.dup.discard(msg.job_id)
                self._inc("fleet.msgs_duplicated")
                out.extend([msg, Message(msg.kind, msg.src, msg.job_id,
                                         msg.payload)])
                return self._flush(out)
        out.append(msg)
        return self._flush(out)

    def _flush(self, out: list) -> list:
        """A delivered (or consumed) message lets any held one pass."""
        if self._held:
            out.extend(self._held)
            self._held.clear()
        return out

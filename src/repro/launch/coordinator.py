"""The elastic coordinator: process-level fault domains for the federated
runtime.

``Coordinator`` wraps an ordinarily-constructed trainer and routes its
compiled train dispatches (``_round_executor`` / ``_block_executor`` /
``_async_executor``) through a worker fleet, while keeping everything
stateful exactly where the paper's reliable server owns it — the m-stacked
group params, the ``ClientStateTable``, membership, both rng streams, the
eq.-9 cold start, evaluation, staleness folds and checkpointing all stay on
the coordinator. Workers are stateless executors (``launch.worker``); a
job is a pure function of its message, so any worker — or the same worker
after a restart — produces the bit-identical result.

Every dispatch holds a **lease** (``fed.leases`` — the same
timeout/requeue/backoff machinery the async runtime uses in-device):
the job is sent to a worker, and if the result is not back before the
deadline — or the holder is declared dead by the heartbeat miss-threshold
detector, or chaos dropped the message — the lease is requeued with capped
exponential backoff and re-dispatched to the next live worker. After
``max_retries`` requeues the job is unrecoverable and the run raises.

Failure detection is heartbeat-driven: workers beat every
``heartbeat_interval`` seconds; a worker silent for ``heartbeat_interval *
heartbeat_miss`` seconds is declared dead (``fleet.worker_deaths``), its
leases requeue, and the fleet degrades gracefully down to a single worker.
A late heartbeat resurrects (``fleet.joins``). Elastic membership is
scripted or programmatic: ``FleetConfig.joins``/``leaves`` adopt newcomer
workers or retire live ones at a given dispatch clock, and
:meth:`Coordinator.spawn`/:meth:`Coordinator.retire` do the same on
demand. A process-mode newcomer cold-starts itself by building its trainer
replica from the ``WorkerSpec`` before joining.

Chaos injection extends the PR-6 ``FaultConfig``: ``FaultSpec``'s fleet
fields (``worker_kill``, ``heartbeat_delay``, ``msg_drop``, ``msg_dup``,
``msg_reorder``) are read per dispatch-clock tick and applied to that
dispatch's lease — a SIGKILL mid-dispatch, a muted heartbeat window, or
delivery-order faults on the transport. Because jobs are pure, every
recovery path re-converges on the bit-identical run.

Fleet-size-1 in-process mode is the equivalence anchor: arguments pass by
reference to a thread executing the trainer's own compiled closures, so
``Coordinator(trainer).run()`` is bit-identical to ``trainer.run()`` for
all four frameworks, pinned and streamed (tests/test_fleet.py) — the
entire PR-6/7/9 equivalence matrix carries over to the control plane.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

from repro.fed import leases as leases_lib
from repro.launch import transport as transport_lib
from repro.launch import worker as worker_lib
from repro.launch.transport import (ChaosRouter, HeartbeatMonitor,
                                    InProcTransport, Message, ProcTransport)
from repro.obs import metrics as metrics_lib

_MISSING = object()


@dataclass
class FleetConfig:
    """Control-plane knobs.

    transport           "inproc" (thread workers, bit-identity mode) or
                        "proc" (spawned processes, real fault domains —
                        requires ``worker_spec``; per-round pinned path
                        only).
    heartbeat_interval  worker beat period (seconds).
    heartbeat_miss      beats missed before a worker is declared dead.
    lease_timeout /     the fleet job lease's ``fed.leases.RetryPolicy``:
    max_retries /       a job not answered by the deadline requeues with
    backoff /           capped exponential backoff, at most ``max_retries``
    backoff_cap         times.
    join_timeout        how long to wait for a live worker before the run
                        fails (covers a process worker's replica build).
    faults              scripted chaos: ``FaultConfig`` whose ``rounds``
                        map *dispatch-clock* ticks to ``FaultSpec``s; only
                        the fleet fields are read here.
    joins / leaves      elastic membership scripts: {dispatch-clock:
                        [worker names]} adopted / retired at that tick.
    worker_spec         process-mode trainer replica recipe
                        (``launch.worker.WorkerSpec``).
    """
    n_workers: int = 1
    transport: str = "inproc"
    heartbeat_interval: float = 0.05
    heartbeat_miss: int = 3
    lease_timeout: float = 60.0
    max_retries: int = 3
    backoff: float = 0.01
    backoff_cap: float = 0.25
    join_timeout: float = 180.0
    faults: object | None = None
    joins: dict | None = None
    leaves: dict | None = None
    worker_spec: worker_lib.WorkerSpec | None = None


class Coordinator:
    """Owns the trainer (and with it all training state); routes its train
    dispatches through the worker fleet. See the module docstring."""

    def __init__(self, trainer, fleet: FleetConfig | None = None):
        self.trainer = trainer
        self.fleet = fleet or FleetConfig()
        self.obs = trainer.obs
        self.obs.registry.declare(metrics_lib.FLEET_SCHEMA)
        self._policy = leases_lib.RetryPolicy(
            self.fleet.lease_timeout, self.fleet.max_retries,
            self.fleet.backoff, self.fleet.backoff_cap)
        self._monitor = HeartbeatMonitor(self.fleet.heartbeat_interval,
                                         self.fleet.heartbeat_miss)
        self._chaos = ChaosRouter(self.obs.registry)
        self._clock = 0              # train dispatches submitted (the
        self._job_id = 0             # chaos/elasticity script clock)
        self._rr = 0                 # round-robin cursor
        self._live: list = []        # adopted worker names, join order
        self._workers: dict = {}     # name -> InProcWorker (inproc mode)
        self._results: dict = {}     # job_id -> payload (delivered)
        self._done: set = set()      # completed/abandoned job ids (so a
        #                              late or duplicated result is ignored)
        self._closed = False
        if self.fleet.transport == "inproc":
            self._transport = InProcTransport()
            self._table = worker_lib.worker_fn_table(trainer)
        elif self.fleet.transport == "proc":
            self._validate_proc(trainer)
            self._transport = ProcTransport()
            self._table = None
        else:
            raise ValueError(
                f"unknown fleet transport {self.fleet.transport!r} "
                f"(expected 'inproc' or 'proc')")
        self._patch(trainer)
        for i in range(self.fleet.n_workers):
            self.spawn(f"w{i}")

    # -- setup ----------------------------------------------------------
    def _validate_proc(self, trainer):
        cfg = trainer.cfg
        if self.fleet.worker_spec is None:
            raise ValueError("proc transport needs FleetConfig.worker_spec "
                             "(the worker-side trainer replica recipe)")
        if trainer.population is not None:
            raise ValueError("proc transport supports pinned trainers only "
                             "(the streamed population's prefetched device "
                             "cohorts cannot cross a process boundary)")
        if cfg.block_size > 1 or cfg.async_depth >= 1:
            raise ValueError("proc transport supports the per-round path "
                             "only (set block_size=1, async_depth=0)")

    def _patch(self, trainer):
        """Route the trainer's cached executor seams through the fleet.
        Everything else — staging, rng, cold start, eval, folds,
        checkpoints — keeps running on the coordinator, unchanged."""
        if self.fleet.transport == "inproc":
            # the real compiled closures live in self._table; jobs carry
            # their arguments by reference
            trainer._round_exec = self._proxy("round")
            trainer._block_exec = self._proxy("block")
            trainer._async_exec = self._proxy("async")
        else:
            trainer._round_exec = self._proxy("round", remote=True)
        trainer._fleet_meta = self._fleet_meta

    def _fleet_meta(self) -> dict:
        """The control-plane checkpoint snapshot (ckpt format v4 ``"fleet"``
        metadata): enough to resume the chaos/elasticity script clock and
        audit the fleet shape at save time."""
        return {"transport": self.fleet.transport,
                "n_workers": int(self.fleet.n_workers),
                "live": sorted(self._live),
                "dispatch_clock": int(self._clock),
                "next_job_id": int(self._job_id)}

    # -- fleet membership -----------------------------------------------
    def spawn(self, name: str):
        """Start (and eventually adopt) a worker. In-process workers share
        the coordinator's executor table; process workers build their own
        trainer replica from the ``WorkerSpec`` (their cold start) and
        join once it is up. Adoption happens when the ``join`` message is
        pumped — dispatches only ever go to adopted workers."""
        if self.fleet.transport == "inproc":
            ep = self._transport.add_worker(name)
            w = worker_lib.InProcWorker(name, ep, self._table,
                                        self.fleet.heartbeat_interval)
            self._workers[name] = w
            w.start()
        else:
            self._transport.add_worker(
                name, worker_lib.worker_entry, self.fleet.worker_spec,
                self.fleet.heartbeat_interval)

    def retire(self, name: str):
        """Graceful leave: stop dispatching to the worker and ask it to
        drain and exit; the ``leave`` message finalizes the departure."""
        if name in self._live:
            self._live.remove(name)
            self.obs.registry.set("fleet.workers", len(self._live))
        self._transport.send(name, Message("stop"))

    def kill_worker(self, name: str):
        """Hard-kill a worker (the chaos primitive): SIGKILL in process
        mode, a no-reply hard-stop in-process. Detection is the heartbeat
        monitor's job, not ours."""
        if self.fleet.transport == "inproc":
            w = self._workers.get(name)
            if w is not None:
                w.kill()
        else:
            self._transport.kill(name)

    def _adopt(self, name: str, now: float):
        if name in self._live:
            return
        self._live.append(name)
        self._monitor.add(name, now)
        self.obs.registry.inc("fleet.joins")
        self.obs.registry.set("fleet.workers", len(self._live))

    def _declare_dead(self, name: str):
        if name in self._live:
            self._live.remove(name)
        self.obs.registry.inc("fleet.worker_deaths")
        self.obs.registry.set("fleet.workers", len(self._live))

    def _on_leave(self, name: str):
        if name in self._live:
            self._live.remove(name)
        self._monitor.remove(name)
        self._workers.pop(name, None)
        self._transport.remove_worker(name)
        self.obs.registry.inc("fleet.leaves")
        self.obs.registry.set("fleet.workers", len(self._live))

    # -- the message pump -----------------------------------------------
    def _route(self, msg: Message, now: float):
        reg = self.obs.registry
        if msg.kind == "heartbeat":
            reg.inc("fleet.heartbeats")
            if self._monitor.beat(msg.src, now) \
                    and msg.src not in self._live:
                # back from the dead (a muted/delayed heartbeat window):
                # re-adopt — the resurrection path. ``beat`` only returns
                # True for a previously-adopted worker.
                self._live.append(msg.src)
                reg.inc("fleet.joins")
                reg.set("fleet.workers", len(self._live))
        elif msg.kind == "join":
            self._adopt(msg.src, now)
        elif msg.kind == "leave":
            self._on_leave(msg.src)
        elif msg.kind == "result":
            if msg.job_id in self._done or msg.job_id in self._results:
                # a superseded lease's late answer, or a chaos-duplicated
                # delivery: the first result won, this copy is ignored
                reg.inc("fleet.stale_results")
            else:
                self._results[msg.job_id] = msg.payload
        elif msg.kind == "error":
            raise RuntimeError(
                f"fleet worker {msg.src!r} failed job {msg.job_id}:\n"
                f"{msg.payload}")
        elif msg.kind == "eof":
            # closed pipe: the fast path of process-death detection. The
            # pipe must come out of the transport either way, or the
            # closed fd keeps signalling ready forever.
            self._transport.remove_worker(msg.src)
            if msg.src in self._live:
                with self.obs.span("heartbeat", worker=msg.src,
                                   event="eof"):
                    self._monitor.remove(msg.src)
                    self._declare_dead(msg.src)

    def _pump(self, timeout: float):
        """Drain every available message (blocking up to ``timeout`` for
        the first), then sweep the heartbeat monitor — drain-first keeps
        queued beats from reading as misses."""
        now = time.monotonic()
        msg = self._transport.recv(timeout)
        while msg is not None:
            for m in self._chaos.filter(msg, now):
                self._route(m, now)
            msg = self._transport.recv(0.0)
            now = time.monotonic()
        for name in self._monitor.sweep(time.monotonic()):
            self.obs.registry.inc("fleet.heartbeat_misses")
            with self.obs.span("heartbeat", worker=name, event="miss"):
                self._declare_dead(name)

    # -- dispatch -------------------------------------------------------
    def _elastic(self):
        """Apply the membership script for this dispatch-clock tick."""
        for name in (self.fleet.joins or {}).get(self._clock, ()):
            self.spawn(name)
        for name in (self.fleet.leaves or {}).get(self._clock, ()):
            self.retire(name)

    def _pick_worker(self) -> str:
        deadline = time.monotonic() + self.fleet.join_timeout
        while not self._live:
            if time.monotonic() > deadline:
                raise RuntimeError(
                    "fleet has no live workers (all dead or departed, and "
                    "none joined within join_timeout="
                    f"{self.fleet.join_timeout}s)")
            self._pump(0.01)
        w = self._live[self._rr % len(self._live)]
        self._rr += 1
        return w

    def _await_result(self, job_id: int, holder: str, deadline: float):
        """The lease wait: the result, or ``_MISSING`` when the lease must
        requeue (timeout, dropped message, or the holder died)."""
        while True:
            self._pump(0.005)
            if job_id in self._results:
                return self._results.pop(job_id)
            if job_id in self._chaos.dropped:
                # the transport lost the result: informationally a timeout,
                # resolved now instead of stalling out the full lease
                self._chaos.dropped.discard(job_id)
                return _MISSING
            if holder not in self._live:
                return _MISSING          # holder died: requeue immediately
            if time.monotonic() >= deadline:
                return _MISSING

    def _proxy(self, fn_name: str, remote: bool = False):
        """The executor seam: a callable with the real executor's
        signature that runs the job through lease + transport + fleet."""

        def dispatch(*args):
            spec = (self.fleet.faults.spec(self._clock)
                    if self.fleet.faults is not None else None)
            self._elastic()
            self._clock += 1
            payload = worker_lib._to_numpy(args) if remote else args
            lease = leases_lib.Lease(staged=(fn_name, payload))
            return self._dispatch_lease(lease, spec)

        return dispatch

    def _dispatch_lease(self, lease, spec):
        reg = self.obs.registry
        buf = leases_lib.RequeueBuffer()
        attempts = 0
        while True:
            holder = self._pick_worker()
            if spec is not None and getattr(spec, "worker_kill", False):
                # SIGKILL mid-dispatch: the holder dies with the job in
                # flight; heartbeat misses (or the closed pipe) detect it
                self.kill_worker(holder)
            if spec is not None and getattr(spec, "heartbeat_delay", 0.0):
                self._chaos.mute_heartbeats(
                    holder, time.monotonic() + float(spec.heartbeat_delay))
            job_id = self._job_id
            self._job_id += 1
            self._chaos.arm(spec, job_id)
            spec = None                  # chaos fires once per scripted tick
            reg.inc("fleet.jobs")
            lease.holder, lease.job_id = holder, job_id
            lease.deadline = self._policy.deadline(time.monotonic())
            with self.obs.span("lease", job=job_id, worker=holder,
                               attempt=attempts):
                sent = self._transport.send(
                    holder, Message("job", job_id=job_id,
                                    payload=lease.staged))
                result = (self._await_result(job_id, holder, lease.deadline)
                          if sent else _MISSING)
            self._done.add(job_id)
            if result is not _MISSING:
                reg.inc("fleet.results")
                return result
            # expired / lost / holder died: requeue with capped backoff
            # (raises "unrecoverable" after max_retries, like the async
            # runtime's cohort leases)
            reg.inc("fleet.lease_expiries")
            lease.attempts = attempts
            buf.push(lease, self._policy, time.monotonic(),
                     what="fleet job", timeout_key="lease_timeout",
                     retries_key="max_retries")
            reg.inc("fleet.requeues")
            ready = None
            while ready is None:
                wait = buf.earliest() - time.monotonic()
                if wait > 0:
                    self._pump(min(wait, 0.02))
                ready = buf.pop_ready(time.monotonic())
            _, attempts = ready

    # -- the run surface -------------------------------------------------
    def run(self, n_rounds=None):
        """Train through the fleet: the trainer's own loop, every device
        dispatch routed through a worker lease."""
        return self.trainer.run(n_rounds)

    def save_checkpoint(self, path: str | None = None) -> str:
        """Coordinator-owned checkpointing: the trainer's atomic v4
        snapshot, with this fleet's control-plane metadata riding along."""
        return self.trainer.save_checkpoint(path)

    def load_checkpoint(self, path_or_dir: str) -> int:
        """Coordinator restart: restore the trainer bit-identically and
        resume the control-plane script clock from the fleet metadata."""
        from repro.checkpoint import io as ckpt_io
        path = path_or_dir
        if os.path.isdir(path):
            path = ckpt_io.latest_checkpoint(path)
            if path is None:
                raise FileNotFoundError(
                    f"no ckpt_*.npz checkpoints in {path_or_dir}")
        t = self.trainer.load_checkpoint(path)
        fm = ckpt_io.load_metadata(path).get("fleet")
        if fm is not None:
            self._clock = int(fm["dispatch_clock"])
            self._job_id = int(fm["next_job_id"])
        return t

    def close(self):
        """Retire the fleet, close the transport, finalize the trainer."""
        if self._closed:
            return
        self._closed = True
        for name in list(self._live):
            self.retire(name)
        # give graceful leavers a moment to ack (hard-killed workers never
        # will — don't wait on them), then tear down
        deadline = time.monotonic() + 0.5
        while time.monotonic() < deadline:
            if all(w._dead.is_set() for w in self._workers.values()):
                break
            try:
                self._pump(0.02)
            except RuntimeError:
                break
        for w in list(self._workers.values()):
            w.kill()
        self._transport.close()
        self.trainer.close()

"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state. The production target is TPU v5e: one pod = 16x16 = 256 chips,
multi-pod = 2 pods = 512 chips with a leading "pod" axis (DCN between pods,
ICI within).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-host debug mesh (1x1) — smoke tests, examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_fed_mesh(data: int, model: int = 1):
    """Federated-round mesh: the round executor's client axis shards over
    "data" (``data`` slices — cohorts, ShardedClientStore shards) and the
    local solver's parameter dim over "model" (``model``-way, replicated
    when 1). ``data * model`` must equal the visible device count; see
    docs/scaling.md for the placement rules."""
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12          # per chip, bf16
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per-direction)
CHIPS_PER_POD = 256

"""Stateless fleet workers: the compute side of the coordinator/worker
control plane.

A worker owns **no training state** — the coordinator holds the m-stacked
group params, the ``ClientStateTable``, membership, both rng streams and
the checkpoints. A worker holds only *executors* (the compiled fused round
programs) and runs whatever job message arrives: ``payload = (fn_name,
args)``, looked up in its function table, executed, result sent back. That
statelessness is what makes recovery trivial — a job is a pure function of
its arguments, so a re-dispatched lease (after a SIGKILL, a dropped
message, an expired lease) produces the bit-identical result on any other
worker.

Two flavors:

* :class:`InProcWorker` — a thread sharing the coordinator's process and
  its compiled executors (the coordinator passes its own executor table);
  arguments arrive by reference. ``kill()`` hard-stops it mid-queue
  without a reply — the observable signature of a process death, used by
  the chaos path.
* :func:`worker_entry` — the spawned-process body (``ProcTransport``):
  builds its own trainer replica from a :class:`WorkerSpec` (the
  newcomer's "cold start" — executors compile locally on the first job),
  then serves jobs with numpy-pytree payloads.

Both beat a heartbeat every ``heartbeat_interval`` seconds from a side
thread, and announce themselves with a ``join`` message once ready.
"""
from __future__ import annotations

import importlib
import threading
import traceback
from dataclasses import dataclass, field

import numpy as np

from repro.launch.transport import Message


# ---------------------------------------------------------------------------
# building a worker-side trainer (process mode)
# ---------------------------------------------------------------------------
@dataclass
class WorkerSpec:
    """How a process worker builds its trainer replica: ``builder`` is a
    ``"module:function"`` import string; the function receives ``kwargs``
    and returns a constructed (untrained) trainer. The builder must be
    importable from the spawned interpreter — a module under ``src/``
    (spawn propagates ``sys.path``), never a test-file local."""
    builder: str
    kwargs: dict = field(default_factory=dict)


def resolve_builder(spec: WorkerSpec):
    mod_name, _, fn_name = spec.builder.partition(":")
    if not fn_name:
        raise ValueError(
            f"WorkerSpec.builder must be 'module:function', got "
            f"{spec.builder!r}")
    return getattr(importlib.import_module(mod_name), fn_name)


def synthetic_builder(framework: str = "fedavg", n_clients: int = 40,
                      dim: int = 16, seed: int = 0, **cfg_kw):
    """Reference builder for tests and benchmarks: an mnist-like pinned
    trainer of any of the four frameworks. Deterministic in its arguments,
    so every worker process builds the identical replica."""
    from repro.core.fedgroup import FedGroupTrainer
    from repro.data.generators import mnist_like
    from repro.fed.engine import FedAvgTrainer, FedConfig
    from repro.fed.fesem import FeSEMTrainer
    from repro.fed.ifca import IFCATrainer
    from repro.models.paper_models import mclr

    classes = {"fedavg": FedAvgTrainer, "fedgroup": FedGroupTrainer,
               "ifca": IFCATrainer, "fesem": FeSEMTrainer}
    data = mnist_like(seed=seed, n_clients=n_clients, classes_per_client=2,
                      total_train=50 * n_clients, dim=dim)
    base = dict(n_rounds=4, clients_per_round=8, local_epochs=2,
                batch_size=5, lr=0.05, n_groups=3, pretrain_scale=4,
                seed=seed)
    base.update(cfg_kw)
    model = mclr(dim, 10)
    return classes[framework](model, data, FedConfig(**base))


def worker_fn_table(trainer) -> dict:
    """The jobs a worker serves: the trainer's compiled train dispatches.
    Evaluation stays on the coordinator (server-side metrics)."""
    return {"round": trainer._round_executor(),
            "block": trainer._block_executor(),
            "async": trainer._async_executor()}


def _to_numpy(tree):
    """Host-side copy of a pytree (device arrays -> numpy) for pickling
    across the process boundary."""
    import jax
    return jax.tree_util.tree_map(lambda a: np.asarray(a), tree)


# ---------------------------------------------------------------------------
# in-process (thread) worker
# ---------------------------------------------------------------------------
class InProcWorker:
    """A thread worker over an :class:`InProcEndpoint`. The function table
    is shared with the coordinator's trainer, so a routed dispatch runs
    the *same* compiled executor on the *same* arrays as a single-process
    run — the fleet-size-1 bit-identity guarantee."""

    def __init__(self, name: str, endpoint, table: dict,
                 heartbeat_interval: float = 0.05):
        self.name = name
        self._ep = endpoint
        self._table = table
        self._interval = heartbeat_interval
        self._dead = threading.Event()     # hard-stop (chaos kill)
        self._thread = None
        self._beat_thread = None

    def start(self):
        self._thread = threading.Thread(
            target=self._run, name=f"fleet-worker-{self.name}", daemon=True)
        self._beat_thread = threading.Thread(
            target=self._beat, name=f"fleet-beat-{self.name}", daemon=True)
        self._thread.start()
        self._beat_thread.start()
        self._ep.send(Message("join", self.name))

    def kill(self):
        """Hard-stop: no more job replies, no more heartbeats — the
        in-process equivalent of SIGKILL (chaos ``worker_kill``). A job
        already in the inbox is lost, exactly like a process death
        mid-dispatch."""
        self._dead.set()

    def stop(self):
        """Graceful leave: the worker drains its inbox up to the stop
        marker and announces departure."""
        self._ep.send(Message("leave", self.name))
        self._dead.set()

    def _beat(self):
        while not self._dead.is_set():
            self._ep.send(Message("heartbeat", self.name))
            self._dead.wait(self._interval)

    def _run(self):
        while not self._dead.is_set():
            msg = self._ep.recv(timeout=0.02)
            if msg is None or self._dead.is_set():
                continue
            if msg.kind == "stop":
                self._ep.send(Message("leave", self.name))
                self._dead.set()         # stops the beat thread too
                break
            if msg.kind != "job":
                continue
            fn_name, args = msg.payload
            try:
                out = self._table[fn_name](*args)
            except Exception:
                self._ep.send(Message("error", self.name, msg.job_id,
                                      traceback.format_exc()))
                continue
            if self._dead.is_set():
                continue                 # killed mid-dispatch: result lost
            self._ep.send(Message("result", self.name, msg.job_id, out))


# ---------------------------------------------------------------------------
# spawned-process worker body
# ---------------------------------------------------------------------------
def worker_entry(conn, name: str, spec: WorkerSpec,
                 heartbeat_interval: float = 0.05):
    """Process-worker main: build the trainer replica from ``spec`` (the
    newcomer cold start — jit compilation happens lazily on the first
    job), join the fleet, then serve jobs until ``stop`` or pipe close.
    Payloads are numpy pytrees both ways."""
    from repro.launch.transport import PipeEndpoint

    ep = PipeEndpoint(name, conn)
    try:
        trainer = resolve_builder(spec)(**spec.kwargs)
        table = worker_fn_table(trainer)
    except Exception:
        try:
            ep.send(Message("error", name, -1, traceback.format_exc()))
        finally:
            ep.close()
        return
    stop = threading.Event()

    def beat():
        while not stop.is_set():
            try:
                ep.send(Message("heartbeat", name))
            except (BrokenPipeError, OSError):
                return
            stop.wait(heartbeat_interval)

    threading.Thread(target=beat, daemon=True).start()
    ep.send(Message("join", name))
    try:
        while True:
            try:
                msg = ep.recv(timeout=0.05)
            except (EOFError, OSError):
                break                    # coordinator went away
            if msg is None:
                continue
            if msg.kind == "stop":
                ep.send(Message("leave", name))
                break
            if msg.kind != "job":
                continue
            fn_name, args = msg.payload
            try:
                out = _to_numpy(table[fn_name](*args))
            except Exception:
                ep.send(Message("error", name, msg.job_id,
                                traceback.format_exc()))
                continue
            ep.send(Message("result", name, msg.job_id, out))
    finally:
        stop.set()
        ep.close()

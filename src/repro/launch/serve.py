"""Serving launcher: batched autoregressive decoding with a KV/state cache.

Runs prefill (full forward) then step-decodes with ``serve_step`` —
exercises the same code path the decode dry-run shapes lower.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import zoo


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32, dest="plen")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.smoke:
        cfg = registry.smoke_variant(cfg)
    if args.window:
        cfg = cfg.with_window(args.window)
    if not cfg.decode_supported:
        print(f"{cfg.name} is encoder-only: no decode step")
        return 1

    key = jax.random.PRNGKey(args.seed)
    params = zoo.init_params(key, cfg)
    B = args.batch
    max_len = args.plen + args.gen
    cache_len = min(max_len, cfg.window) if cfg.window else max_len

    prompts = jax.random.randint(key, (B, args.plen), 0, cfg.vocab_size)
    step = jax.jit(lambda p, c, t, pos: zoo.serve_step(p, cfg, c, t, pos))

    # prefill through the decode path (one compiled program serves both)
    cache = zoo.init_cache(cfg, B, cache_len)
    t0 = time.time()
    logits = None
    for t in range(args.plen):
        logits, cache = step(params, cache, prompts[:, t:t + 1],
                             jnp.full((B,), t))
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    last = prompts[:, -1:]
    for i in range(args.gen):
        pos = jnp.full((B,), args.plen + i)
        if i == 0:
            nxt = jnp.argmax(logits, -1)[:, None]
        else:
            logits, cache = step(params, cache, last, pos - 1)
            if args.temperature > 0:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(
                    sk, logits / args.temperature, axis=-1)[:, None]
            else:
                nxt = jnp.argmax(logits, -1)[:, None]
        toks.append(nxt)
        last = nxt
    jax.block_until_ready(last)
    t_gen = time.time() - t0

    out = np.asarray(jnp.concatenate(toks, 1))
    print(f"# served {cfg.name}: batch={B} prompt={args.plen} gen={args.gen}")
    print(f"prefill {t_prefill*1e3:.1f}ms  decode {t_gen*1e3:.1f}ms "
          f"({args.gen * B / max(t_gen, 1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"seq[{b}]: {out[b, :16].tolist()} ...")
    return 0


if __name__ == "__main__":
    sys.exit(main())

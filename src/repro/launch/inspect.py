"""Run inspector: render a telemetry dir (``FedConfig.telemetry_dir``).

    PYTHONPATH=src python -m repro.launch.inspect RUN_DIR [--top K] [--spark]
    PYTHONPATH=src python -m repro.launch.inspect --check RUN_DIR

Works on finished *and* live runs: ``run_summary.json`` is used when
present, otherwise the per-stage breakdown is derived from ``trace.json``
and the accuracy series from the (still-growing) ``metrics.jsonl``.

``--check`` validates the dir against the telemetry schemas — Chrome
trace-event format, JSONL round-record keys + monotone round index, and
the summary's required keys — and exits non-zero on any violation, so
the benchmark gate can lint its own output (benchmarks/obs_bench.py).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

from repro.obs.trace import validate_chrome_trace

#: keys every JSONL round record must carry (trainer subclasses add more)
ROUND_RECORD_KEYS = ("kind", "t", "acc", "loss", "disc", "quarantined")

#: keys a run_summary.json must carry (repro.obs.telemetry.Telemetry.summary)
SUMMARY_KEYS = ("format", "counters", "stages", "span_kinds", "top_rounds")

_SPARK = "▁▂▃▄▅▆▇█"


def load_dir(run_dir: str) -> dict:
    """Best-effort load of everything a telemetry dir may contain."""
    out = {"summary": None, "records": [], "trace": None}
    p = os.path.join(run_dir, "run_summary.json")
    if os.path.exists(p):
        with open(p) as f:
            out["summary"] = json.load(f)
    p = os.path.join(run_dir, "trace.json")
    if os.path.exists(p):
        with open(p) as f:
            out["trace"] = json.load(f)
    for name in sorted(os.listdir(run_dir)):
        if name.startswith("metrics") and name.endswith(".jsonl"):
            with open(os.path.join(run_dir, name)) as f:
                for line in f:
                    if line.strip():
                        out["records"].append(json.loads(line))
    out["records"].sort(key=lambda r: r.get("t", -1))
    return out


def _stages_from_trace(trace: dict) -> dict:
    stages = {}
    for ev in trace.get("traceEvents", []):
        agg = stages.setdefault(ev["name"], {"count": 0, "total_s": 0.0,
                                             "max_s": 0.0})
        s = ev.get("dur", 0.0) / 1e6
        agg["count"] += 1
        agg["total_s"] += s
        agg["max_s"] = max(agg["max_s"], s)
    return stages


def _top_rounds_from_trace(trace: dict, k: int) -> list:
    per_round = {}
    for ev in trace.get("traceEvents", []):
        t = (ev.get("args") or {}).get("t")
        if t is None:
            continue
        per_round[int(t)] = per_round.get(int(t), 0.0) + \
            ev.get("dur", 0.0) / 1e6
    top = sorted(per_round.items(), key=lambda kv: -kv[1])[:k]
    return [{"t": t, "s": s} for t, s in top]


def sparkline(values, width: int = 60) -> str:
    vals = [v for v in values if v is not None and not math.isnan(v)]
    if not vals:
        return "(no data)"
    if len(vals) > width:          # downsample to the display width
        step = len(vals) / width
        vals = [vals[int(i * step)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(_SPARK[int((v - lo) / span * (len(_SPARK) - 1))]
                   for v in vals)


def render(run_dir: str, data: dict, top_k: int = 5,
           spark: bool = False) -> str:
    summary, records, trace = data["summary"], data["records"], data["trace"]
    live = summary is None
    lines = [f"telemetry dir: {run_dir}" + ("   [live — no summary yet]"
                                            if live else "")]
    stages = (summary or {}).get("stages") or (
        _stages_from_trace(trace) if trace else {})
    if stages:
        total = sum(a["total_s"] for a in stages.values()) or 1.0
        lines += ["", "per-stage time breakdown:",
                  f"  {'stage':<12} {'count':>7} {'total':>10} "
                  f"{'mean':>10} {'max':>10} {'share':>7}"]
        for kind, a in sorted(stages.items(), key=lambda kv: -kv[1]["total_s"]):
            mean = a["total_s"] / max(a["count"], 1)
            lines.append(
                f"  {kind:<12} {a['count']:>7} {a['total_s']:>9.3f}s "
                f"{mean * 1e3:>8.2f}ms {a['max_s'] * 1e3:>8.2f}ms "
                f"{a['total_s'] / total:>6.1%}")
    counters = (summary or {}).get("counters") or {}
    # pop.* are all degradation counters by construction (_STATS_ZERO);
    # of async.* only expiries/requeues and quarantines signal trouble;
    # of fleet.* everything except normal throughput/liveness traffic
    # (jobs, results, heartbeats, joins, workers gauge) is a fault signal
    _FLEET_OK = ("fleet.jobs", "fleet.results", "fleet.heartbeats",
                 "fleet.joins", "fleet.workers")
    degraded = {k: v for k, v in counters.items()
                if (k.startswith("pop.")
                    or (k.startswith("fleet.") and k not in _FLEET_OK)
                    or k in ("async.lease_expiries", "async.requeues",
                             "rounds.quarantined", "rounds.empty_folds"))
                and not isinstance(v, dict) and v}
    lines += ["", "degradation counters:"]
    if degraded:
        lines += [f"  {k:<28} {v}" for k, v in sorted(degraded.items())]
    else:
        lines.append("  (all zero)")
    shist = counters.get("async.staleness_hist") or {}
    if shist:
        lines.append("  staleness histogram: " + ", ".join(
            f"s={k}: {v}" for k, v in sorted(shist.items(),
                                             key=lambda kv: int(kv[0]))))
    top = (summary or {}).get("top_rounds") or (
        _top_rounds_from_trace(trace, top_k) if trace else [])
    if top:
        lines += ["", f"top-{min(top_k, len(top))} slowest rounds:"]
        lines += [f"  t={r['t']:<6} {r['s'] * 1e3:>9.2f}ms"
                  for r in top[:top_k]]
    if records:
        accs = [r.get("acc") for r in records if r.get("kind") == "round"]
        lines += ["", f"rounds streamed: "
                      f"{sum(1 for r in records if r.get('kind') == 'round')}"]
        if spark:
            lines.append("accuracy: " + sparkline(accs))
            losses = [r.get("loss") for r in records
                      if r.get("kind") == "round"]
            lines.append("loss:     " + sparkline(losses))
    return "\n".join(lines)


def check_dir(run_dir: str) -> list:
    """Schema-validate a telemetry dir; returns error strings (empty = ok)."""
    errors = []
    if not os.path.isdir(run_dir):
        return [f"{run_dir}: not a directory"]
    trace_path = os.path.join(run_dir, "trace.json")
    if os.path.exists(trace_path):
        try:
            with open(trace_path) as f:
                doc = json.load(f)
        except ValueError as e:
            errors.append(f"trace.json: invalid JSON ({e})")
        else:
            errors += [f"trace.json: {e}" for e in validate_chrome_trace(doc)]
    last_t = None
    for name in sorted(os.listdir(run_dir)):
        if not (name.startswith("metrics") and name.endswith(".jsonl")):
            continue
        with open(os.path.join(run_dir, name)) as f:
            for i, line in enumerate(f):
                if not line.strip():
                    continue
                try:
                    rec = json.loads(line)
                except ValueError as e:
                    errors.append(f"{name}:{i + 1}: invalid JSON ({e})")
                    continue
                if rec.get("kind") != "round":
                    continue
                missing = [k for k in ROUND_RECORD_KEYS if k not in rec]
                if missing:
                    errors.append(f"{name}:{i + 1}: missing {missing}")
                    continue
                if last_t is not None and rec["t"] <= last_t:
                    errors.append(
                        f"{name}:{i + 1}: round index t={rec['t']} not "
                        f"increasing (previous {last_t}) — duplicate or "
                        f"out-of-order record")
                last_t = rec["t"]
    summary_path = os.path.join(run_dir, "run_summary.json")
    if os.path.exists(summary_path):
        try:
            with open(summary_path) as f:
                summary = json.load(f)
        except ValueError as e:
            errors.append(f"run_summary.json: invalid JSON ({e})")
        else:
            for k in SUMMARY_KEYS:
                if k not in summary:
                    errors.append(f"run_summary.json: missing key {k!r}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="inspect a repro.obs telemetry dir")
    ap.add_argument("run_dir", help="telemetry dir (FedConfig.telemetry_dir)")
    ap.add_argument("--top", type=int, default=5,
                    help="slowest rounds to show")
    ap.add_argument("--spark", action="store_true",
                    help="ASCII sparklines of accuracy/loss")
    ap.add_argument("--check", action="store_true",
                    help="schema-validate only; exit 1 on violations")
    args = ap.parse_args(argv)
    if args.check:
        errors = check_dir(args.run_dir)
        for e in errors:
            print(f"SCHEMA VIOLATION: {e}", file=sys.stderr)
        print(f"{args.run_dir}: "
              + ("OK" if not errors else f"{len(errors)} violation(s)"))
        return 1 if errors else 0
    if not os.path.isdir(args.run_dir):
        print(f"{args.run_dir}: not a directory", file=sys.stderr)
        return 2
    print(render(args.run_dir, load_dir(args.run_dir),
                 top_k=args.top, spark=args.spark))
    return 0


if __name__ == "__main__":
    sys.exit(main())

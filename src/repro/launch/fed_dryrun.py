import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Dry-run for the PAPER'S TECHNIQUE at production scale (deliverable e/g,
'most representative of the paper' roofline pair).

Two workloads on the 16x16 (or 2x16x16) mesh:

  round      one FedGroup round: K=1024 clients sharded over "data", each
             running E=20 local epochs of the FEMNIST-MLP (415k params,
             paper Table 2), then per-group segment aggregation.

  coldstart  Algorithm 3 with a production-size update matrix
             ΔW (60 x d_w), d_w = 415,258,624 (the FEMNIST MLP scaled x1000
             — a realistic modern model), sharded over "model" along d_w.
             --qr cholesky switches tall-skinny QR to CholeskyQR2 (§Perf).

Usage:
  PYTHONPATH=src python -m repro.launch.fed_dryrun --workload round
  PYTHONPATH=src python -m repro.launch.fed_dryrun --workload coldstart --qr cholesky
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.fed import parallel as fp
from repro.launch.dryrun import OUT_DIR, parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models.paper_models import mlp

SDS = jax.ShapeDtypeStruct


def run_round(mesh, *, n_clients=1024, max_n=256, dim=784, n_groups=5,
              epochs=20, batch=10):
    model = mlp(dim, 512, 62)                      # paper FEMNIST-MLP
    round_fn = fp.make_parallel_round(
        model, epochs=epochs, batch_size=batch, lr=0.03, mu=0.0,
        n_groups=n_groups, max_samples=max_n)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    gp = jax.tree_util.tree_map(
        lambda l: SDS((n_groups,) + l.shape, l.dtype), params)
    args = (gp,
            SDS((n_clients,), jnp.int32),
            SDS((n_clients, max_n, dim), jnp.float32),
            SDS((n_clients, max_n), jnp.int32),
            SDS((n_clients,), jnp.int32),
            SDS((n_clients, 2), jnp.uint32))
    rep = jax.tree_util.tree_map(lambda l: P(*([None] * l.ndim)), gp)
    dsh = lambda nd: P(("pod", "data") if "pod" in mesh.axis_names
                       else "data", *([None] * (nd - 1)))
    in_specs = (rep, dsh(1), dsh(3), dsh(2), dsh(1), dsh(2))
    to_sh = lambda t: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    fn = jax.jit(round_fn, in_shardings=tuple(map(to_sh, in_specs)))
    return fn, args, {"while": epochs * ((max_n + batch - 1) // batch)}


def run_coldstart(mesh, *, n_pre=64, d_w=415_258_624, m=5,
                  qr_impl="householder", use_kernel=False):
    def coldstart(dW, key):
        E, V = fp.edc_embedding_distributed(dW, m, key=key, qr_impl=qr_impl,
                                            use_kernel=use_kernel)
        centers0 = E[:m]
        assign, centers = fp.kmeans_step(E, centers0)
        return assign, centers, E

    args = (SDS((n_pre, d_w), jnp.float32), SDS((2,), jnp.uint32))
    in_specs = (P(None, "model"), P(None))
    to_sh = lambda s: NamedSharding(mesh, s)
    fn = jax.jit(coldstart, in_shardings=tuple(map(to_sh, in_specs)))
    return fn, args, {"while": 1}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("round", "coldstart"),
                    default="round")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--qr", choices=("householder", "cholesky"),
                    default="householder")
    ap.add_argument("--kernel", action="store_true",
                    help="use the Pallas cosine kernel for the embedding")
    ap.add_argument("--dw", type=int, default=415_258_624)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.time()
    with mesh:
        if args.workload == "round":
            fn, fargs, trips = run_round(mesh)
        else:
            fn, fargs, trips = run_coldstart(mesh, qr_impl=args.qr,
                                             use_kernel=args.kernel,
                                             d_w=args.dw)
        lowered = fn.lower(*fargs)
        compiled = lowered.compile()
    dt = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo, trips)
    coll_bytes = sum(c["total_bytes"] for c in colls)
    by_kind = {}
    for c in colls:
        by_kind[c["kind"]] = by_kind.get(c["kind"], 0) + c["total_bytes"]

    rec = {
        "workload": f"fedgroup_{args.workload}", "mesh": mesh_name,
        "qr": args.qr, "kernel": args.kernel, "status": "ok",
        "compile_s": round(dt, 2),
        "memory_analysis": {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("argument_size_in_bytes", "temp_size_in_bytes",
                      "output_size_in_bytes")},
        "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed")},
        "collective_bytes_total": int(coll_bytes),
        "collective_bytes_by_kind": by_kind,
        "n_collectives": len(colls),
    }
    print(json.dumps(rec, indent=1))
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = f"fedgroup_{args.workload}_{mesh_name}_{args.qr}" + \
          ("_kernel" if args.kernel else "")
    with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())

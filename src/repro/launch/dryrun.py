import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, SPMD-partitions and compiles.

MUST be the first import side-effect: the XLA_FLAGS line above runs before
jax initializes, giving 512 placeholder host devices so the production
meshes (16x16 and 2x16x16) can be built. Do NOT import this module from
tests — they should see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 pairs, 1 pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Outputs one JSON per run under experiments/dryrun/ with:
  memory_analysis (bytes/device), cost_analysis (raw HLO flops/bytes —
  NOTE: scan bodies counted ONCE, see benchmarks/roofline.py for trip-count
  corrected numbers), and the collective inventory parsed from the
  partitioned HLO (op kind, shape, bytes, in-loop multiplier).
"""
import argparse
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry, shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.models import zoo
from repro.sharding import specs as sh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def input_specs(cfg: zoo.ArchConfig, shape: shp.InputShape):
    """ShapeDtypeStruct stand-ins for every model input of this workload."""
    if shape.kind in ("train", "prefill"):
        return shp.batch_specs(cfg, shape)
    return shp.decode_specs(cfg, shape)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

def _shape_bytes(shape_str: str) -> int:
    """'f32[4096,512]{1,0}' or tuple '(f32[..], ..)' -> payload bytes."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, loop_trip_counts: dict[str, int]):
    """Inventory of collective ops in the optimized module.

    loop_trip_counts: {computation-name-substring: trip count} — collectives
    inside while bodies execute once per iteration; the static trip counts of
    our scans (layer count, chunk count) are supplied by the caller.
    """
    out = []
    current_comp = ""
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*(?:->.*)?\{?$", line)
        if line.startswith(("ENTRY", "%", "fused_computation")) and "{" in line and "=" not in line:
            cm = re.search(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if cm:
                current_comp = cm.group(1)
        opm = re.search(r"=\s*(\([^=]*\)|\S+)\s+(" + "|".join(COLLECTIVES)
                        + r")(?:-start|-done)?\(", line)
        if opm:
            shape_str, kind = opm.group(1), opm.group(2)
            if "-done(" in line:       # avoid double counting start/done pairs
                continue
            nbytes = _shape_bytes(shape_str)
            mult = 1
            for key, tc in loop_trip_counts.items():
                if key in current_comp:
                    mult = max(mult, tc)
            out.append({"kind": kind, "computation": current_comp,
                        "bytes": nbytes, "loop_mult": mult,
                        "total_bytes": nbytes * mult})
    return out


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def build_step(cfg: zoo.ArchConfig, shape: shp.InputShape, mesh,
               zero: bool = False, fsdp: bool = False,
               cache_seq_shard: bool = False,
               batch_over_model: bool = False, moe_2d: bool = False):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    key = jax.random.PRNGKey(0)
    if shape.kind == "train":
        state_shapes = jax.eval_shape(lambda: zoo.init_train_state(key, cfg))
        st_specs = sh.state_specs(state_shapes, cfg, zero=zero, fsdp=fsdp,
                                  moe_2d=moe_2d)
        batch = input_specs(cfg, shape)
        b_specs = sh.data_specs(batch, mesh, include_model=batch_over_model)
        fn = jax.jit(
            partial(zoo.train_step, cfg=cfg),
            in_shardings=(_to_sharding(st_specs, mesh),
                          _to_sharding(b_specs, mesh)),
            out_shardings=(_to_sharding(st_specs, mesh), None),
            donate_argnums=(0,),
        )
        return fn, (state_shapes, batch)

    if shape.kind == "prefill":
        params_shapes = jax.eval_shape(lambda: zoo.init_params(key, cfg))
        p_specs = sh.param_specs(params_shapes, cfg,
                                 fsdp_axis="data" if fsdp else None)
        batch = input_specs(cfg, shape)
        b_specs = sh.data_specs(batch, mesh)

        def prefill(params, batch):
            logits, _ = zoo.forward(params, cfg, batch)
            return logits

        fn = jax.jit(prefill,
                     in_shardings=(_to_sharding(p_specs, mesh),
                                   _to_sharding(b_specs, mesh)),
                     out_shardings=None)
        return fn, (params_shapes, batch)

    # decode
    params_shapes = jax.eval_shape(lambda: zoo.init_params(key, cfg))
    p_specs = sh.param_specs(params_shapes, cfg,
                             fsdp_axis="data" if fsdp else None)
    ins = input_specs(cfg, shape)
    c_specs = sh.cache_specs(ins["cache"], cfg, mesh,
                             seq_shard=cache_seq_shard)
    t_specs = sh.data_specs({"tokens": ins["tokens"], "pos": ins["pos"]}, mesh)

    kv_spec = None
    if cache_seq_shard and "k" in ins["cache"]:
        full = c_specs["k"]                    # (L, B, S, KV, hd)
        kv_spec = P(*tuple(full)[1:])          # per-layer, inside the scan

    def decode(params, cache, tokens, pos):
        return zoo.serve_step(params, cfg, cache, tokens, pos,
                              kv_spec=kv_spec)

    fn = jax.jit(decode,
                 in_shardings=(_to_sharding(p_specs, mesh),
                               _to_sharding(c_specs, mesh),
                               _to_sharding(t_specs["tokens"], mesh),
                               _to_sharding(t_specs["pos"], mesh)),
                 out_shardings=(None, _to_sharding(c_specs, mesh)),
                 donate_argnums=(1,))
    return fn, (params_shapes, ins["cache"], ins["tokens"], ins["pos"])


def _to_sharding(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def loop_trip_counts(cfg: zoo.ArchConfig, shape: shp.InputShape):
    """Static trip counts for collective multipliers inside while bodies."""
    return {"while": cfg.n_layers}


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            zero: bool = False, fsdp: bool = False,
            cache_seq_shard: bool = False, mlstm_chunkwise: bool = False,
            xlstm_opt: bool = False, batch_over_model: bool = False,
            moe_2d: bool = False, bf16_params: bool = False,
            moe_grouped: bool = False, attn_chunk: int | None = None,
            save: bool = True, verbose: bool = True):
    base = registry.get(arch)
    if bf16_params:
        base = base.replace(param_dtype="bfloat16")
    if moe_grouped:
        base = base.replace(moe_impl="grouped")
    if attn_chunk:
        base = base.replace(attn_q_chunk=attn_chunk)
    if mlstm_chunkwise:
        base = base.replace(mlstm_impl="chunkwise")
    if xlstm_opt:
        base = base.replace(mlstm_impl="chunkwise", xlstm_chunk=256,
                            xlstm_scan_units=True)
        batch_over_model = True
    shape = shp.SHAPES[shape_name]
    ok, why = shp.supported(base, shape)
    if not ok:
        if verbose:
            print(f"SKIP {arch} x {shape_name}: {why}")
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": why}
    cfg = shp.config_for(base, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    t0 = time.time()
    with mesh:
        fn, args = build_step(cfg, shape, mesh, zero=zero, fsdp=fsdp,
                              cache_seq_shard=cache_seq_shard,
                              batch_over_model=batch_over_model,
                              moe_2d=moe_2d)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    mem_d = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_d[k] = int(v)
    cost_d = {k: float(v) for k, v in (cost or {}).items()
              if isinstance(v, (int, float)) and (
                  k in ("flops", "bytes accessed")
                  or k.startswith("bytes accessed"))}

    hlo = compiled.as_text()
    colls = parse_collectives(hlo, loop_trip_counts(cfg, shape))
    coll_bytes = sum(c["total_bytes"] for c in colls)
    by_kind = {}
    for c in colls:
        by_kind[c["kind"]] = by_kind.get(c["kind"], 0) + c["total_bytes"]

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "multi_pod": multi_pod, "zero": zero, "fsdp": fsdp,
        "cache_seq_shard": cache_seq_shard,
        "window": cfg.window,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": cost_d,
        "collective_bytes_total": int(coll_bytes),
        "collective_bytes_by_kind": by_kind,
        "n_collectives": len(colls),
    }
    if verbose:
        print(f"OK {arch} x {shape_name} mesh={mesh_name} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"   memory/device: "
              f"args={mem_d.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={mem_d.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"out={mem_d.get('output_size_in_bytes', 0)/2**30:.2f}GiB")
        print(f"   HLO flops={cost_d.get('flops', 0):.3e} "
              f"bytes={cost_d.get('bytes accessed', 0):.3e} "
              f"collective_bytes={coll_bytes:.3e} ({len(colls)} ops)")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_name}" + ("_zero" if zero else "") \
              + ("_fsdp" if fsdp else "") + ("_seqshard" if cache_seq_shard else "") \
              + ("_chunkwise" if mlstm_chunkwise else "") \
              + ("_xlstmopt" if xlstm_opt else "") \
              + ("_bom" if (batch_over_model and not xlstm_opt) else "") \
              + ("_moe2d" if moe_2d else "") + ("_bf16p" if bf16_params else "") \
              + ("_grouped" if moe_grouped else "") \
              + (f"_qc{attn_chunk}" if attn_chunk else "")
        with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shp.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--zero", action="store_true",
                    help="shard optimizer moments over the data axis (ZeRO-1)")
    ap.add_argument("--fsdp", action="store_true",
                    help="also shard params over the data axis (ZeRO-3)")
    ap.add_argument("--mlstm-chunkwise", action="store_true",
                    dest="mlstm_chunkwise",
                    help="chunkwise-parallel mLSTM instead of recurrent scan")
    ap.add_argument("--xlstm-opt", action="store_true", dest="xlstm_opt",
                    help="full optimized xLSTM: chunkwise Q=256 + unit-scan "
                         "+ batch sharded over the idle model axis")
    ap.add_argument("--moe-2d", action="store_true", dest="moe_2d",
                    help="2-D expert parallelism: experts over data x model")
    ap.add_argument("--attn-chunk", type=int, default=None, dest="attn_chunk",
                    help="query-chunked attention block size (§Perf)")
    ap.add_argument("--moe-grouped", action="store_true", dest="moe_grouped",
                    help="grouped (GShard-style) dispatch: shard-local "
                         "sort/gather + all-to-all instead of global scatter")
    ap.add_argument("--bf16-params", action="store_true", dest="bf16_params",
                    help="bf16 parameter storage (fp32 moments)")
    ap.add_argument("--batch-over-model", action="store_true",
                    dest="batch_over_model",
                    help="shard the train batch over the model axis too "
                         "(for archs with no tensor-parallel params)")
    ap.add_argument("--cache-seq-shard", action="store_true",
                    dest="cache_seq_shard",
                    help="shard decode caches over sequence when kv-heads "
                         "do not divide the model axis (§Perf)")
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        for a in registry.ARCHS:
            for s in shp.SHAPES:
                pairs.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    results = []
    for a, s in pairs:
        try:
            results.append(run_one(a, s, multi_pod=args.multi_pod,
                                   zero=args.zero, fsdp=args.fsdp,
                                   cache_seq_shard=args.cache_seq_shard,
                                   mlstm_chunkwise=args.mlstm_chunkwise,
                                   xlstm_opt=args.xlstm_opt,
                                   batch_over_model=args.batch_over_model,
                                   moe_2d=args.moe_2d,
                                   bf16_params=args.bf16_params,
                                   moe_grouped=args.moe_grouped,
                                   attn_chunk=args.attn_chunk))
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            print(f"FAIL {a} x {s}: {type(e).__name__}: {e}")
            results.append({"arch": a, "shape": s, "status": "fail",
                            "error": f"{type(e).__name__}: {e}"})
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skip, {n_fail} fail ==")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())

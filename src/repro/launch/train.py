"""Training launcher.

Two modes:
  --mode fed   (default) federated training with any framework on the
               synthetic federated datasets — the paper's workload.
  --mode lm    language-model training of a zoo architecture (reduced or
               full config) on synthetic token data — the substrate driver
               used by examples/zoo_train.py.

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode fed \
      --framework fedgroup --dataset femnist --rounds 30
  PYTHONPATH=src python -m repro.launch.train --mode lm \
      --arch gemma-2b --smoke --steps 200
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_pytree


def run_fed(args) -> int:
    from repro.core.fedgroup import FedGrouProxTrainer, FedGroupTrainer
    from repro.data import generators as gen
    from repro.fed.engine import FedAvgTrainer, FedConfig, FedProxTrainer
    from repro.fed.fesem import FeSEMTrainer
    from repro.fed.ifca import IFCATrainer
    from repro.models.paper_models import lstm_classifier, mclr, mlp

    datasets = {
        "mnist": lambda: (gen.mnist_like(args.seed, n_clients=args.clients or 1000,
                                         classes_per_client=2,
                                         total_train=20000, dim=128),
                          mclr(128, 10)),
        "mnist_mlp": lambda: (gen.mnist_like(args.seed, n_clients=args.clients or 1000,
                                             classes_per_client=2,
                                             total_train=20000, dim=128),
                              mlp(128, 128, 10)),
        "femnist": lambda: (gen.femnist_like(args.seed,
                                             n_clients=args.clients or 200,
                                             total_train=15000, dim=128),
                            mlp(128, 128, 62)),
        "synthetic": lambda: (gen.synthetic(1.0, 1.0, args.seed,
                                            n_clients=args.clients or 100),
                              mclr(60, 10)),
        "sent140": lambda: (gen.sent140_like(args.seed,
                                             n_clients=args.clients or 300,
                                             total_train=10000, vocab=400),
                            lstm_classifier(400, 16, 32)),
    }
    frameworks = {
        "fedavg": FedAvgTrainer, "fedprox": FedProxTrainer,
        "fedgroup": FedGroupTrainer, "fedgrouprox": FedGrouProxTrainer,
        "ifca": IFCATrainer, "fesem": FeSEMTrainer,
    }
    data, model = datasets[args.dataset]()
    cfg = FedConfig(n_rounds=args.rounds, clients_per_round=args.k,
                    local_epochs=args.epochs, batch_size=args.batch,
                    lr=args.lr, mu=args.mu, n_groups=args.groups,
                    pretrain_scale=args.alpha, eta_g=args.eta_g,
                    measure=args.measure, seed=args.seed,
                    async_depth=args.async_depth,
                    async_alpha=args.async_alpha,
                    async_beta=args.async_beta,
                    telemetry_dir=args.telemetry_dir)
    tr = frameworks[args.framework](model, data, cfg)
    print(f"# {args.framework} on {data.name}: {data.n_clients} clients, "
          f"m={cfg.n_groups}, K={cfg.clients_per_round}, E={cfg.local_epochs}"
          + (f", async_depth={cfg.async_depth}" if cfg.async_depth else ""))
    t0 = time.time()
    if cfg.async_depth:
        # async mode folds FIFO inside run(); report per-fold metrics after
        tr.run(cfg.n_rounds)
        for t, m in enumerate(tr.history.rounds):
            print(f"round {t:3d} acc={m.weighted_acc:.4f} "
                  f"disc={m.discrepancy:.4f}")
        st = tr.history.async_stats
        print(f"async: folds={st.get('folds')} "
              f"max_in_flight={st.get('max_in_flight')} "
              f"staleness={st.get('staleness_hist')} ({time.time()-t0:.1f}s)")
    else:
        for t in range(cfg.n_rounds):
            m = tr.round(t)
            print(f"round {t:3d} acc={m.weighted_acc:.4f} "
                  f"disc={m.discrepancy:.4f} ({time.time()-t0:.1f}s)")
    print(f"max_acc={tr.history.max_acc:.4f}")
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        from repro.fed.server import tree_index
        params = (tree_index(tr.group_params, 0)
                  if hasattr(tr, "group_params") else tr.params)
        save_pytree(os.path.join(args.out, "model.npz"), params,
                    {"framework": args.framework, "dataset": args.dataset,
                     "max_acc": tr.history.max_acc})
        with open(os.path.join(args.out, "history.json"), "w") as f:
            json.dump([r.__dict__ for r in tr.history.rounds], f, indent=1)
        print(f"saved to {args.out}")
    tr.close()          # flush telemetry (trace.json + run_summary.json)
    if args.telemetry_dir:
        print(f"telemetry in {args.telemetry_dir} — render with "
              f"python -m repro.launch.inspect {args.telemetry_dir}")
    return 0


def run_lm(args) -> int:
    from repro.configs import registry
    from repro.models import zoo

    cfg = registry.get(args.arch)
    if args.smoke:
        cfg = registry.smoke_variant(cfg)
    key = jax.random.PRNGKey(args.seed)
    state = zoo.init_train_state(key, cfg)
    from repro.models.modules import param_count
    print(f"# LM training {cfg.name} ({'smoke' if args.smoke else 'full'}): "
          f"{param_count(state['params']):,} params")

    B, S = args.batch, args.seq
    step_fn = jax.jit(lambda st, b: zoo.train_step(st, b, cfg))

    def make_batch(k):
        # synthetic markovian token stream: learnable bigram structure
        trans = jax.random.categorical(
            jax.random.PRNGKey(7), jnp.zeros((cfg.vocab_size, 32)), axis=-1)
        toks = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    t0 = time.time()
    for step in range(args.steps):
        key, sk = jax.random.split(key)
        state, metrics = step_fn(state, make_batch(sk))
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)")
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        save_pytree(os.path.join(args.out, "state.npz"), state,
                    {"arch": cfg.name, "steps": args.steps})
        print(f"saved to {args.out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("fed", "lm"), default="fed")
    # fed args
    ap.add_argument("--framework", default="fedgroup")
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--batch", type=int, default=10)
    ap.add_argument("--lr", type=float, default=0.03)
    ap.add_argument("--mu", type=float, default=0.0)
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--alpha", type=int, default=20)
    ap.add_argument("--eta-g", type=float, default=0.0, dest="eta_g")
    ap.add_argument("--measure", choices=("edc", "madc"), default="edc")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--async-depth", type=int, default=0, dest="async_depth",
                    help="D>0 keeps D in-flight cohort dispatches, folded "
                         "with FedAsync staleness weights (0 = synchronous)")
    ap.add_argument("--async-alpha", type=float, default=1.0,
                    dest="async_alpha")
    ap.add_argument("--telemetry-dir", default=None, dest="telemetry_dir",
                    help="stream spans/metrics here; render with "
                         "python -m repro.launch.inspect DIR")
    ap.add_argument("--async-beta", type=float, default=0.0,
                    dest="async_beta")
    # lm args
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    # common
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    return run_fed(args) if args.mode == "fed" else run_lm(args)


if __name__ == "__main__":
    sys.exit(main())

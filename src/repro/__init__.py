"""repro — FedGroup (Duan et al., 2020) as a production-grade multi-pod
JAX/TPU framework.

Subpackages:
  core      the paper's contribution: EDC/MADC measures, randomized SVD,
            clustering, FedGroup/FedGrouProx (Algorithms 2-3), cold starts,
            gate-network group mixing
  fed       federated engines (FedAvg/FedProx/IFCA/FeSEM) + mesh-parallel
            client engine and distributed cold start
  models    architecture zoo (10 assigned archs) + the paper's MCLR/MLP/LSTM
  kernels   Pallas TPU kernels (edc_cosine, swa_attention, ssd_chunk)
  sharding  PartitionSpec rules for the 16x16 / 2x16x16 production meshes
  data      synthetic federated datasets (offline stand-ins, see DESIGN.md)
  optim     SGD/momentum/AdamW/proximal + schedules
  checkpoint  npz pytree I/O
  configs   per-arch configs, input shapes, smoke variants
  launch    mesh, dry-runs, train/serve CLIs
"""

__version__ = "1.0.0"

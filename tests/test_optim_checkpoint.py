"""Optimizers + checkpoint I/O."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import load_metadata, load_pytree, save_pytree
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         momentum_init, momentum_update, proximal_grad,
                         sgd_update)


class TestOptimizers:
    def test_sgd_matches_formula(self):
        p = {"w": jnp.array([1.0, 2.0])}
        g = {"w": jnp.array([0.5, -1.0])}
        out = sgd_update(p, g, 0.1)
        np.testing.assert_allclose(out["w"], [0.95, 2.1])

    def test_momentum_accumulates(self):
        p = {"w": jnp.zeros(2)}
        g = {"w": jnp.ones(2)}
        v = momentum_init(p)
        p, v = momentum_update(p, g, v, lr=1.0, beta=0.9)
        p, v = momentum_update(p, g, v, lr=1.0, beta=0.9)
        np.testing.assert_allclose(v["w"], 1.9)     # 1 + 0.9*1
        np.testing.assert_allclose(p["w"], -2.9)    # -(1) - (1.9)

    def test_adamw_first_step_is_lr_sized(self):
        p = {"w": jnp.array([0.0])}
        g = {"w": jnp.array([3.0])}
        opt = adamw_init(p)
        p2, opt = adamw_update(p, g, opt, lr=0.1, weight_decay=0.0)
        # bias-corrected first step: update == sign(g) * lr
        np.testing.assert_allclose(p2["w"], [-0.1], atol=1e-5)

    def test_adamw_weight_decay_shrinks(self):
        p = {"w": jnp.array([10.0])}
        g = {"w": jnp.array([0.0])}
        opt = adamw_init(p)
        p2, _ = adamw_update(p, g, opt, lr=0.1, weight_decay=0.1)
        assert float(p2["w"][0]) < 10.0

    def test_proximal_grad(self):
        p = {"w": jnp.array([2.0])}
        a = {"w": jnp.array([1.0])}
        g = proximal_grad(p, a, mu=0.5)
        np.testing.assert_allclose(g["w"], [0.5])

    def test_cosine_schedule(self):
        assert float(cosine_schedule(0, base_lr=1.0, warmup=10, total=100)) == 0.0
        assert float(cosine_schedule(10, base_lr=1.0, warmup=10, total=100)) \
            == pytest.approx(1.0, abs=1e-5)
        end = float(cosine_schedule(100, base_lr=1.0, warmup=10, total=100))
        assert end == pytest.approx(0.1, abs=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        key = jax.random.PRNGKey(0)
        tree = {"layers": {"w": jax.random.normal(key, (4, 4)),
                           "b": jnp.zeros(4)},
                "step": jnp.array(7, jnp.int32)}
        p = str(tmp_path / "ck.npz")
        save_pytree(p, tree, {"round": 3, "acc": 0.9})
        back = load_pytree(p, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        meta = load_metadata(p)
        assert meta == {"round": 3, "acc": 0.9}

    def test_shape_mismatch_raises(self, tmp_path):
        p = str(tmp_path / "ck.npz")
        save_pytree(p, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            load_pytree(p, {"w": jnp.zeros((3, 3))})

    def test_model_state_roundtrip(self, tmp_path):
        from repro.configs import registry
        from repro.models import zoo
        cfg = registry.smoke_variant(registry.get("gemma-2b"))
        state = zoo.init_train_state(jax.random.PRNGKey(0), cfg)
        p = str(tmp_path / "state.npz")
        save_pytree(p, state, {"arch": cfg.name})
        back = load_pytree(p, state)
        assert load_metadata(p)["arch"] == "gemma-2b"
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

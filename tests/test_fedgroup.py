"""FedGroup core behaviour (Algorithms 2-3, eq. 9, convergence bound)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedgroup import FedGroupTrainer, FedGrouProxTrainer
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed import server as server_lib


class TestGroupColdStart:
    def test_assigns_pretrain_clients(self, tiny_model, tiny_fed_data, fast_cfg):
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        pre_idx, labels = tr.group_cold_start()
        assert len(pre_idx) == fast_cfg.pretrain_scale * fast_cfg.n_groups
        assert np.all(tr.membership[pre_idx] >= 0)
        assert set(np.unique(labels)) <= set(range(fast_cfg.n_groups))

    def test_group_models_differ_after_coldstart(self, tiny_model,
                                                 tiny_fed_data, fast_cfg):
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        tr.group_cold_start()
        flats = [np.asarray(jnp.concatenate([jnp.ravel(l) for l in
                 jax.tree_util.tree_leaves(tr.group_param(j))]))
                 for j in range(tr.m)]
        occupied = [j for j in range(tr.m)
                    if (tr.membership == j).sum() > 0]
        assert len(occupied) >= 2
        for i in occupied:
            for j in occupied:
                if i < j:
                    assert not np.allclose(flats[i], flats[j])

    def test_madc_branch(self, tiny_model, tiny_fed_data, fast_cfg):
        cfg = FedConfig(**{**fast_cfg.__dict__, "measure": "madc"})
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, cfg)
        pre_idx, labels = tr.group_cold_start()
        assert np.all(tr.membership[pre_idx] >= 0)


class TestClientColdStart:
    def test_newcomers_assigned(self, tiny_model, tiny_fed_data, fast_cfg):
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        tr.group_cold_start()
        cold = np.where(tr.membership < 0)[0][:8]
        tr.client_cold_start(cold)
        assert np.all(tr.membership[cold] >= 0)

    def test_membership_static_across_rounds(self, tiny_model, tiny_fed_data,
                                             fast_cfg):
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        tr.round(0)
        before = tr.membership.copy()
        tr.round(1)
        assigned = before >= 0
        # once assigned, membership never changes (static grouping)
        np.testing.assert_array_equal(tr.membership[assigned], before[assigned])

    def test_rac_ablation_assigns_randomly(self, tiny_model, tiny_fed_data,
                                           fast_cfg):
        cfg = FedConfig(**{**fast_cfg.__dict__, "rac": True})
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, cfg)
        tr.group_cold_start()
        cold = np.where(tr.membership < 0)[0][:20]
        tr.client_cold_start(cold)
        assert np.all(tr.membership[cold] >= 0)


class TestInterGroupAggregation:
    def test_eq20(self):
        """w̃_g = w_g + η Σ_{l≠g} w_l / ||w_l|| — exact check on vectors."""
        ps = [{"w": jnp.ones((3,)) * (i + 1)} for i in range(3)]
        eta = 0.5
        out = server_lib.inter_group_aggregate(ps, eta)
        for g in range(3):
            expect = np.asarray(ps[g]["w"], np.float64).copy()
            for l in range(3):
                if l != g:
                    wl = np.asarray(ps[l]["w"], np.float64)
                    expect += eta * wl / np.linalg.norm(wl)
            np.testing.assert_allclose(np.asarray(out[g]["w"]), expect,
                                       rtol=1e-5)

    def test_eta_zero_identity(self):
        ps = [{"w": jnp.arange(4.0) + i} for i in range(2)]
        out = server_lib.inter_group_aggregate(ps, 0.0)
        for a, b in zip(ps, out):
            np.testing.assert_allclose(a["w"], b["w"])


class TestFedGroupTraining:
    def test_beats_fedavg_on_label_skew(self, tiny_model, tiny_fed_data,
                                        fast_cfg):
        """Paper Table 3 headline: CFL > consensus FL under label skew."""
        fa = FedAvgTrainer(tiny_model, tiny_fed_data, fast_cfg)
        fg = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        ha = fa.run(4)
        hg = fg.run(4)
        assert hg.max_acc > ha.max_acc + 0.03

    def test_fedgrouprox_runs(self, tiny_model, tiny_fed_data, fast_cfg):
        tr = FedGrouProxTrainer(tiny_model, tiny_fed_data, fast_cfg)
        assert tr.cfg.mu > 0
        h = tr.run(2)
        assert 0.0 <= h.max_acc <= 1.0

    def test_eta_g_semi_pluralistic(self, tiny_model, tiny_fed_data, fast_cfg):
        cfg = FedConfig(**{**fast_cfg.__dict__, "eta_g": 0.01})
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, cfg)
        h = tr.run(2)
        assert np.isfinite(h.max_acc)


class TestConvergenceBound:
    def test_divergence_grows_with_E(self, tiny_model, tiny_fed_data):
        """Lemma 2 (qualitative): the bound (δ/L)((ηL+1)^E − 1) grows with E;
        the measured client-group divergence after local training should too."""
        discs = []
        for E in (1, 5, 20):
            cfg = FedConfig(n_rounds=1, clients_per_round=10, local_epochs=E,
                            batch_size=10, lr=0.05, n_groups=3,
                            pretrain_scale=4, seed=0)
            tr = FedAvgTrainer(tiny_model, tiny_fed_data, cfg)
            m = tr.round(0)
            discs.append(m.discrepancy)
        assert discs[0] < discs[1] < discs[2], discs

    def test_bound_formula_monotone(self):
        """The closed-form bound itself: monotone in E, δ, η_G, |G|."""
        def bound(delta, M, L, eta, E, eta_g=0.0, G=1):
            return delta * M / L * ((eta * L + 1) ** E - 1) + eta_g * (G - 1)
        assert bound(1, 1, 1, 0.1, 20) > bound(1, 1, 1, 0.1, 5)
        assert bound(2, 1, 1, 0.1, 5) > bound(1, 1, 1, 0.1, 5)
        assert bound(1, 1, 1, 0.1, 5, 0.1, 3) > bound(1, 1, 1, 0.1, 5, 0.0, 3)
        # eq. 22 degrades to eq. 19 when eta_g = 0 or |G| = 1
        assert bound(1, 1, 1, 0.1, 5, 0.5, 1) == bound(1, 1, 1, 0.1, 5)

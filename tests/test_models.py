"""Model-layer unit tests: attention, RoPE, SSD, xLSTM, MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.ref import ssd_chunk_ref
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.modules import (apply_rope, flatten_updates, rmsnorm,
                                  init_rmsnorm, unflatten_like)


class TestRoPE:
    def test_norm_preserving(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 64))
        pos = jnp.arange(8)[None, :]
        y = apply_rope(x, pos)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                                   np.linalg.norm(np.asarray(y), axis=-1),
                                   rtol=1e-5)

    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
        def dot_at(i, j):
            qi = apply_rope(q, jnp.array([[i]]))
            kj = apply_rope(k, jnp.array([[j]]))
            return float(jnp.sum(qi * kj))
        assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), abs=1e-4)
        assert dot_at(5, 5) == pytest.approx(dot_at(0, 0), abs=1e-4)

    def test_position_zero_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 2, 16))
        y = apply_rope(x, jnp.zeros((1, 1), jnp.int32))
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


class TestRMSNorm:
    @given(st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_unit_rms(self, seed):
        p = init_rmsnorm(32)
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * 10
        y = np.asarray(rmsnorm(p, x))
        rms = np.sqrt((y ** 2).mean(-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_scale_invariance(self):
        p = init_rmsnorm(16)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
        np.testing.assert_allclose(np.asarray(rmsnorm(p, x)),
                                   np.asarray(rmsnorm(p, x * 100)), atol=1e-4)


class TestAttention:
    def test_gqa_repeat_equals_mha_when_equal_heads(self):
        """kv == heads: GQA path is plain MHA."""
        key = jax.random.PRNGKey(0)
        p = attn.init_attention(key, 64, 4, 4, 16)
        x = jax.random.normal(key, (2, 8, 64))
        y = attn.attention_fwd(p, x, n_heads=4, n_kv=4, head_dim=16,
                               rope_theta=None)
        assert y.shape == (2, 8, 64)

    def test_causality(self):
        """Changing future tokens must not change past outputs."""
        key = jax.random.PRNGKey(1)
        p = attn.init_attention(key, 32, 2, 1, 16)
        x1 = jax.random.normal(key, (1, 8, 32))
        x2 = x1.at[:, 5:].set(jax.random.normal(jax.random.fold_in(key, 1),
                                                (1, 3, 32)))
        kw = dict(n_heads=2, n_kv=1, head_dim=16, rope_theta=10000.0)
        y1 = attn.attention_fwd(p, x1, **kw)
        y2 = attn.attention_fwd(p, x2, **kw)
        np.testing.assert_allclose(np.asarray(y1[:, :5]),
                                   np.asarray(y2[:, :5]), atol=1e-5)

    def test_window_restricts_reach(self):
        """With window w, token t ignores tokens < t-w+1."""
        key = jax.random.PRNGKey(2)
        p = attn.init_attention(key, 32, 2, 2, 16)
        x1 = jax.random.normal(key, (1, 16, 32))
        x2 = x1.at[:, 0:2].set(0.0)        # far past
        kw = dict(n_heads=2, n_kv=2, head_dim=16, rope_theta=None, window=4)
        y1 = attn.attention_fwd(p, x1, **kw)
        y2 = attn.attention_fwd(p, x2, **kw)
        np.testing.assert_allclose(np.asarray(y1[:, 10:]),
                                   np.asarray(y2[:, 10:]), atol=1e-5)

    def test_decode_matches_full_forward(self):
        """Token-by-token decode with positional cache == full causal fwd."""
        key = jax.random.PRNGKey(3)
        D, H, KV, hd, S, B = 32, 2, 1, 16, 6, 2
        p = attn.init_attention(key, D, H, KV, hd)
        x = jax.random.normal(key, (B, S, D))
        full = attn.attention_fwd(p, x, n_heads=H, n_kv=KV, head_dim=hd,
                                  rope_theta=10000.0)
        cache = attn.init_kv_cache(B, S, KV, hd, jnp.float32)
        outs = []
        for t in range(S):
            y, cache = attn.attention_decode(
                p, cache, x[:, t:t + 1], jnp.full((B,), t), n_heads=H,
                n_kv=KV, head_dim=hd, rope_theta=10000.0)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)

    def test_ring_buffer_decode_matches_windowed_forward(self):
        """Windowed ring-buffer cache == full forward with the same window."""
        key = jax.random.PRNGKey(4)
        D, H, KV, hd, S, B, W = 32, 2, 2, 16, 10, 1, 4
        p = attn.init_attention(key, D, H, KV, hd)
        x = jax.random.normal(key, (B, S, D))
        full = attn.attention_fwd(p, x, n_heads=H, n_kv=KV, head_dim=hd,
                                  rope_theta=10000.0, window=W)
        cache = attn.init_kv_cache(B, W, KV, hd, jnp.float32)
        outs = []
        for t in range(S):
            y, cache = attn.attention_decode(
                p, cache, x[:, t:t + 1], jnp.full((B,), t), n_heads=H,
                n_kv=KV, head_dim=hd, rope_theta=10000.0, window=W)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)


class TestMLA:
    def test_decode_matches_forward(self):
        """Absorbed-matrix decode == expanded training attention."""
        key = jax.random.PRNGKey(5)
        D, H, S, B = 32, 2, 5, 2
        kw = dict(n_heads=H, qk_nope=8, qk_rope=8, v_dim=8, kv_rank=16,
                  rope_theta=10000.0)
        p = attn.init_mla(key, D, H, q_rank=16, kv_rank=16, qk_nope=8,
                          qk_rope=8, v_dim=8)
        x = jax.random.normal(key, (B, S, D))
        full = attn.mla_fwd(p, x, **kw)
        cache = attn.init_mla_cache(B, S, 16, 8, jnp.float32)
        outs = []
        for t in range(S):
            y, cache = attn.mla_decode(p, cache, x[:, t:t + 1],
                                       jnp.full((B,), t), **kw)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)


class TestSSD:
    def test_chunked_matches_recurrence(self):
        """Chunked SSD == step-by-step recurrence (oracle)."""
        key = jax.random.PRNGKey(6)
        b, l, h, p, n = 2, 32, 3, 8, 4
        ks = jax.random.split(key, 4)
        X = jax.random.normal(ks[0], (b, l, h, p))
        dtA = -jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        B = jax.random.normal(ks[2], (b, l, h, n))
        C = jax.random.normal(ks[3], (b, l, h, n))
        for chunk in (4, 8, 16, 32):
            Y, fin = ssm_lib.ssd_chunked(X, dtA, B, C, chunk)
            Yr, finr = ssd_chunk_ref(X, dtA, B, C)
            np.testing.assert_allclose(np.asarray(Y), np.asarray(Yr),
                                       atol=1e-4, rtol=1e-4)
            np.testing.assert_allclose(np.asarray(fin), np.asarray(finr),
                                       atol=1e-4, rtol=1e-4)

    def test_mamba_block_decode_matches_forward(self):
        key = jax.random.PRNGKey(7)
        D, S, B = 16, 12, 2
        kw = dict(d_state=4, expand=2, head_dim=8)
        p = ssm_lib.init_mamba2(key, D, d_state=4, expand=2, head_dim=8)
        x = jax.random.normal(key, (B, S, D))
        full = ssm_lib.mamba2_fwd(p, x, chunk=4, **kw)
        cache = ssm_lib.init_mamba2_cache(B, D, d_state=4, expand=2,
                                          head_dim=8)
        outs = []
        for t in range(S):
            y, cache = ssm_lib.mamba2_step(p, cache, x[:, t:t + 1], **kw)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)


class TestXLSTM:
    def test_mlstm_block_decode_matches_forward(self):
        key = jax.random.PRNGKey(8)
        D, S, B, H = 16, 10, 2, 2
        p = xlstm_lib.init_mlstm(key, D, H)
        x = jax.random.normal(key, (B, S, D))
        full = xlstm_lib.mlstm_block_fwd(p, x, n_heads=H, chunk=5)
        cache = xlstm_lib.init_mlstm_cache(B, D, H)
        outs = []
        for t in range(S):
            y, cache = xlstm_lib.mlstm_block_step(p, cache, x[:, t:t + 1],
                                                  n_heads=H)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)

    def test_slstm_block_decode_matches_forward(self):
        key = jax.random.PRNGKey(9)
        D, S, B, H = 16, 10, 2, 2
        p = xlstm_lib.init_slstm(key, D, H)
        x = jax.random.normal(key, (B, S, D))
        full = xlstm_lib.slstm_block_fwd(p, x, n_heads=H, chunk=5)
        cache = xlstm_lib.init_slstm_cache(B, D)
        outs = []
        for t in range(S):
            y, cache = xlstm_lib.slstm_block_step(p, cache, x[:, t:t + 1],
                                                  n_heads=H)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)

    def test_mlstm_chunk_invariance(self):
        key = jax.random.PRNGKey(10)
        p = xlstm_lib.init_mlstm(key, 16, 2)
        x = jax.random.normal(key, (1, 16, 16))
        a = xlstm_lib.mlstm_block_fwd(p, x, n_heads=2, chunk=4)
        b = xlstm_lib.mlstm_block_fwd(p, x, n_heads=2, chunk=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


class TestMoE:
    def _apply(self, key, N=64, D=16, E=4, k=2, cf=8.0):
        p = moe_lib.init_moe(key, D, 32, E)
        x = jax.random.normal(key, (1, N, D))
        return p, x, moe_lib.moe_apply(p, x, top_k=k, capacity_factor=cf)

    def test_output_shape_finite(self):
        p, x, (y, aux) = self._apply(jax.random.PRNGKey(0))
        assert y.shape == x.shape
        assert np.all(np.isfinite(np.asarray(y)))

    def test_load_balance_loss_near_one_for_uniform(self):
        """Uniform routing -> load balance loss == E * sum(1/E * 1/E * E) = 1."""
        key = jax.random.PRNGKey(1)
        p = moe_lib.init_moe(key, 8, 16, 4)
        p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform logits
        x = jax.random.normal(key, (1, 256, 8))
        _, aux = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=8.0)
        # with ties broken arbitrarily the top-1 histogram may deviate a bit
        assert 0.5 < float(aux.load_balance_loss) < 2.0

    def test_expert_load_sums_to_one(self):
        _, _, (y, aux) = self._apply(jax.random.PRNGKey(2))
        assert float(jnp.sum(aux.expert_load)) == pytest.approx(1.0, abs=1e-5)

    def test_capacity_drops_dont_crash(self):
        """Tiny capacity factor: tokens dropped, output still finite."""
        p, x, (y, aux) = self._apply(jax.random.PRNGKey(3), cf=0.25)
        assert np.all(np.isfinite(np.asarray(y)))

    def test_matches_dense_computation_with_big_capacity(self):
        """With capacity >= all tokens, dispatch-combine == dense masked sum."""
        key = jax.random.PRNGKey(4)
        D, E, k = 8, 4, 2
        p = moe_lib.init_moe(key, D, 16, E)
        x = jax.random.normal(key, (1, 32, D))
        y, _ = moe_lib.moe_apply(p, x, top_k=k, capacity_factor=100.0)

        # dense reference
        xt = x.reshape(-1, D)
        logits = xt @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        gv, ei = jax.lax.top_k(probs, k)
        gv = gv / gv.sum(-1, keepdims=True)
        y_ref = jnp.zeros_like(xt)
        for e in range(E):
            up = xt @ p["w_up"][e]
            g = jax.nn.silu(xt @ p["w_gate"][e])
            out_e = (g * up) @ p["w_down"][e]
            w = jnp.sum(jnp.where(ei == e, gv, 0.0), -1)
            y_ref = y_ref + out_e * w[:, None]
        np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)


class TestFlatten:
    @given(st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_roundtrip(self, seed):
        key = jax.random.PRNGKey(seed)
        tree = {"a": jax.random.normal(key, (3, 4)),
                "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (5,)),
                      "d": jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 2))}}
        flat = flatten_updates(tree)
        assert flat.shape == (3 * 4 + 5 + 8,)
        back = unflatten_like(flat, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

"""Fault-tolerant runtime: checkpoint/restore bit-identity, the update
quarantine, scripted fault injection, straggler deadlines, and the bounded
async state writer.

The load-bearing guarantees:

  * kill-and-resume is BIT-identical: a run killed between checkpoints,
    restored into a fresh same-config trainer via ``load_checkpoint``,
    replays the remaining rounds with exactly the uninterrupted run's
    History, params, membership, and comm accounting — for the consensus
    and clustered frameworks alike, pinned and streamed.
  * the in-program quarantine keeps poisoned (NaN/Inf/blown-up) client
    updates out of the group parameters, and a screened lane is
    indistinguishable from a zero-weight dropped lane.
  * every wait in the failure domain is bounded: writer drains time out
    with a useful error, dead worker threads are surfaced instead of
    joined forever, and ``deadline`` degrades a straggling cohort to its
    staged prefix instead of barriering.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed import rounds as rounds_lib
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer
from repro.fed.population import (FaultConfig, FaultSpec, Population,
                                  PopulationConfig, Scheduler,
                                  _AsyncStateWriter)
from repro.fed.store import ArrayClientStore


@pytest.fixture(scope="module")
def small_data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


@pytest.fixture(scope="module")
def small_model():
    from repro.models.paper_models import mclr
    return mclr(16, 10)


def _cfg(**kw):
    base = dict(n_rounds=4, clients_per_round=8, local_epochs=2,
                batch_size=5, lr=0.05, n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _tree_finite(tree) -> bool:
    return all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(tree))


STREAM_KW = dict(initial_active=30, arrival_rate=2.0, prefetch=2)


def _fresh(cls, model, data, streamed, **cfg_kw):
    """A fresh trainer; streamed mode gets arrivals so the scheduler's
    arrival queue / newcomer cold start are part of what resume must
    reproduce."""
    cfg = _cfg(**cfg_kw)
    if streamed:
        pop = Population(ArrayClientStore(data),
                         PopulationConfig(**STREAM_KW))
        return cls(model, None, cfg, population=pop)
    return cls(model, data, cfg)


# ---------------------------------------------------------------------------
# checkpoint primitives (checkpoint/io.py)
# ---------------------------------------------------------------------------
class TestCheckpointIO:
    def test_save_is_atomic_and_path_exact(self, tmp_path):
        # bare path WITHOUT .npz: np.savez would silently append the
        # suffix; the file must land at exactly the requested path
        path = str(tmp_path / "snap")
        tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 3))}}
        ckpt_io.save_pytree(path, tree, {"note": "x"})
        assert os.path.exists(path)
        assert not list(tmp_path.glob("*.tmp-*"))    # no temp debris
        back = ckpt_io.load_pytree(path, tree)
        _assert_tree_equal(back, tree)
        assert ckpt_io.load_metadata(path) == {"note": "x"}

    def test_numpy_template_preserves_host_dtype(self, tmp_path):
        # int64 state arrays (membership, arrival queues) must come back
        # as host numpy int64 even under x64-disabled JAX
        path = str(tmp_path / "ints.npz")
        tree = {"ids": np.arange(5, dtype=np.int64),
                "dev": jnp.ones(3, jnp.float32)}
        ckpt_io.save_pytree(path, tree)
        back = ckpt_io.load_pytree(path, tree)
        assert isinstance(back["ids"], np.ndarray)
        assert back["ids"].dtype == np.int64
        assert isinstance(back["dev"], jnp.ndarray)

    def test_strict_load_rejects_key_mismatch(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        ckpt_io.save_pytree(path, {"a": np.zeros(2), "b": np.zeros(3)})
        with pytest.raises(ValueError, match="extra keys.*'b'"):
            ckpt_io.load_pytree(path, {"a": np.zeros(2)})
        with pytest.raises(ValueError, match="missing keys.*'c'"):
            ckpt_io.load_pytree(path, {"a": np.zeros(2), "b": np.zeros(3),
                                       "c": np.zeros(1)})

    def test_strict_load_rejects_shape_mismatch(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        ckpt_io.save_pytree(path, {"a": np.zeros((2, 3))})
        with pytest.raises(ValueError, match="shape mismatch at a"):
            ckpt_io.load_pytree(path, {"a": np.zeros((3, 2))})

    def test_latest_checkpoint_picks_highest_round(self, tmp_path):
        assert ckpt_io.latest_checkpoint(str(tmp_path)) is None
        assert ckpt_io.latest_checkpoint(str(tmp_path / "missing")) is None
        for t in (2, 10, 4):
            ckpt_io.save_pytree(ckpt_io.checkpoint_path(str(tmp_path), t),
                                {"t": np.asarray(t)})
        (tmp_path / "not_a_ckpt.npz").write_bytes(b"x")
        best = ckpt_io.latest_checkpoint(str(tmp_path))
        assert best == ckpt_io.checkpoint_path(str(tmp_path), 10)

    def test_saved_array_specs(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        ckpt_io.save_pytree(path, {"a": np.zeros((2, 3), np.float32),
                                   "b": np.zeros(5, np.int64)})
        specs = ckpt_io.saved_array_specs(path)
        assert specs["a"] == ((2, 3), np.dtype(np.float32))
        assert specs["b"] == ((5,), np.dtype(np.int64))


# ---------------------------------------------------------------------------
# bounded async state writer
# ---------------------------------------------------------------------------
class TestAsyncWriter:
    def test_writes_land_in_order(self):
        w, out = _AsyncStateWriter(), []
        for i in range(5):
            w.submit(out.append, i)
        w.drain()
        assert out == [0, 1, 2, 3, 4]
        w.close()

    def test_drain_timeout_names_inflight_write(self):
        import time
        w = _AsyncStateWriter()
        w.submit(time.sleep, 1.0, label="slow-write")
        with pytest.raises(RuntimeError,
                           match=r"did not complete within 0\.2s.*slow-write"):
            w.drain(timeout=0.2)
        w.drain(timeout=5.0)                 # the write eventually lands
        w.close()

    def test_failed_write_surfaces_on_drain(self):
        w = _AsyncStateWriter()

        def boom():
            raise ValueError("disk on fire")

        w.submit(boom)
        with pytest.raises(RuntimeError,
                           match="async state-table write failed") as ei:
            w.drain()
        assert isinstance(ei.value.__cause__, ValueError)
        w.close()

    def test_dead_thread_is_surfaced_not_awaited(self):
        w, out = _AsyncStateWriter(), []
        w.submit(out.append, 1)
        w.drain()
        w.inject_thread_crash()
        w.submit(out.append, 2)             # queued behind the crash
        with pytest.raises(RuntimeError,
                           match=r"writer thread died with 2 write"):
            w.drain(timeout=2.0)
        # close() reports the same instead of joining forever
        with pytest.raises(RuntimeError, match="writer thread died"):
            w.close(timeout=0.5)
        assert out == [1]


# ---------------------------------------------------------------------------
# kill-and-resume bit-identity (the tentpole guarantee)
# ---------------------------------------------------------------------------
ALL_TRAINERS = [FedAvgTrainer, FedGroupTrainer, IFCATrainer, FeSEMTrainer]


class TestKillAndResume:
    @pytest.mark.parametrize("streamed", [False, True],
                             ids=["pinned", "streamed"])
    @pytest.mark.parametrize("cls", ALL_TRAINERS,
                             ids=lambda c: c.framework)
    def test_resume_is_bit_identical(self, cls, streamed, small_model,
                                     small_data, tmp_path):
        # uninterrupted reference (no checkpointing)
        ref = _fresh(cls, small_model, small_data, streamed)
        h_ref = ref.run(4)
        ref.close()

        # checkpointed run "killed" after 3 rounds: the last checkpoint is
        # at t=2, so resume must also RE-execute round 2 identically
        ck = dict(checkpoint_every=2, checkpoint_dir=str(tmp_path))
        killed = _fresh(cls, small_model, small_data, streamed, **ck)
        killed.run(3)
        killed.close()
        assert os.path.exists(ckpt_io.checkpoint_path(str(tmp_path), 2))

        resumed = _fresh(cls, small_model, small_data, streamed, **ck)
        t = resumed.load_checkpoint(str(tmp_path))   # dir -> latest ckpt
        assert t == 2
        h_res = resumed.run(4 - t)
        resumed.close()

        assert h_res.rounds == h_ref.rounds
        _assert_tree_equal(resumed.params, ref.params)
        if hasattr(ref, "group_params"):
            _assert_tree_equal(resumed.group_params, ref.group_params)
            np.testing.assert_array_equal(resumed.membership, ref.membership)
        if getattr(ref, "local_flat", None) is not None:
            np.testing.assert_array_equal(np.asarray(resumed.local_flat),
                                          np.asarray(ref.local_flat))
        assert resumed.comm_params == ref.comm_params
        np.testing.assert_array_equal(np.asarray(resumed.key),
                                      np.asarray(ref.key))

    def test_run_counts_more_rounds_from_history(self, small_model,
                                                 small_data):
        # run(a); run(b) == run(a+b): absolute labels, one rng stream
        a = FedAvgTrainer(small_model, small_data, _cfg())
        a.run(2)
        a.run(2)
        b = FedAvgTrainer(small_model, small_data, _cfg())
        b.run(4)
        assert a.history.rounds == b.history.rounds
        assert [r.round for r in a.history.rounds] == [0, 1, 2, 3]

    def test_load_checkpoint_rejects_mismatches(self, small_model,
                                                small_data, tmp_path):
        tr = FedAvgTrainer(small_model, small_data, _cfg())
        tr.run(2)
        path = tr.save_checkpoint(str(tmp_path / "ck.npz"))
        # wrong framework
        other = FedGroupTrainer(small_model, small_data, _cfg())
        with pytest.raises(ValueError, match="framework"):
            other.load_checkpoint(path)
        # a trainer that has already trained
        busy = FedAvgTrainer(small_model, small_data, _cfg())
        busy.run(1)
        with pytest.raises(RuntimeError, match="fresh trainer"):
            busy.load_checkpoint(path)
        # pinned checkpoint into a streamed trainer
        pop = Population(ArrayClientStore(small_data), PopulationConfig())
        st = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        with pytest.raises(ValueError, match="pinned run"):
            st.load_checkpoint(path)
        st.close()

    def test_explicit_earlier_checkpoint_replays_forward(self, small_model,
                                                         small_data,
                                                         tmp_path):
        ck = dict(checkpoint_every=2, checkpoint_dir=str(tmp_path))
        full = FedAvgTrainer(small_model, small_data, _cfg(**ck))
        h_full = full.run(4)                 # ckpts at t=2 and t=4
        early = ckpt_io.checkpoint_path(str(tmp_path), 2)
        resumed = FedAvgTrainer(small_model, small_data, _cfg(**ck))
        assert resumed.load_checkpoint(early) == 2   # explicit file, not dir
        h_res = resumed.run(2)
        assert h_res.rounds == h_full.rounds
        _assert_tree_equal(resumed.params, full.params)


# ---------------------------------------------------------------------------
# update quarantine
# ---------------------------------------------------------------------------
class TestQuarantine:
    def test_screened_lane_equals_zero_weight_drop(self, small_model,
                                                   small_data):
        """A poisoned-and-quarantined lane must be indistinguishable from
        the same cohort with that lane zero-weighted out (the dropout
        padding path) — same group params, loss, and discrepancy."""
        d = small_data
        K, m = 4, 2
        mk = lambda q: rounds_lib._make_round_core(
            small_model, epochs=1, batch_size=5, lr=0.05, mu=0.0,
            n_groups=m, max_samples=d.x_train.shape[1], quarantine=q)
        keys = jax.random.split(jax.random.PRNGKey(3), K)
        gp = rounds_lib.stack_trees(
            [small_model.init(k) for k in jax.random.split(
                jax.random.PRNGKey(7), m)])
        mem = jnp.asarray([0, 1, 0, 1], jnp.int32)
        x = jnp.asarray(d.x_train[:K])
        y = jnp.asarray(d.y_train[:K])
        n = jnp.asarray(d.n_train[:K])
        ones = jnp.ones(K, jnp.float32)

        x_poison = x.at[2].set(jnp.nan)
        out_q = mk(True)(gp, mem, x_poison, y, n, keys, ones)
        assert int(out_q.n_quarantined) == 1
        assert _tree_finite(out_q.group_params)

        # oracle: lane 2 dead from the start, payload finite-but-ignored
        x_dead = x.at[2].set(0.0)
        alive = ones.at[2].set(0.0)
        out_d = mk(False)(gp, mem, x_dead, y, n, keys, alive)
        _assert_tree_equal(out_q.group_params, out_d.group_params)
        _assert_tree_equal(out_q.global_params, out_d.global_params)
        np.testing.assert_array_equal(np.asarray(out_q.mean_loss),
                                      np.asarray(out_d.mean_loss))
        np.testing.assert_array_equal(np.asarray(out_q.discrepancy),
                                      np.asarray(out_d.discrepancy))

    def _faulted_run(self, model, data, quarantine, faults=None):
        pop = Population(ArrayClientStore(data),
                         PopulationConfig(faults=faults))
        tr = FedGroupTrainer(model, None, _cfg(quarantine=quarantine),
                             population=pop)
        h = tr.run(5)
        tr.close()
        return tr, h

    def test_quarantine_keeps_params_finite_under_faults(self, small_model,
                                                         small_data):
        faults = FaultConfig(rounds={
            1: FaultSpec(corrupt=3, corrupt_mode="nan"),
            2: FaultSpec(corrupt=2, corrupt_mode="inf"),
            3: FaultSpec(corrupt=2, corrupt_mode="scale")})
        tr, h = self._faulted_run(small_model, small_data, True, faults)
        assert _tree_finite(tr.group_params)
        assert _tree_finite(tr.params)
        # every poisoned payload was injected...
        assert tr.population.stats["corrupted_clients"] == 7
        # ...and at least the non-finite ones were screened, with the
        # counts surfaced round by round in History
        assert h.total_quarantined >= 5
        assert h.rounds[1].quarantined >= 1
        assert h.rounds[2].quarantined >= 1
        assert h.rounds[0].quarantined == 0
        # the screen costs at most noise: the faulted run's final accuracy
        # tracks a clean run's
        _, h_clean = self._faulted_run(small_model, small_data, True)
        assert h.rounds[-1].weighted_acc >= \
            h_clean.rounds[-1].weighted_acc - 0.25

    def test_without_quarantine_faults_poison_params(self, small_model,
                                                     small_data):
        faults = FaultConfig(rounds={1: FaultSpec(corrupt=3,
                                                  corrupt_mode="nan")})
        tr, h = self._faulted_run(small_model, small_data, False, faults)
        assert not _tree_finite(tr.group_params)
        assert h.total_quarantined == 0


# ---------------------------------------------------------------------------
# fault injection + straggler deadlines
# ---------------------------------------------------------------------------
class TestFaultsAndDeadlines:
    def test_mid_round_client_death(self, small_model, small_data):
        faults = FaultConfig(rounds={1: FaultSpec(kill=5)})
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(faults=faults))
        tr = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        h = tr.run(3)
        tr.close()
        assert pop.stats["killed_clients"] == 5
        assert len(h.rounds) == 3
        assert _tree_finite(tr.params)

    def test_kill_floors_at_one_survivor(self, small_model, small_data):
        faults = FaultConfig(rounds={0: FaultSpec(kill=100)})
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(faults=faults, prefetch=0))
        tr = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        tr.run(1)
        tr.close()
        assert pop.stats["killed_clients"] == 7      # 8-client cohort -> 1

    @pytest.mark.parametrize("prefetch", [2, 0], ids=["prefetch", "sync"])
    def test_deadline_degrades_straggling_round(self, prefetch, small_model,
                                                small_data):
        # straggle round 0: the consumer cannot run ahead of the first
        # round, so the deadline deterministically fires mid-gather (a
        # later round's cohort could finish staging while the previous
        # round is still compiling)
        faults = FaultConfig(rounds={0: FaultSpec(straggle=2.0)})
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(faults=faults, prefetch=prefetch,
                                          deadline=0.3, stage_chunks=4))
        tr = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        h = tr.run(3)
        tr.close()
        assert pop.stats["deadline_rounds"] >= 1
        assert pop.stats["deadline_dropped_clients"] >= 1
        assert len(h.rounds) == 3                    # no round was lost
        assert _tree_finite(tr.params)

    def test_generous_deadline_is_bit_identical_to_pinned(self, small_model,
                                                          small_data):
        # the chunked-staging deadline path must not change results when
        # the deadline never fires
        pin = FedAvgTrainer(small_model, small_data, _cfg())
        h_pin = pin.run(3)
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(deadline=60.0, stage_chunks=4))
        st = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        h_st = st.run(3)
        st.close()
        assert pop.stats["deadline_rounds"] == 0
        assert h_st.rounds == h_pin.rounds
        _assert_tree_equal(st.params, pin.params)

    def test_writer_thread_crash_is_surfaced(self, small_model, small_data):
        faults = FaultConfig(rounds={1: FaultSpec(writer_crash=True)})
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(faults=faults))
        tr = FeSEMTrainer(small_model, None, _cfg(), population=pop)
        with pytest.raises(RuntimeError, match="writer thread died"):
            tr.run(4)
        assert pop.stats["writer_crashes"] == 1
        pop._stop.set()                  # stop the producer...
        with pytest.raises(RuntimeError, match="writer thread died"):
            pop.close()                  # ...shutdown reports, not hangs


# ---------------------------------------------------------------------------
# empty-cohort edge (satellite): selection always yields >= 1 client
# ---------------------------------------------------------------------------
class TestEmptyCohortEdge:
    def test_full_dropout_keeps_one_client(self, small_data):
        sched = Scheduler(ArrayClientStore(small_data), PopulationConfig(),
                          seed=0)
        idx, _ = sched.select(0, 8, dropout_rate=1.0)
        assert len(idx) == 1

    def test_all_asleep_wakes_one_active(self, small_data):
        # duty=0 puts every client to sleep every round: selection falls
        # back to waking one *active* client instead of an empty cohort
        sched = Scheduler(ArrayClientStore(small_data),
                          PopulationConfig(availability="diurnal", duty=0.0,
                                           initial_active=10), seed=0)
        for t in range(3):
            idx, _ = sched.select(t, 8)
            assert len(idx) == 1
            assert sched.active[idx[0]]

    def test_no_active_clients_is_an_error(self, small_data):
        sched = Scheduler(ArrayClientStore(small_data),
                          PopulationConfig(initial_active=0), seed=0)
        with pytest.raises(RuntimeError, match="no active clients"):
            sched.select(0, 8)

    def test_pinned_select_keeps_one_client(self, small_model, small_data):
        tr = FedAvgTrainer(small_model, small_data, _cfg(dropout_rate=1.0))
        assert len(tr._select()) == 1

    def test_streamed_run_survives_empty_rounds(self, small_model,
                                                small_data):
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(availability="diurnal", duty=0.0,
                                          initial_active=10, prefetch=0))
        tr = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        h = tr.run(2)
        tr.close()
        assert len(h.rounds) == 2
        assert _tree_finite(tr.params)

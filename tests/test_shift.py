"""Distribution-shift scenarios + FedGroup's shift-migration path.

Three layers, matching the runtime's:

  * the scripted generators (``ShiftSpec``/``apply_shift``) are pure and
    deterministic per seed — label swaps are abrupt class-cycle remaps,
    drift phases samples in monotonically;
  * the population applies them identically on every feeding path —
    prefetched, synchronous, eval — so streamed runs replay bit-for-bit
    at any prefetch depth and across kill-and-resume;
  * FedGroup's detector probes cached eq.-9 directions, invalidates the
    stale rows (the cache-staleness fix), migrates drifted clients and
    accounts everything in the telemetry registry.
"""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer
from repro.fed.population import (Population, PopulationConfig, ShiftConfig,
                                  ShiftSpec, apply_shift, shift_client_mask,
                                  shift_label_map)
from repro.fed.store import ArrayClientStore, ClientStateTable

pytestmark = pytest.mark.shift


@pytest.fixture(scope="module")
def small_data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


@pytest.fixture(scope="module")
def small_model():
    from repro.models.paper_models import mclr
    return mclr(16, 10)


def _cfg(**kw):
    base = dict(n_rounds=4, clients_per_round=8, local_epochs=2,
                batch_size=5, lr=0.05, n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


SWAP_ALL = ShiftConfig([ShiftSpec(at=2)])    # cycle every class at t=2


# ---------------------------------------------------------------------------
# generators: pure, deterministic, composable
# ---------------------------------------------------------------------------
class TestGenerators:
    def test_label_map_cycles(self):
        m = shift_label_map(4, (0, 2))
        assert m.tolist() == [2, 1, 0, 3]            # 0<->2, others fixed
        m = shift_label_map(4, (1, 2, 3))
        assert m.tolist() == [0, 2, 3, 1]            # 1->2->3->1
        assert shift_label_map(3, None).tolist() == [1, 2, 0]
        assert shift_label_map(3, (2,)).tolist() == [0, 1, 2]  # degenerate

    def test_inactive_before_at_and_identity_object(self):
        y = np.arange(6).reshape(2, 3)
        out = apply_shift(SWAP_ALL, 5, 10, 1, np.arange(2), y)
        assert out is y                              # untouched, not copied
        assert apply_shift(None, 5, 10, 9, np.arange(2), y) is y
        assert apply_shift(SWAP_ALL, 5, 10, -1, np.arange(2), y) is y

    def test_label_swap_is_abrupt_and_stable(self):
        y = np.array([[0, 1, 2, 3]])
        for t in (2, 3, 50):                         # same remap every round
            out = apply_shift(SWAP_ALL, 4, 4, t, np.array([1]), y)
            assert out.tolist() == [[1, 2, 3, 0]]
        assert y.tolist() == [[0, 1, 2, 3]]          # input never mutated

    def test_frac_masks_fixed_client_subset(self):
        mask = shift_client_mask(200, seed=0, spec_index=0, frac=0.4)
        again = shift_client_mask(200, seed=0, spec_index=0, frac=0.4)
        np.testing.assert_array_equal(mask, again)   # per-seed deterministic
        other = shift_client_mask(200, seed=1, spec_index=0, frac=0.4)
        assert (mask != other).any()                 # seed actually matters
        assert 0.2 < mask.mean() < 0.6
        sh = ShiftConfig([ShiftSpec(at=0, frac=0.4)], seed=0)
        idx = np.arange(200)
        y = np.zeros((200, 3), np.int64)
        out = apply_shift(sh, 200, 4, 0, idx, y)
        np.testing.assert_array_equal((out != y).any(1), mask)

    def test_drift_phases_in_monotonically(self):
        sh = ShiftConfig([ShiftSpec(at=2, kind="drift", duration=5)])
        y = np.tile(np.arange(4), (3, 6))
        idx = np.arange(3)
        changed = [int((apply_shift(sh, 3, 4, t, idx, y) != y).sum())
                   for t in range(12)]
        assert changed[0] == changed[1] == 0         # before onset
        assert all(a <= b for a, b in zip(changed[2:], changed[3:]))
        # fully phased in == the abrupt swap of the same cycle
        full = apply_shift(ShiftConfig([ShiftSpec(at=2)]), 3, 4, 9, idx, y)
        np.testing.assert_array_equal(
            apply_shift(sh, 3, 4, 9, idx, y), full)

    def test_specs_compose_in_order(self):
        sh = ShiftConfig([ShiftSpec(at=0, classes=(0, 1)),
                          ShiftSpec(at=2, classes=(1, 2))])
        y = np.array([[0]])
        # t=0: only 0<->1; t=2: 0 ->(swap 0,1)-> 1 ->(swap 1,2)-> 2
        assert apply_shift(sh, 1, 3, 0, [0], y).tolist() == [[1]]
        assert apply_shift(sh, 1, 3, 2, [0], y).tolist() == [[2]]

    def test_unknown_kind_rejected(self):
        sh = ShiftConfig([ShiftSpec(at=0, kind="meteor")])
        with pytest.raises(ValueError, match="meteor"):
            apply_shift(sh, 1, 3, 0, [0], np.array([[0]]))


# ---------------------------------------------------------------------------
# population feeding paths under shift
# ---------------------------------------------------------------------------
class TestPopulationShift:
    def _collect(self, data, pop_kw, rounds=4):
        pop = Population(ArrayClientStore(data), PopulationConfig(**pop_kw))
        out = []
        try:
            pop.attach(_cfg())
            for _ in range(rounds):
                c = pop.next_cohort()
                out.append((c.t, c.idx.copy(), np.asarray(c.y).copy()))
        finally:
            pop.close()
        return out

    def test_cohorts_shift_at_onset(self, small_data):
        sh = ShiftConfig([ShiftSpec(at=2)])
        got = self._collect(small_data, dict(shift=sh, prefetch=0))
        store = ArrayClientStore(small_data)
        for t, idx, y in got:
            _, y_raw, _ = store._gather("train", idx)
            if t < 2:
                np.testing.assert_array_equal(y, y_raw)
            else:
                assert (y != y_raw).any()
                np.testing.assert_array_equal(
                    y, apply_shift(sh, store.n_clients, store.n_classes,
                                   t, idx, y_raw))

    def test_prefetched_equals_synchronous(self, small_data):
        sh = ShiftConfig([ShiftSpec(at=1, frac=0.5),
                          ShiftSpec(at=2, kind="drift", duration=3)])
        a = self._collect(small_data, dict(shift=sh, prefetch=2))
        b = self._collect(small_data, dict(shift=sh, prefetch=0))
        for (ta, ia, ya), (tb, ib, yb) in zip(a, b):
            assert ta == tb
            np.testing.assert_array_equal(ia, ib)
            np.testing.assert_array_equal(ya, yb)

    def test_eval_blocks_follow_the_shift(self, small_data):
        sh = ShiftConfig([ShiftSpec(at=1)])
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(shift=sh, prefetch=0))
        store = ArrayClientStore(small_data)
        try:
            pop.attach(_cfg())
            pop.next_cohort()                        # consume round 0
            blk = next(iter(pop.eval_batches(np.arange(5))))
            _, y_raw, _ = store._gather("test", blk[0])
            np.testing.assert_array_equal(np.asarray(blk[2]), y_raw)
            pop.next_cohort()                        # round 1: shift live
            blk = next(iter(pop.eval_batches(np.arange(5))))
            _, y_raw, _ = store._gather("test", blk[0])
            assert (np.asarray(blk[2]) != y_raw).any()
        finally:
            pop.close()

    def test_streamed_run_deterministic_per_seed(self, small_model,
                                                 small_data):
        def go():
            pop = Population(ArrayClientStore(small_data),
                             PopulationConfig(shift=SWAP_ALL, prefetch=2))
            tr = FedAvgTrainer(small_model, None, _cfg(), population=pop)
            h = tr.run(4)
            tr.close()
            return tr, h

        a, h_a = go()
        b, h_b = go()
        assert h_a.rounds == h_b.rounds
        _assert_tree_equal(a.params, b.params)


# ---------------------------------------------------------------------------
# the direction-cache staleness fix (satellite a)
# ---------------------------------------------------------------------------
class TestDirectionCacheInvalidation:
    def test_invalidate_drops_only_named_rows(self):
        st = ClientStateTable(10)
        st.set_pretrain_dir([1, 4, 7], np.ones((3, 5), np.float32))
        np.testing.assert_array_equal(
            st.has_pretrain_dir(np.arange(10)),
            np.isin(np.arange(10), [1, 4, 7]))
        st.invalidate_pretrain_dir([4, 9])           # 9 never set: no-op
        np.testing.assert_array_equal(
            st.has_pretrain_dir([1, 4, 7]), [True, False, True])
        # a dropped row reads as the default again, not the stale value
        np.testing.assert_array_equal(st.get_pretrain_dir([4]),
                                      np.zeros((1, 5), np.float32))

    def test_empty_table_is_safe(self):
        st = ClientStateTable(4)
        assert not st.has_pretrain_dir([0, 1]).any()
        st.invalidate_pretrain_dir([0, 1])           # no table yet: no-op


# ---------------------------------------------------------------------------
# FedGroup shift detection + migration
# ---------------------------------------------------------------------------
class TestFedGroupMigration:
    def _run(self, model, data, rounds=9, threshold=0.35, shift=None,
             **cfg_kw):
        pop = Population(ArrayClientStore(data),
                         PopulationConfig(shift=shift))
        cfg = _cfg(n_rounds=rounds, shift_threshold=threshold,
                   clients_per_round=10, **cfg_kw)
        tr = FedGroupTrainer(model, None, cfg, population=pop)
        h = tr.run(rounds)
        tr.close()
        return tr, h

    def test_swap_triggers_migration_within_k_rounds(self, small_model,
                                                     small_data):
        """After the round-3 label swap, the detector re-clusters affected
        clients within the remaining rounds — and the migrations land in
        the registry and the per-round records."""
        tr, h = self._run(small_model, small_data,
                          shift=ShiftConfig([ShiftSpec(at=3)]))
        reg = tr.obs.registry
        assert int(reg.get("rounds.shift_checks")) > 0
        assert int(reg.get("rounds.migrations")) > 0
        assert len(h.rounds) == 9
        # the stale rows were recomputed, not reused: every client the
        # detector migrated carries a (fresh) cached direction afterwards
        migrated = tr._last_shifted
        if len(migrated):
            assert tr.population.state.has_pretrain_dir(migrated).all()

    def test_no_shift_no_migration(self, small_model, small_data):
        """Same detector, stationary data: probes run, nobody moves (the
        threshold separates re-probe noise from a real swap)."""
        tr, _ = self._run(small_model, small_data, rounds=6, threshold=0.35)
        reg = tr.obs.registry
        assert int(reg.get("rounds.shift_checks")) > 0
        assert int(reg.get("rounds.migrations")) == 0

    def test_detector_off_is_bitwise_undisturbed(self, small_model,
                                                 small_data):
        """shift_threshold=None (the default) must leave the streamed
        FedGroup run byte-identical to the pre-detector behaviour — no rng
        splits, no comm accounting, no record fields."""
        def go(**kw):
            pop = Population(ArrayClientStore(small_data),
                             PopulationConfig())
            tr = FedGroupTrainer(small_model, None, _cfg(**kw),
                                 population=pop)
            h = tr.run(4)
            tr.close()
            return tr, h

        a, h_a = go()
        b, h_b = go(shift_threshold=None)
        assert h_a.rounds == h_b.rounds
        _assert_tree_equal(a.group_params, b.group_params)
        assert a.comm_params == b.comm_params
        np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))

    def test_check_every_throttles_probes(self, small_model, small_data):
        dense, _ = self._run(small_model, small_data, rounds=6,
                             shift_check_every=1)
        sparse, _ = self._run(small_model, small_data, rounds=6,
                              shift_check_every=3)
        assert int(sparse.obs.registry.get("rounds.shift_checks")) < \
            int(dense.obs.registry.get("rounds.shift_checks"))


# ---------------------------------------------------------------------------
# kill-and-resume bit-identity under shift (extends the PR-6 matrix)
# ---------------------------------------------------------------------------
SHIFT_KW = dict(shift=ShiftConfig([ShiftSpec(at=2, frac=0.6),
                                   ShiftSpec(at=3, kind="drift",
                                             duration=3)]),
                prefetch=2)


def _fresh_shifted(cls, model, data, **cfg_kw):
    pop = Population(ArrayClientStore(data), PopulationConfig(**SHIFT_KW))
    if cls is FedGroupTrainer:
        cfg_kw.setdefault("shift_threshold", 0.35)
    return cls(model, None, _cfg(**cfg_kw), population=pop)


class TestKillAndResumeUnderShift:
    @pytest.mark.parametrize("cls", [FedAvgTrainer, FedGroupTrainer,
                                     IFCATrainer, FeSEMTrainer],
                             ids=lambda c: c.framework)
    def test_resume_is_bit_identical(self, cls, small_model, small_data,
                                     tmp_path):
        """A checkpoint written mid-shift (t=2, the swap round; FedGroup
        with a live detector and cached directions) restores into a fresh
        trainer that replays the remaining drift rounds bit-for-bit."""
        ref = _fresh_shifted(cls, small_model, small_data)
        h_ref = ref.run(4)
        ref.close()

        ck = dict(checkpoint_every=2, checkpoint_dir=str(tmp_path))
        killed = _fresh_shifted(cls, small_model, small_data, **ck)
        killed.run(3)
        killed.close()
        assert os.path.exists(ckpt_io.checkpoint_path(str(tmp_path), 2))

        resumed = _fresh_shifted(cls, small_model, small_data, **ck)
        assert resumed.load_checkpoint(str(tmp_path)) == 2
        h_res = resumed.run(2)
        resumed.close()

        assert h_res.rounds == h_ref.rounds
        _assert_tree_equal(resumed.params, ref.params)
        if hasattr(ref, "group_params"):
            _assert_tree_equal(resumed.group_params, ref.group_params)
            np.testing.assert_array_equal(resumed.membership, ref.membership)
        if getattr(ref, "local_flat", None) is not None:
            np.testing.assert_array_equal(np.asarray(resumed.local_flat),
                                          np.asarray(ref.local_flat))
        assert resumed.comm_params == ref.comm_params
        np.testing.assert_array_equal(np.asarray(resumed.key),
                                      np.asarray(ref.key))

    def test_pinned_fedgroup_detector_resume(self, small_model, small_data,
                                             tmp_path):
        """The detector's pinned-mode direction cache (trainer-owned lazy
        rows, checkpointed through the generic state hooks) survives
        kill-and-resume bit-identically too."""
        kw = dict(shift_threshold=0.35)
        ref = FedGroupTrainer(small_model, small_data, _cfg(**kw))
        h_ref = ref.run(4)

        ck = dict(checkpoint_every=2, checkpoint_dir=str(tmp_path), **kw)
        killed = FedGroupTrainer(small_model, small_data, _cfg(**ck))
        killed.run(3)
        resumed = FedGroupTrainer(small_model, small_data, _cfg(**ck))
        assert resumed.load_checkpoint(str(tmp_path)) == 2
        h_res = resumed.run(2)

        assert h_res.rounds == h_ref.rounds
        _assert_tree_equal(resumed.group_params, ref.group_params)
        np.testing.assert_array_equal(resumed.membership, ref.membership)
        np.testing.assert_array_equal(np.asarray(resumed.key),
                                      np.asarray(ref.key))

"""Clustering backends: K-Means++ (JAX) and hierarchical complete linkage."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.cluster import hierarchical, kmeans_inertia, kmeans_pp


def _blobs(seed, k=3, per=10, dim=4, sep=8.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (k, dim))
    centers *= sep / np.linalg.norm(centers, axis=1, keepdims=True)
    X = np.concatenate([c + rng.normal(0, 0.3, (per, dim)) for c in centers])
    y = np.repeat(np.arange(k), per)
    return X.astype(np.float32), y


def _purity(labels, truth):
    total = 0
    for lbl in np.unique(labels):
        members = truth[labels == lbl]
        total += np.bincount(members).max()
    return total / len(truth)


class TestKMeansPP:
    def test_recovers_blobs(self):
        X, y = _blobs(0)
        assign, centers = kmeans_pp(jax.random.PRNGKey(0), jnp.asarray(X), 3)
        assert _purity(np.asarray(assign), y) == 1.0

    def test_inertia_below_random(self):
        X, y = _blobs(1, k=4, per=12)
        assign, centers = kmeans_pp(jax.random.PRNGKey(1), jnp.asarray(X), 4)
        good = float(kmeans_inertia(jnp.asarray(X), assign, centers))
        rng = np.random.default_rng(0)
        rand_assign = jnp.asarray(rng.integers(0, 4, len(X)))
        rand_centers = jnp.asarray(rng.normal(0, 1, (4, X.shape[1])).astype(np.float32))
        bad = float(kmeans_inertia(jnp.asarray(X), rand_assign, rand_centers))
        assert good < bad / 5

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_all_clusters_valid(self, seed):
        X, _ = _blobs(seed, k=3, per=6)
        assign, _ = kmeans_pp(jax.random.PRNGKey(seed), jnp.asarray(X), 3)
        a = np.asarray(assign)
        assert a.min() >= 0 and a.max() < 3


class TestHierarchical:
    def test_recovers_blobs_from_distance(self):
        X, y = _blobs(2)
        D = np.linalg.norm(X[:, None] - X[None], axis=-1)
        labels = hierarchical(D, 3)
        assert _purity(labels, y) == 1.0

    def test_k_clusters(self):
        X, _ = _blobs(3, k=4, per=5)
        D = np.linalg.norm(X[:, None] - X[None], axis=-1)
        labels = hierarchical(D, 4)
        assert len(np.unique(labels)) == 4

    def test_trivial_k_equals_n(self):
        X, _ = _blobs(4, k=2, per=3)
        D = np.linalg.norm(X[:, None] - X[None], axis=-1)
        labels = hierarchical(D, len(X))
        assert len(np.unique(labels)) == len(X)

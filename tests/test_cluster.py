"""Clustering backends: K-Means++ (JAX) and hierarchical complete linkage."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.cluster import hierarchical, kmeans_inertia, kmeans_pp


def _blobs(seed, k=3, per=10, dim=4, sep=8.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (k, dim))
    centers *= sep / np.linalg.norm(centers, axis=1, keepdims=True)
    X = np.concatenate([c + rng.normal(0, 0.3, (per, dim)) for c in centers])
    y = np.repeat(np.arange(k), per)
    return X.astype(np.float32), y


def _purity(labels, truth):
    total = 0
    for lbl in np.unique(labels):
        members = truth[labels == lbl]
        total += np.bincount(members).max()
    return total / len(truth)


class TestKMeansPP:
    def test_recovers_blobs(self):
        X, y = _blobs(0)
        assign, centers = kmeans_pp(jax.random.PRNGKey(0), jnp.asarray(X), 3)
        assert _purity(np.asarray(assign), y) == 1.0

    def test_inertia_below_random(self):
        X, y = _blobs(1, k=4, per=12)
        assign, centers = kmeans_pp(jax.random.PRNGKey(1), jnp.asarray(X), 4)
        good = float(kmeans_inertia(jnp.asarray(X), assign, centers))
        rng = np.random.default_rng(0)
        rand_assign = jnp.asarray(rng.integers(0, 4, len(X)))
        rand_centers = jnp.asarray(rng.normal(0, 1, (4, X.shape[1])).astype(np.float32))
        bad = float(kmeans_inertia(jnp.asarray(X), rand_assign, rand_centers))
        assert good < bad / 5

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_all_clusters_valid(self, seed):
        X, _ = _blobs(seed, k=3, per=6)
        assign, _ = kmeans_pp(jax.random.PRNGKey(seed), jnp.asarray(X), 3)
        a = np.asarray(assign)
        assert a.min() >= 0 and a.max() < 3


class TestHierarchical:
    def test_recovers_blobs_from_distance(self):
        X, y = _blobs(2)
        D = np.linalg.norm(X[:, None] - X[None], axis=-1)
        labels = hierarchical(D, 3)
        assert _purity(labels, y) == 1.0

    def test_k_clusters(self):
        X, _ = _blobs(3, k=4, per=5)
        D = np.linalg.norm(X[:, None] - X[None], axis=-1)
        labels = hierarchical(D, 4)
        assert len(np.unique(labels)) == 4

    def test_trivial_k_equals_n(self):
        X, _ = _blobs(4, k=2, per=3)
        D = np.linalg.norm(X[:, None] - X[None], axis=-1)
        labels = hierarchical(D, len(X))
        assert len(np.unique(labels)) == len(X)


def _hierarchical_submatrix(proximity, k):
    """The retired implementation: rebuilds D[np.ix_(active, active)] on
    every merge (an extra O(n²) copy per step) — kept verbatim as the
    equivalence oracle for the masked-argmin rewrite."""
    D = np.array(proximity, dtype=np.float64, copy=True)
    n = D.shape[0]
    np.fill_diagonal(D, np.inf)
    active = list(range(n))
    members = {i: [i] for i in range(n)}
    while len(active) > k:
        sub = D[np.ix_(active, active)]
        flat = np.argmin(sub)
        ai, aj = np.unravel_index(flat, sub.shape)
        i, j = active[ai], active[aj]
        if j < i:
            i, j = j, i
        for other in active:
            if other in (i, j):
                continue
            D[i, other] = D[other, i] = max(D[i, other], D[j, other])
        members[i].extend(members.pop(j))
        active.remove(j)
    labels = np.zeros(n, dtype=np.int32)
    for lbl, root in enumerate(active):
        for idx in members[root]:
            labels[idx] = lbl
    return labels


class TestHierarchicalMaskedArgminEquivalence:
    """The masked-argmin rewrite (argmin over the full +inf-masked matrix,
    vectorized linkage update) must reproduce the submatrix version label
    for label — including under ties, where both argmin orders agree
    because the active set stays ascending."""

    @given(st.integers(0, 500))
    @settings(max_examples=25, deadline=None)
    def test_matches_submatrix_version(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 25))
        k = int(rng.integers(1, n))
        A = rng.random((n, n))
        D = (A + A.T) / 2
        if seed % 3 == 0:
            D = np.round(D, 1)          # quantize to force argmin ties
        np.fill_diagonal(D, 0)
        np.testing.assert_array_equal(hierarchical(D, k),
                                      _hierarchical_submatrix(D, k))

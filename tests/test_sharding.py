"""Sharding-spec sanity for every full architecture config (no mesh needed:
pure spec/rank/divisibility checks via eval_shape — no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry, shapes as shp
from repro.models import zoo
from repro.sharding import specs as sh

ARCHS = sorted(registry.ARCHS)


def _axis_sizes():
    return {"model": 16, "data": 16, "pod": 2}


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_rank_and_divisibility(arch):
    cfg = registry.get(arch)
    params = jax.eval_shape(lambda: zoo.init_params(jax.random.PRNGKey(0), cfg))
    spec_tree = sh.param_specs(params, cfg)
    sizes = _axis_sizes()

    leaves_p = jax.tree_util.tree_leaves(params)
    leaves_s = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    for leaf, spec in zip(leaves_p, leaves_s):
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
def test_fsdp_specs_divisible(arch):
    cfg = registry.get(arch)
    params = jax.eval_shape(lambda: zoo.init_params(jax.random.PRNGKey(0), cfg))
    spec_tree = sh.param_specs(params, cfg, fsdp_axis="data")
    sizes = _axis_sizes()
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(spec_tree,
                                      is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "granite-moe-1b-a400m"])
def test_moe_experts_sharded(arch):
    cfg = registry.get(arch)
    params = jax.eval_shape(lambda: zoo.init_params(jax.random.PRNGKey(0), cfg))
    spec_tree = sh.param_specs(params, cfg)
    moe_spec = spec_tree["blocks"]["moe"]["w_up"]
    # stacked layer dim None, then expert axis on "model"
    assert tuple(moe_spec) == (None, "model", None, None)


def test_decode_specs_window_shrinks_cache():
    cfg = registry.get("gemma-2b")
    long_cfg = shp.config_for(cfg, shp.SHAPES["long_500k"])
    assert long_cfg.window == shp.LONG_CONTEXT_WINDOW
    ins = shp.decode_specs(long_cfg, shp.SHAPES["long_500k"])
    assert ins["cache"]["k"].shape[2] == shp.LONG_CONTEXT_WINDOW
    full = shp.decode_specs(cfg, shp.SHAPES["decode_32k"])
    assert full["cache"]["k"].shape[2] == 32768


@pytest.mark.parametrize("shape_name", list(shp.SHAPES))
def test_supported_matrix(shape_name):
    """The 40-pair support matrix: only hubert decode shapes skip."""
    shape = shp.SHAPES[shape_name]
    for arch in ARCHS:
        ok, why = shp.supported(registry.get(arch), shape)
        if arch == "hubert-xlarge" and shape.kind == "decode":
            assert not ok
        else:
            assert ok, (arch, shape_name, why)


def test_batch_spec_replicates_indivisible():
    """long_500k (B=1) cannot shard over 16 data ways -> replicated."""
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    tree = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32),
            "big": jax.ShapeDtypeStruct((256, 8), jnp.float32)}
    specs = sh.data_specs(tree, FakeMesh())
    assert tuple(specs["tokens"]) == (None, None)
    # PartitionSpec normalizes a 1-tuple axis to the bare name
    assert specs["big"] == P(("data",), None) or specs["big"] == P("data", None)

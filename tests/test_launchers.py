"""Launcher CLIs (train/serve) exercised in-process with tiny settings."""
import os

import numpy as np
import pytest

from repro.launch import serve, train


class TestTrainCLI:
    def test_fed_mode(self, tmp_path, capsys):
        rc = train.main([
            "--mode", "fed", "--framework", "fedgroup", "--dataset",
            "synthetic", "--rounds", "2", "--k", "6", "--epochs", "2",
            "--groups", "2", "--alpha", "2", "--clients", "20",
            "--out", str(tmp_path)])
        assert rc == 0
        out = capsys.readouterr().out
        assert "max_acc=" in out
        assert os.path.exists(tmp_path / "model.npz")
        assert os.path.exists(tmp_path / "history.json")

    def test_lm_mode(self, tmp_path, capsys):
        rc = train.main([
            "--mode", "lm", "--arch", "gemma-2b", "--smoke", "--steps", "3",
            "--batch", "2", "--seq", "16", "--out", str(tmp_path)])
        assert rc == 0
        out = capsys.readouterr().out
        assert "loss=" in out
        assert os.path.exists(tmp_path / "state.npz")

    def test_fed_madc_measure(self, capsys):
        rc = train.main([
            "--mode", "fed", "--framework", "fedgroup", "--dataset",
            "synthetic", "--rounds", "1", "--k", "4", "--epochs", "1",
            "--groups", "2", "--alpha", "2", "--clients", "12",
            "--measure", "madc"])
        assert rc == 0


class TestServeCLI:
    def test_dense_decode(self, capsys):
        rc = serve.main(["--arch", "gemma-2b", "--smoke", "--batch", "2",
                         "--prompt-len", "4", "--gen", "4",
                         "--temperature", "0"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "tok/s" in out

    def test_windowed_decode(self, capsys):
        rc = serve.main(["--arch", "glm4-9b", "--smoke", "--batch", "1",
                         "--prompt-len", "4", "--gen", "4", "--window", "8"])
        assert rc == 0

    def test_encoder_only_refuses(self, capsys):
        rc = serve.main(["--arch", "hubert-xlarge", "--smoke"])
        assert rc == 1
        assert "encoder-only" in capsys.readouterr().out

"""Elastic coordinator/worker control plane: process-level fault domains,
heartbeat leases, and coordinator-owned recovery.

The load-bearing guarantees:

  * fleet-size-1 in-process mode is BIT-identical to ``engine.run()`` —
    History, params, group params, membership, local state, comm
    accounting and the rng stream — for all four frameworks, pinned and
    streamed. The control plane adds zero numerical surface.
  * recovery is bit-identical: a worker SIGKILLed (or hard-stopped)
    mid-dispatch is detected by missed heartbeats, its lease requeues
    with capped backoff, and the re-dispatched job produces the exact
    same run. Same for dropped / duplicated / reordered messages.
  * the fleet degrades gracefully down to one worker, adopts elastic
    newcomers mid-run, and a coordinator restart resumes bit-identically
    from the v4 checkpoint (fleet metadata riding along).
  * checkpoint integrity: per-array CRC32 checksums catch bit flips and
    torn archives at load (``CheckpointCorruptError``); pre-checksum v3
    archives still load; ``checkpoint_keep`` prunes old snapshots.
"""
import json
import os
import threading
import time
import zlib

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed import leases as leases_lib
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer
from repro.fed.population import (FaultConfig, FaultSpec, Population,
                                  PopulationConfig)
from repro.fed.store import ArrayClientStore
from repro.launch.coordinator import Coordinator, FleetConfig
from repro.launch.transport import (ChaosRouter, HeartbeatMonitor,
                                    InProcTransport, Message)
from repro.launch.worker import WorkerSpec, synthetic_builder


@pytest.fixture(scope="module")
def small_data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


@pytest.fixture(scope="module")
def small_model():
    from repro.models.paper_models import mclr
    return mclr(16, 10)


def _cfg(**kw):
    base = dict(n_rounds=4, clients_per_round=8, local_epochs=2,
                batch_size=5, lr=0.05, n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _assert_tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


STREAM_KW = dict(initial_active=30, arrival_rate=2.0, prefetch=2)


def _fresh(cls, model, data, streamed, **cfg_kw):
    cfg = _cfg(**cfg_kw)
    if streamed:
        pop = Population(ArrayClientStore(data),
                         PopulationConfig(**STREAM_KW))
        return cls(model, None, cfg, population=pop)
    return cls(model, data, cfg)


def _assert_same_run(fleet_tr, ref_tr, h_fleet, h_ref):
    """The full bit-identity surface: history, params, clustered state,
    local state, comm accounting, rng stream."""
    assert h_fleet.rounds == h_ref.rounds
    _assert_tree_equal(fleet_tr.params, ref_tr.params)
    if hasattr(ref_tr, "group_params"):
        _assert_tree_equal(fleet_tr.group_params, ref_tr.group_params)
        np.testing.assert_array_equal(fleet_tr.membership,
                                      ref_tr.membership)
    if getattr(ref_tr, "local_flat", None) is not None:
        np.testing.assert_array_equal(np.asarray(fleet_tr.local_flat),
                                      np.asarray(ref_tr.local_flat))
    assert fleet_tr.comm_params == ref_tr.comm_params
    np.testing.assert_array_equal(np.asarray(fleet_tr.key),
                                  np.asarray(ref_tr.key))


def _fleet_snap(tr):
    reg = tr.obs.registry
    return {k: reg.get(k) for k in reg.names("fleet.")}


ALL_TRAINERS = [FedAvgTrainer, FedGroupTrainer, IFCATrainer, FeSEMTrainer]

# chaos-friendly knobs: in-process workers answer in ms, so short backoffs
# keep the chaos tests fast (drop-chaos expiry is signalled, not wall-clock
# timed). The heartbeat window stays a generous 0.6s — a beat thread stalled
# behind a jit compile must never read as a spurious death.
FAST = dict(heartbeat_interval=0.02, heartbeat_miss=30,
            backoff=0.005, backoff_cap=0.02)


# ---------------------------------------------------------------------------
# lease primitives (fed/leases.py)
# ---------------------------------------------------------------------------
class TestLeasePrimitives:
    def test_backoff_is_capped_exponential(self):
        assert leases_lib.backoff_delay(0, 0.05, 1.0) == 0.05
        assert leases_lib.backoff_delay(1, 0.05, 1.0) == 0.1
        assert leases_lib.backoff_delay(10, 0.05, 1.0) == 1.0

    def test_requeue_buffer_fifo_among_ready(self):
        buf = leases_lib.RequeueBuffer()
        pol = leases_lib.RetryPolicy(timeout=1.0, max_retries=5,
                                     backoff=0.0, backoff_cap=0.0)
        for staged in ("a", "b"):
            buf.push(leases_lib.Lease(staged=staged), pol, now=0.0)
        assert len(buf) == 2
        assert buf.pop_ready(0.0) == ("a", 1)      # FIFO among ready
        assert buf.pop_ready(0.0) == ("b", 1)
        assert buf.pop_ready(0.0) is None
        assert buf.earliest() is None

    def test_backoff_delays_readiness(self):
        buf = leases_lib.RequeueBuffer()
        pol = leases_lib.RetryPolicy(backoff=0.5, backoff_cap=10.0)
        buf.push(leases_lib.Lease(staged="x", attempts=1), pol, now=0.0)
        assert buf.pop_ready(0.9) is None          # 0.5 * 2^1 = 1.0
        assert buf.earliest() == 1.0
        assert buf.pop_ready(1.0) == ("x", 2)

    def test_exhausted_budget_raises_with_callers_key_names(self):
        buf = leases_lib.RequeueBuffer()
        pol = leases_lib.RetryPolicy(timeout=2.0, max_retries=1)
        lease = leases_lib.Lease(staged="x", attempts=1)
        with pytest.raises(RuntimeError, match=r"fleet job lease expired "
                           r".*lease_timeout=2.0s.*max_retries=1.*"
                           r"unrecoverable"):
            buf.push(lease, pol, now=0.0, what="fleet job",
                     timeout_key="lease_timeout", retries_key="max_retries")
        # the engine's default keys are unchanged
        with pytest.raises(RuntimeError, match="async_lease_timeout"):
            buf.push(leases_lib.Lease(staged="y", attempts=1), pol, now=0.0)


# ---------------------------------------------------------------------------
# heartbeat failure detection
# ---------------------------------------------------------------------------
class TestHeartbeatMonitor:
    def test_miss_threshold_and_resurrection(self):
        m = HeartbeatMonitor(interval=1.0, miss=3)
        m.add("w0", now=0.0)
        assert m.sweep(2.9) == []                  # inside the window
        assert m.sweep(3.1) == ["w0"]              # 3 missed beats: dead
        assert m.sweep(3.2) == []                  # declared only once
        assert m.is_dead("w0")
        assert m.beat("w0", 3.3) is True           # late beat resurrects
        assert not m.is_dead("w0")
        assert m.sweep(3.4) == []

    def test_beat_from_unknown_worker_is_ignored(self):
        m = HeartbeatMonitor(interval=1.0, miss=3)
        assert m.beat("ghost", 0.0) is False
        assert m.sweep(100.0) == []

    def test_removed_worker_never_declared(self):
        m = HeartbeatMonitor(interval=1.0, miss=2)
        m.add("w0", 0.0)
        m.remove("w0")
        assert m.sweep(100.0) == []
        assert m.beat("w0", 100.0) is False        # departed, not dead


# ---------------------------------------------------------------------------
# scripted delivery chaos
# ---------------------------------------------------------------------------
class TestChaosRouter:
    def test_drop_consumes_and_signals(self):
        c = ChaosRouter()
        c.arm(FaultSpec(msg_drop=True), job_id=7)
        out = c.filter(Message("result", "w0", 7, "payload"), now=0.0)
        assert out == [] and 7 in c.dropped
        # only that one delivery: a re-dispatched job 8 passes through
        out = c.filter(Message("result", "w0", 8, "payload"), now=0.0)
        assert [m.job_id for m in out] == [8]

    def test_dup_delivers_twice(self):
        c = ChaosRouter()
        c.arm(FaultSpec(msg_dup=True), job_id=3)
        out = c.filter(Message("result", "w0", 3, "p"), now=0.0)
        assert [m.job_id for m in out] == [3, 3]

    def test_reorder_holds_until_next_message_passes(self):
        c = ChaosRouter()
        c.arm(FaultSpec(msg_reorder=True), job_id=5)
        assert c.filter(Message("result", "w0", 5, "p"), now=0.0) == []
        out = c.filter(Message("heartbeat", "w1"), now=0.0)
        assert [(m.kind, m.job_id) for m in out] == \
            [("heartbeat", -1), ("result", 5)]

    def test_heartbeat_mute_until_deadline(self):
        c = ChaosRouter()
        c.mute_heartbeats("w0", until=1.0)
        assert c.filter(Message("heartbeat", "w0"), now=0.5) == []
        assert len(c.filter(Message("heartbeat", "w0"), now=1.5)) == 1
        # the mute is consumed: later beats flow
        assert len(c.filter(Message("heartbeat", "w0"), now=1.6)) == 1


class TestInProcTransport:
    def test_roundtrip_and_unknown_worker(self):
        tr = InProcTransport()
        ep = tr.add_worker("w0")
        assert tr.send("w0", Message("job", job_id=1)) is True
        assert ep.recv(0.1).job_id == 1
        ep.send(Message("result", "w0", 1, "r"))
        assert tr.recv(0.1).payload == "r"
        assert tr.recv(0.01) is None
        tr.remove_worker("w0")
        assert tr.send("w0", Message("job")) is False
        with pytest.raises(ValueError, match="already registered"):
            tr.add_worker("w0"), tr.add_worker("w0")


# ---------------------------------------------------------------------------
# fleet-size-1 bit-identity (the tentpole equivalence anchor)
# ---------------------------------------------------------------------------
class TestFleetOneBitIdentity:
    @pytest.mark.parametrize("streamed", [False, True],
                             ids=["pinned", "streamed"])
    @pytest.mark.parametrize("cls", ALL_TRAINERS,
                             ids=lambda c: c.framework)
    def test_fleet_of_one_equals_engine_run(self, cls, streamed,
                                            small_model, small_data):
        ref = _fresh(cls, small_model, small_data, streamed)
        h_ref = ref.run()
        ref.close()

        tr = _fresh(cls, small_model, small_data, streamed)
        coord = Coordinator(tr, FleetConfig(n_workers=1))
        h = coord.run()
        snap = _fleet_snap(tr)
        coord.close()

        _assert_same_run(tr, ref, h, h_ref)
        assert snap["fleet.jobs"] == snap["fleet.results"] > 0
        assert snap["fleet.heartbeats"] > 0

    def test_async_path_routes_through_fleet(self, small_model, small_data):
        tr = FedAvgTrainer(small_model, small_data,
                           _cfg(async_depth=2, async_alpha=0.5))
        coord = Coordinator(tr, FleetConfig(n_workers=1))
        h = coord.run()
        snap = _fleet_snap(tr)
        coord.close()
        assert len(h.rounds) == 4
        assert all(np.isfinite(np.asarray(leaf)).all()
                   for leaf in jax.tree_util.tree_leaves(tr.params))
        assert snap["fleet.jobs"] >= 4          # async dispatches routed

    def test_rejects_unknown_transport(self, small_model, small_data):
        tr = FedAvgTrainer(small_model, small_data, _cfg())
        with pytest.raises(ValueError, match="unknown fleet transport"):
            Coordinator(tr, FleetConfig(transport="carrier-pigeon"))
        tr.close()


# ---------------------------------------------------------------------------
# chaos recovery (in-process fault domains)
# ---------------------------------------------------------------------------
class TestChaosRecovery:
    def _ref(self, small_model, small_data, n_rounds=6):
        ref = _fresh(FedAvgTrainer, small_model, small_data, False,
                     n_rounds=n_rounds)
        h_ref = ref.run()
        ref.close()
        return ref, h_ref

    def test_worker_kill_recovers_bit_identically(self, small_model,
                                                  small_data):
        ref, h_ref = self._ref(small_model, small_data)
        faults = FaultConfig(rounds={1: FaultSpec(worker_kill=True)})
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    n_rounds=6)
        coord = Coordinator(tr, FleetConfig(n_workers=2, faults=faults,
                                            **FAST))
        h = coord.run()
        snap = _fleet_snap(tr)
        coord.close()
        _assert_same_run(tr, ref, h, h_ref)
        assert snap["fleet.worker_deaths"] == 1
        assert snap["fleet.lease_expiries"] >= 1
        assert snap["fleet.requeues"] >= 1
        assert snap["fleet.workers"] == 1       # degraded, still finished

    def test_message_chaos_is_bit_identical(self, small_model, small_data):
        # drop, duplicate and reorder the result message on three
        # different rounds of one run: every delivery fault is absorbed
        ref, h_ref = self._ref(small_model, small_data)
        faults = FaultConfig(rounds={1: FaultSpec(msg_drop=True),
                                     2: FaultSpec(msg_dup=True),
                                     3: FaultSpec(msg_reorder=True)})
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    n_rounds=6)
        coord = Coordinator(tr, FleetConfig(n_workers=2, faults=faults,
                                            **FAST))
        h = coord.run()
        snap = _fleet_snap(tr)
        coord.close()
        _assert_same_run(tr, ref, h, h_ref)
        assert snap["fleet.msgs_dropped"] == 1
        assert snap["fleet.msgs_duplicated"] == 1
        assert snap["fleet.msgs_reordered"] == 1
        assert snap["fleet.requeues"] == 1      # only the drop requeues
        assert snap["fleet.stale_results"] >= 1  # the dup's second copy

    def test_heartbeat_delay_death_and_resurrection(self, small_model,
                                                    small_data):
        # mute a healthy worker's beats past the miss window while it
        # works a (stalled) job: it is declared dead, the lease requeues
        # to the survivor, then the worker's first unmuted beat resurrects
        # it — and the run is still bit-identical
        ref, h_ref = self._ref(small_model, small_data)
        faults = FaultConfig(rounds={1: FaultSpec(heartbeat_delay=1.2)})
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    n_rounds=6)
        coord = Coordinator(tr, FleetConfig(n_workers=2, faults=faults,
                                            **FAST))
        real = coord._table["round"]
        calls = []

        def stall_second_call(*args):
            calls.append(1)
            if len(calls) == 2:         # the muted worker's job: outlive
                time.sleep(0.9)         # the 0.6s miss window
            return real(*args)

        coord._table["round"] = stall_second_call
        h = coord.run()
        snap = _fleet_snap(tr)
        # the muted worker is healthy: once the mute lapses its next beat
        # must resurrect it
        deadline = time.monotonic() + 3.0
        while len(coord._live) < 2 and time.monotonic() < deadline:
            coord._pump(0.02)
        resurrected = len(coord._live)
        joins = tr.obs.registry.get("fleet.joins")
        coord.close()
        _assert_same_run(tr, ref, h, h_ref)
        assert snap["fleet.worker_deaths"] == 1
        assert snap["fleet.heartbeat_misses"] == 1
        assert snap["fleet.requeues"] >= 1
        assert resurrected == 2 and joins == 3  # w0, w1, 1 resurrection

    def test_elastic_join_and_leave(self, small_model, small_data):
        ref, h_ref = self._ref(small_model, small_data)
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    n_rounds=6)
        coord = Coordinator(tr, FleetConfig(
            n_workers=1, joins={2: ["newcomer"]}, leaves={4: ["w0"]},
            **FAST))
        h = coord.run()
        snap = _fleet_snap(tr)
        coord.close()
        _assert_same_run(tr, ref, h, h_ref)
        assert snap["fleet.joins"] == 2         # w0 + the newcomer
        assert snap["fleet.leaves"] == 1
        assert snap["fleet.workers"] == 1       # only the newcomer left

    def test_lease_timeout_requeues_to_next_worker(self, small_model,
                                                   small_data):
        # a worker that stalls (but does not die) past the lease deadline:
        # the lease expires, requeues, and the re-dispatched job lands on
        # the other worker — run still bit-identical
        ref, h_ref = self._ref(small_model, small_data, n_rounds=2)
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    n_rounds=2)
        coord = Coordinator(tr, FleetConfig(n_workers=2, lease_timeout=0.4,
                                            **FAST))
        real = coord._table["round"]
        stalled = threading.Event()

        def stall_once(*args):
            if not stalled.is_set():
                stalled.set()
                time.sleep(1.2)             # > lease_timeout: expires
            return real(*args)

        coord._table["round"] = stall_once
        h = coord.run()
        snap = _fleet_snap(tr)
        coord.close()
        _assert_same_run(tr, ref, h, h_ref)
        assert snap["fleet.lease_expiries"] >= 1
        assert snap["fleet.requeues"] >= 1

    def test_unrecoverable_job_raises_with_fleet_keys(self, small_model,
                                                      small_data):
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    n_rounds=2)
        coord = Coordinator(tr, FleetConfig(n_workers=1, lease_timeout=0.1,
                                            max_retries=1, **FAST))
        coord._table["round"] = lambda *a: time.sleep(5.0)
        with pytest.raises(RuntimeError, match=r"fleet job lease expired"
                           r".*lease_timeout=0.1s.*max_retries=1"):
            coord.run()
        coord.close()

    def test_worker_exception_surfaces_with_traceback(self, small_model,
                                                      small_data):
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    n_rounds=2)
        coord = Coordinator(tr, FleetConfig(n_workers=1, **FAST))

        def boom(*args):
            raise ValueError("kaboom in the executor")

        coord._table["round"] = boom
        with pytest.raises(RuntimeError,
                           match=r"(?s)failed job 0.*kaboom in the executor"):
            coord.run()
        coord.close()


# ---------------------------------------------------------------------------
# coordinator restart: kill-and-resume through the control plane
# ---------------------------------------------------------------------------
class TestCoordinatorRestart:
    def test_restart_resumes_bit_identically(self, small_model, small_data,
                                             tmp_path):
        ref = _fresh(FedGroupTrainer, small_model, small_data, True)
        h_ref = ref.run(4)
        ref.close()

        ck = dict(checkpoint_every=2, checkpoint_dir=str(tmp_path))
        killed = _fresh(FedGroupTrainer, small_model, small_data, True,
                        **ck)
        c1 = Coordinator(killed, FleetConfig(n_workers=2, **FAST))
        c1.run(3)                          # "killed" after 3 rounds
        c1.close()
        path = ckpt_io.checkpoint_path(str(tmp_path), 2)
        assert os.path.exists(path)
        # the v4 archive carries the control-plane snapshot
        fm = ckpt_io.load_metadata(path)["fleet"]
        assert fm["transport"] == "inproc"
        assert fm["n_workers"] == 2 and len(fm["live"]) == 2
        assert fm["dispatch_clock"] >= 2

        resumed = _fresh(FedGroupTrainer, small_model, small_data, True,
                         **ck)
        c2 = Coordinator(resumed, FleetConfig(n_workers=2, **FAST))
        t = c2.load_checkpoint(str(tmp_path))      # dir -> latest ckpt
        assert t == 2
        assert c2._clock == fm["dispatch_clock"]   # script clock resumes
        h_res = c2.run(4 - t)
        c2.close()

        assert h_res.rounds == h_ref.rounds
        _assert_tree_equal(resumed.group_params, ref.group_params)
        np.testing.assert_array_equal(resumed.membership, ref.membership)
        assert resumed.comm_params == ref.comm_params
        np.testing.assert_array_equal(np.asarray(resumed.key),
                                      np.asarray(ref.key))

    def test_plain_trainer_reads_fleet_checkpoint(self, small_model,
                                                  small_data, tmp_path):
        # a fleet-run checkpoint restores into a coordinator-less trainer:
        # the fleet metadata and metric snapshot ride along harmlessly
        tr = _fresh(FedAvgTrainer, small_model, small_data, False)
        coord = Coordinator(tr, FleetConfig(n_workers=1))
        coord.run(2)
        path = coord.save_checkpoint(str(tmp_path / "ck.npz"))
        coord.close()

        solo = _fresh(FedAvgTrainer, small_model, small_data, False)
        assert solo.load_checkpoint(path) == 2
        solo.run(1)
        assert len(solo.history.rounds) == 3
        solo.close()


# ---------------------------------------------------------------------------
# process-level fault domains (spawned workers, SIGKILL chaos)
# ---------------------------------------------------------------------------
PROC_KW = dict(framework="fedavg", n_clients=20, dim=8, seed=0, n_rounds=3,
               clients_per_round=6)


@pytest.mark.fleet
class TestProcFleet:
    def test_sigkill_mid_dispatch_recovers_bit_identically(self):
        # the real thing: two spawned worker processes, one SIGKILLed
        # while it holds round 1's lease; the closed pipe / missed
        # heartbeats detect it, the lease requeues to the survivor, and
        # the run completes bit-identical to a single-process run
        ref = synthetic_builder(**PROC_KW)
        h_ref = ref.run()
        ref.close()

        tr = synthetic_builder(**PROC_KW)
        coord = Coordinator(tr, FleetConfig(
            n_workers=2, transport="proc",
            worker_spec=WorkerSpec("repro.launch.worker:synthetic_builder",
                                   PROC_KW),
            faults=FaultConfig(rounds={1: FaultSpec(worker_kill=True)}),
            heartbeat_interval=0.1, heartbeat_miss=5,
            lease_timeout=300.0, join_timeout=300.0))
        h = coord.run()
        snap = _fleet_snap(tr)
        coord.close()

        _assert_same_run(tr, ref, h, h_ref)
        assert snap["fleet.worker_deaths"] == 1
        assert snap["fleet.requeues"] >= 1
        assert snap["fleet.workers"] == 1

    def test_proc_mode_validates_its_limits(self, small_model, small_data):
        spec = WorkerSpec("repro.launch.worker:synthetic_builder", PROC_KW)
        pinned = _fresh(FedAvgTrainer, small_model, small_data, False)
        with pytest.raises(ValueError,
                           match="needs FleetConfig.worker_spec"):
            Coordinator(pinned, FleetConfig(transport="proc"))
        pinned.close()
        streamed = _fresh(FedAvgTrainer, small_model, small_data, True)
        with pytest.raises(ValueError, match="pinned trainers only"):
            Coordinator(streamed,
                        FleetConfig(transport="proc", worker_spec=spec))
        streamed.close()
        asy = _fresh(FedAvgTrainer, small_model, small_data, False,
                     async_depth=2)
        with pytest.raises(ValueError, match="per-round path only"):
            Coordinator(asy,
                        FleetConfig(transport="proc", worker_spec=spec))
        asy.close()

    def test_bad_builder_spec_is_rejected(self):
        from repro.launch.worker import resolve_builder
        with pytest.raises(ValueError, match="module:function"):
            resolve_builder(WorkerSpec("no_colon_here"))


# ---------------------------------------------------------------------------
# checkpoint integrity (satellites: CRC32, retention, v3 compat)
# ---------------------------------------------------------------------------
class TestCheckpointIntegrity:
    def test_bit_flip_raises_corrupt_error(self, tmp_path):
        # a stored array whose bytes no longer match the save-time CRC32
        # table must fail loudly, never restore garbage
        path = str(tmp_path / "ck.npz")
        arr = np.arange(8, dtype=np.float32)
        meta = {ckpt_io._FORMAT_KEY: ckpt_io.CKPT_FORMAT_VERSION,
                ckpt_io._CRC_KEY: {"a": zlib.crc32(arr.tobytes()) ^ 0xFF}}
        with open(path, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), a=arr)
        with pytest.raises(ckpt_io.CheckpointCorruptError,
                           match="failed its CRC32"):
            ckpt_io.load_pytree(path, {"a": arr})

    def test_truncated_archive_raises_corrupt_error(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        ckpt_io.save_pytree(path, {"a": np.arange(64, dtype=np.float32)})
        raw = open(path, "rb").read()
        open(path, "wb").write(raw[:len(raw) // 2])
        with pytest.raises(ckpt_io.CheckpointCorruptError):
            ckpt_io.load_pytree(path, {"a": np.zeros(64, np.float32)})

    def test_intact_roundtrip_and_crc_is_internal(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        tree = {"a": np.arange(4.0), "b": np.ones((2, 3))}
        ckpt_io.save_pytree(path, tree, {"note": "x"})
        _assert_tree_equal(ckpt_io.load_pytree(path, tree), tree)
        # the checksum table never leaks into user metadata
        assert ckpt_io.load_metadata(path) == {"note": "x"}

    def test_pre_checksum_v3_archive_still_loads(self, tmp_path):
        path = str(tmp_path / "old.npz")
        arr = np.arange(8, dtype=np.float32)
        meta = {ckpt_io._FORMAT_KEY: 3}      # v3: no __crc__ table
        with open(path, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), a=arr)
        _assert_tree_equal(ckpt_io.load_pytree(path, {"a": arr}),
                           {"a": arr})

    def test_prune_keeps_newest_n(self, tmp_path):
        for t in (2, 4, 6, 8):
            ckpt_io.save_pytree(ckpt_io.checkpoint_path(str(tmp_path), t),
                                {"a": np.zeros(2)})
        keeper = str(tmp_path / "notes.txt")
        open(keeper, "w").write("not a checkpoint")
        removed = ckpt_io.prune_checkpoints(str(tmp_path), keep=2)
        assert sorted(os.path.basename(p) for p in removed) == \
            ["ckpt_00000002.npz", "ckpt_00000004.npz"]
        assert os.path.exists(ckpt_io.checkpoint_path(str(tmp_path), 8))
        assert os.path.exists(keeper)        # non-checkpoints untouched
        assert ckpt_io.prune_checkpoints(str(tmp_path), keep=0) == []

    def test_checkpoint_keep_prunes_during_run(self, small_model,
                                               small_data, tmp_path):
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    checkpoint_every=1, checkpoint_dir=str(tmp_path),
                    checkpoint_keep=2)
        tr.run(4)
        tr.close()
        names = sorted(p.name for p in tmp_path.glob("ckpt_*.npz"))
        assert names == ["ckpt_00000003.npz", "ckpt_00000004.npz"]
        # the survivor restores fine
        resumed = _fresh(FedAvgTrainer, small_model, small_data, False,
                         checkpoint_every=1, checkpoint_dir=str(tmp_path),
                         checkpoint_keep=2)
        assert resumed.load_checkpoint(str(tmp_path)) == 4
        resumed.close()


# ---------------------------------------------------------------------------
# quarantine edge case (satellite: all-screened round = identity fold)
# ---------------------------------------------------------------------------
class TestEmptyFold:
    def test_all_screened_round_is_identity_passthrough(self, small_model,
                                                        small_data):
        faults = FaultConfig(
            rounds={1: FaultSpec(corrupt=8, corrupt_mode="nan")})
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(faults=faults, **STREAM_KW))
        tr = FedGroupTrainer(small_model, None,
                             _cfg(quarantine=True), population=pop)
        tr.run(1)
        before = jax.tree_util.tree_map(
            lambda a: np.asarray(a).copy(), tr.group_params)
        h = tr.run(1)                        # round 1: whole cohort NaN
        after = jax.tree_util.tree_map(np.asarray, tr.group_params)
        assert h.rounds[1].quarantined == 8  # every lane screened
        _assert_tree_equal(after, before)    # fold was the identity
        assert tr.obs.registry.get("rounds.empty_folds") == 1
        h2 = tr.run(2)                       # healthy rounds keep training
        assert tr.obs.registry.get("rounds.empty_folds") == 1
        assert h2.rounds[2].quarantined == 0
        tr.close()

"""Mesh-parallel FedGroup engine (fed/parallel.py): the vectorized round and
distributed cold-start must agree with the sequential trainer machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed import parallel as fp
from repro.models.paper_models import mclr


class TestParallelRound:
    def _setup(self, K=8, max_n=20, dim=6, m=3):
        key = jax.random.PRNGKey(0)
        model = mclr(dim, 4)
        params = model.init(key)
        gp = jax.tree_util.tree_map(
            lambda l: jnp.stack([l + 0.01 * i for i in range(m)]), params)
        ks = jax.random.split(key, 5)
        X = jax.random.normal(ks[0], (K, max_n, dim))
        Y = jax.random.randint(ks[1], (K, max_n), 0, 4)
        n = jnp.full((K,), max_n, jnp.int32)
        membership = jnp.asarray([i % m for i in range(K)])
        keys = jax.random.split(ks[2], K)
        return model, gp, membership, X, Y, n, keys, m

    def test_round_shapes_and_finiteness(self):
        model, gp, mem, X, Y, n, keys, m = self._setup()
        rf = fp.make_parallel_round(model, epochs=2, batch_size=5, lr=0.05,
                                    mu=0.0, n_groups=m, max_samples=20)
        new_gp, global_p, deltas = jax.jit(rf)(gp, mem, X, Y, n, keys)
        for leaf in jax.tree_util.tree_leaves(new_gp):
            assert leaf.shape[0] == m
            assert np.all(np.isfinite(np.asarray(leaf)))
        for leaf in jax.tree_util.tree_leaves(global_p):
            assert np.all(np.isfinite(np.asarray(leaf)))

    def test_groups_move_independently(self):
        """Clients of group j only influence group j's parameters."""
        model, gp, mem, X, Y, n, keys, m = self._setup()
        rf = fp.make_parallel_round(model, epochs=2, batch_size=5, lr=0.05,
                                    mu=0.0, n_groups=m, max_samples=20)
        new1, _, _ = rf(gp, mem, X, Y, n, keys)
        # perturb ONLY group-0 clients' data
        X2 = X.at[0].add(10.0)
        new2, _, _ = rf(gp, mem, X2, Y, n, keys)
        w1 = np.asarray(new1["w"])
        w2 = np.asarray(new2["w"])
        assert not np.allclose(w1[0], w2[0])          # group 0 changed
        np.testing.assert_allclose(w1[1], w2[1])      # group 1 untouched
        np.testing.assert_allclose(w1[2], w2[2])

    def test_global_is_group_mean(self):
        model, gp, mem, X, Y, n, keys, m = self._setup()
        rf = fp.make_parallel_round(model, epochs=1, batch_size=5, lr=0.05,
                                    mu=0.0, n_groups=m, max_samples=20)
        new_gp, global_p, _ = rf(gp, mem, X, Y, n, keys)
        want = jax.tree_util.tree_map(lambda g: jnp.mean(g, 0), new_gp)
        for a, b in zip(jax.tree_util.tree_leaves(global_p),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

    def test_empty_group_unchanged(self):
        model, gp, mem, X, Y, n, keys, m = self._setup()
        mem = jnp.zeros_like(mem)                     # everyone in group 0
        rf = fp.make_parallel_round(model, epochs=1, batch_size=5, lr=0.05,
                                    mu=0.0, n_groups=m, max_samples=20)
        new_gp, _, _ = rf(gp, mem, X, Y, n, keys)
        np.testing.assert_allclose(np.asarray(new_gp["w"][1]),
                                   np.asarray(gp["w"][1]))
        assert not np.allclose(np.asarray(new_gp["w"][0]),
                               np.asarray(gp["w"][0]))

    def test_proximal_term_shrinks_delta(self):
        model, gp, mem, X, Y, n, keys, m = self._setup()
        plain = fp.make_parallel_round(model, epochs=3, batch_size=5, lr=0.1,
                                       mu=0.0, n_groups=m, max_samples=20)
        prox = fp.make_parallel_round(model, epochs=3, batch_size=5, lr=0.1,
                                      mu=1.0, n_groups=m, max_samples=20)
        _, _, d0 = plain(gp, mem, X, Y, n, keys)
        _, _, d1 = prox(gp, mem, X, Y, n, keys)
        n0 = float(sum(jnp.sum(jnp.square(l))
                       for l in jax.tree_util.tree_leaves(d0)))
        n1 = float(sum(jnp.sum(jnp.square(l))
                       for l in jax.tree_util.tree_leaves(d1)))
        assert n1 < n0


class TestDistributedColdStart:
    def test_kmeans_step_reduces_inertia(self):
        key = jax.random.PRNGKey(1)
        E = jnp.concatenate([jax.random.normal(key, (10, 3)) + 4,
                             jax.random.normal(jax.random.fold_in(key, 1),
                                               (10, 3)) - 4])
        centers = E[:2]
        def inertia(c):
            d2 = jnp.sum(jnp.square(E[:, None] - c[None]), -1)
            return float(jnp.sum(jnp.min(d2, 1)))
        i0 = inertia(centers)
        for _ in range(5):
            assign, centers = fp.kmeans_step(E, centers)
        assert inertia(centers) < i0

    def test_full_coldstart_pipeline_recovers_clusters(self):
        key = jax.random.PRNGKey(2)
        dirs = jax.random.normal(key, (3, 500))
        dW = jnp.concatenate([
            dirs[i] + 0.05 * jax.random.normal(jax.random.fold_in(key, i),
                                               (8, 500)) for i in range(3)])
        E, V = fp.edc_embedding_distributed(dW, 3, key=key,
                                            qr_impl="cholesky")
        centers = E[jnp.asarray([0, 8, 16])]
        for _ in range(10):
            assign, centers = fp.kmeans_step(E, centers)
        a = np.asarray(assign)
        # each true cluster maps to a single label
        for g in range(3):
            block = a[g * 8:(g + 1) * 8]
            assert len(np.unique(block)) == 1
        assert len(np.unique(a)) == 3

"""Staleness-aware async runtime: weight math, the D=1 equivalence mode
(bit-identical to the synchronous paths, pinned and streamed), depth > 1
degradation accounting, cohort leases (expiry / requeue / retry cap),
mid-async kill-and-resume, checkpoint format versioning, the bounded-retry
state writer, and the Population.stats lifecycle.

The load-bearing guarantees:

  * ``async_depth=1`` with ``async_alpha=1, async_beta=0`` is BIT-identical
    to the synchronous engine for all four frameworks — same History, same
    parameters, same rng stream, same communication accounting — pinned
    (vs the scan-fused block path) and streamed (vs the per-round path).
  * at depth > 1 every fold is staleness-weighted per group
    (w = α·(s+1)^-β on the per-group version clocks) and the degradation
    record (dispatches / folds / max_in_flight / staleness histogram /
    lease expiries / requeues) surfaces in ``History.async_stats``.
  * an expired cohort lease is requeued with capped exponential backoff
    and its re-dispatch folds as a LATER round; ``async_max_retries``
    bounds the retries with a clear error.
  * a checkpoint cadence crossing drains the in-flight window first, so
    kill-and-resume mid-async replays bit-identically.
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed import rounds as rounds_lib
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer
from repro.fed.population import (FaultConfig, FaultSpec, Population,
                                  PopulationConfig, _AsyncStateWriter)
from repro.fed.store import ArrayClientStore

N_CLIENTS = 40
ALL_TRAINERS = [FedAvgTrainer, FedGroupTrainer, IFCATrainer, FeSEMTrainer]
STREAM_KW = dict(initial_active=30, arrival_rate=2.0, prefetch=2)


@pytest.fixture(scope="module")
def small_data():
    return mnist_like(seed=0, n_clients=N_CLIENTS, classes_per_client=2,
                      total_train=2000, dim=16)


@pytest.fixture(scope="module")
def small_model():
    from repro.models.paper_models import mclr
    return mclr(16, 10)


def _cfg(**kw):
    base = dict(n_rounds=4, clients_per_round=8, local_epochs=2,
                batch_size=5, lr=0.05, n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _fresh(cls, model, data, streamed, **cfg_kw):
    cfg = _cfg(**cfg_kw)
    if streamed:
        pop = Population(ArrayClientStore(data),
                         PopulationConfig(**STREAM_KW))
        return cls(model, None, cfg, population=pop)
    return cls(model, data, cfg)


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _tree_finite(tree) -> bool:
    return all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(tree))


def _state(tr) -> dict:
    """Everything the D=1 equivalence mode must reproduce bit-for-bit."""
    s = {"params": tr.params, "key": tr.key,
         "comm": np.asarray(tr.comm_params)}
    mem = getattr(tr, "membership", None)
    if mem is not None:
        s["membership"] = np.array(mem)
    for name in ("group_params", "group_delta", "local_flat"):
        v = getattr(tr, name, None)
        if v is not None:
            s[name] = v
    if tr.population is not None and isinstance(tr, FeSEMTrainer):
        s["local_flat"] = np.asarray(
            tr.population.gather_local_flat(np.arange(N_CLIENTS)))
    return s


def _bitwise_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.tobytes() == b.tobytes()


# ---------------------------------------------------------------------------
# FedAsync mixing weight w = alpha * (s + 1)^(-beta)
# ---------------------------------------------------------------------------
class TestStalenessWeight:
    def test_zero_staleness_is_exactly_alpha(self):
        for alpha in (1.0, 0.8, 0.25):
            for beta in (0.0, 0.5, 2.0):
                w = rounds_lib.staleness_weight(np.zeros(3, np.int64),
                                                alpha=alpha, beta=beta)
                np.testing.assert_array_equal(w, np.float32(alpha))

    def test_monotone_non_increasing_in_staleness(self):
        s = np.arange(0, 16, dtype=np.int64)
        for beta in (0.0, 0.3, 1.0, 4.0):
            w = rounds_lib.staleness_weight(s, alpha=0.9, beta=beta)
            assert (np.diff(w) <= 0).all()
            assert (w > 0).all()

    def test_equivalence_mode_is_exactly_one(self):
        # alpha=1, beta=0: the D=1 passthrough mode — EXACTLY 1.0, every s
        w = rounds_lib.staleness_weight(np.array([0, 1, 7, 1000]),
                                        alpha=1.0, beta=0.0)
        assert w.dtype == np.float32
        np.testing.assert_array_equal(w, np.ones(4, np.float32))

    def test_negative_staleness_raises(self):
        with pytest.raises(ValueError, match="negative staleness"):
            rounds_lib.staleness_weight(np.array([0, -1]))


# ---------------------------------------------------------------------------
# fold math: w == 1 is a bitwise passthrough, w < 1 a convex mix
# ---------------------------------------------------------------------------
class TestFoldMath:
    def test_param_fold_weight_one_is_bitwise_passthrough(self):
        # 0*cur + 1*res is NOT bit-exact when cur holds -0.0 / inf / nan —
        # the fold must select, not mix
        fold = rounds_lib.make_param_fold()
        cur = {"w": jnp.asarray([[-0.0, np.inf], [np.nan, 1.0]],
                                jnp.float32)}
        res = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.float32)}
        res_g = {"w": jnp.asarray([2.0, 3.0], jnp.float32)}
        groups, glob = fold(cur, res, res_g, jnp.ones(2, jnp.float32))
        assert _bitwise_equal(groups["w"], res["w"])
        assert _bitwise_equal(glob["w"], res_g["w"])

    def test_param_fold_half_weight_mix_and_global_mean(self):
        fold = rounds_lib.make_param_fold()
        cur = {"w": jnp.asarray([[0.0, 4.0], [2.0, 2.0]], jnp.float32)}
        res = {"w": jnp.asarray([[2.0, 0.0], [4.0, 6.0]], jnp.float32)}
        res_g = {"w": jnp.asarray([99.0, 99.0], jnp.float32)}
        groups, glob = fold(cur, res, res_g,
                            jnp.asarray([0.5, 0.5], jnp.float32))
        np.testing.assert_allclose(np.asarray(groups["w"]),
                                   [[1.0, 2.0], [3.0, 4.0]])
        # weighted mode ignores the dispatch's own auxiliary global model:
        # the folded global is the mean of the folded groups
        np.testing.assert_allclose(np.asarray(glob["w"]), [2.0, 3.0])

    def test_param_fold_per_group_weights(self):
        fold = rounds_lib.make_param_fold()
        cur = {"w": jnp.asarray([[0.0], [0.0]], jnp.float32)}
        res = {"w": jnp.asarray([[8.0], [8.0]], jnp.float32)}
        groups, _ = fold(cur, res, {"w": jnp.zeros(1, jnp.float32)},
                         jnp.asarray([1.0, 0.25], jnp.float32))
        np.testing.assert_allclose(np.asarray(groups["w"]), [[8.0], [2.0]])

    def test_staleness_fold_scatters_only_alive_cohort_rows(self):
        # dead lanes are redirected to the trash row; an untouched client's
        # membership must keep the CURRENT value even if the dispatch
        # result's snapshot of it is older
        fold = rounds_lib.make_staleness_fold()
        mk = lambda mem: dict(
            group_params={"w": jnp.zeros((2, 2), jnp.float32)},
            global_params={"w": jnp.zeros(2, jnp.float32)},
            group_delta=jnp.zeros((2, 3), jnp.float32),
            membership=jnp.asarray(mem, jnp.int32), aux=None)
        cur = mk([5, 5, 5, 5, 0])              # 4 clients + trash row
        res = mk([7, 7, 7, 7, 0])
        out = fold(cur, res, jnp.asarray([0, 2], jnp.int32),
                   jnp.asarray([1.0, 0.0], jnp.float32),
                   jnp.ones(2, jnp.float32))
        mem = np.asarray(out["membership"])
        assert mem[0] == 7                     # alive cohort lane adopted
        assert mem[2] == 5                     # dead lane NOT adopted
        assert mem[1] == 5 and mem[3] == 5     # untouched clients


# ---------------------------------------------------------------------------
# D=1 equivalence mode: bit-identical to the synchronous engine
# ---------------------------------------------------------------------------
class TestEquivalencePinned:
    @pytest.mark.parametrize("cls", ALL_TRAINERS,
                             ids=lambda c: c.framework)
    def test_depth1_bitwise_vs_block_path(self, cls, small_model,
                                          small_data):
        sync = _fresh(cls, small_model, small_data, False, block_size=4)
        h_sync = sync.run(4)
        asy = _fresh(cls, small_model, small_data, False, async_depth=1)
        h_asy = asy.run(4)
        assert h_asy.rounds == h_sync.rounds
        _assert_tree_equal(_state(asy), _state(sync))
        st = h_asy.async_stats
        assert st["dispatches"] == st["folds"] == 4
        assert st["max_in_flight"] == 1
        assert st["lease_expiries"] == 0 and st["requeues"] == 0
        assert st["staleness_hist"] == {"0": 4}


class TestEquivalenceStreamed:
    @pytest.mark.parametrize("cls", ALL_TRAINERS,
                             ids=lambda c: c.framework)
    def test_depth1_bitwise_vs_round_path(self, cls, small_model,
                                          small_data):
        sync = _fresh(cls, small_model, small_data, True)
        h_sync = sync.run(4)
        s_sync = _state(sync)
        sync.close()
        asy = _fresh(cls, small_model, small_data, True, async_depth=1)
        h_asy = asy.run(4)
        s_asy = _state(asy)
        asy.close()
        assert h_asy.rounds == h_sync.rounds
        _assert_tree_equal(s_asy, s_sync)
        assert h_asy.async_stats["staleness_hist"] == {"0": 4}


# ---------------------------------------------------------------------------
# depth > 1: staleness accounting and weighted folds
# ---------------------------------------------------------------------------
class TestAsyncDepth:
    def test_depth2_pinned_staleness_accounting(self, small_model,
                                                small_data):
        tr = _fresh(FedGroupTrainer, small_model, small_data, False,
                    async_depth=2, async_alpha=0.8, async_beta=0.5)
        h = tr.run(6)
        assert [r.round for r in h.rounds] == list(range(6))
        assert _tree_finite(tr.params) and _tree_finite(tr.group_params)
        st = h.async_stats
        assert st["dispatches"] == st["folds"] == 6
        assert st["max_in_flight"] == 2
        assert sum(st["staleness_hist"].values()) == 6
        # with two dispatches in flight, some fold saw staleness >= 1
        assert any(int(k) >= 1 for k in st["staleness_hist"])
        # per-group clocks advanced
        assert tr.group_version is not None and tr.group_version.sum() > 0

    def test_depth2_streamed_stays_finite(self, small_model, small_data):
        tr = _fresh(FedAvgTrainer, small_model, small_data, True,
                    async_depth=2, async_alpha=0.9, async_beta=0.5)
        h = tr.run(5)
        tr.close()
        assert len(h.rounds) == 5
        assert _tree_finite(tr.params)
        assert h.async_stats["max_in_flight"] == 2


# ---------------------------------------------------------------------------
# cohort leases: expiry -> requeue with capped backoff -> bounded retries
# ---------------------------------------------------------------------------
class TestLeases:
    def test_expired_lease_requeues_and_folds_later(self, small_model,
                                                    small_data):
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    async_depth=2, async_backoff=0.01,
                    async_backoff_cap=0.02)
        real_wait = tr._wait_ready
        kill = {"n": 1}

        def scripted(lease):
            if kill["n"] > 0 and lease.attempts == 0:
                kill["n"] -= 1        # script exactly one lease expiry
                return False
            return real_wait(lease)

        tr._wait_ready = scripted
        h = tr.run(4)
        st = h.async_stats
        assert st["lease_expiries"] == 1 and st["requeues"] == 1
        # the abandoned cohort was re-dispatched: one extra dispatch,
        # but every round still folded exactly once, in order
        assert st["dispatches"] == 5 and st["folds"] == 4
        assert [r.round for r in h.rounds] == [0, 1, 2, 3]
        assert _tree_finite(tr.params)

    def test_retries_exhausted_raises(self, small_model, small_data):
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    async_depth=1, async_max_retries=1,
                    async_backoff=0.001, async_backoff_cap=0.002)
        tr._wait_ready = lambda lease: False      # never completes
        with pytest.raises(RuntimeError, match="unrecoverable"):
            tr.run(2)
        # the doomed cohort expired at least twice (original + retry);
        # fresh cohorts staged in between may add expiries of their own
        assert tr.history.async_stats["lease_expiries"] >= 2

    def test_ready_result_is_never_expired(self, small_model, small_data):
        # readiness is checked before the deadline: an already-computed
        # result folds even under an absurdly tight lease timeout
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    async_depth=1, async_lease_timeout=1e-9)
        real_wait = tr._wait_ready

        def settled(lease):
            jax.block_until_ready(lease.result)   # result already computed
            return real_wait(lease)

        tr._wait_ready = settled
        h = tr.run(2)
        assert h.async_stats["lease_expiries"] == 0
        assert len(h.rounds) == 2


# ---------------------------------------------------------------------------
# kill-and-resume mid-async: drain-to-quiescence checkpoints
# ---------------------------------------------------------------------------
class TestKillResumeAsync:
    @pytest.mark.parametrize(
        "cls,streamed", [(FedGroupTrainer, False), (FeSEMTrainer, True)],
        ids=["fedgroup-pinned", "fesem-streamed"])
    def test_mid_async_resume_is_bit_identical(self, cls, streamed,
                                               small_model, small_data,
                                               tmp_path):
        kw = dict(async_depth=2, checkpoint_every=3)
        ref = _fresh(cls, small_model, small_data, streamed,
                     checkpoint_dir=str(tmp_path / "ref"), **kw)
        h_ref = ref.run(8)
        s_ref = _state(ref)
        ref.close()

        kill_dir = str(tmp_path / "kill")
        killed = _fresh(cls, small_model, small_data, streamed,
                        checkpoint_dir=kill_dir, **kw)
        killed.run(5)                  # "killed" after 5 folded rounds
        killed.close()
        # the cadence crossing at t=3 drains the one in-flight dispatch
        # (depth 2) before snapshotting, so the quiescent archive is t=4
        assert os.path.exists(ckpt_io.checkpoint_path(kill_dir, 4))

        resumed = _fresh(cls, small_model, small_data, streamed,
                         checkpoint_dir=kill_dir, **kw)
        t = resumed.load_checkpoint(kill_dir)
        assert t == 4
        h_res = resumed.run(8 - t)
        s_res = _state(resumed)
        resumed.close()

        assert h_res.rounds == h_ref.rounds
        assert h_res.async_stats["staleness_hist"] == \
            h_ref.async_stats["staleness_hist"]
        _assert_tree_equal(s_res, s_ref)
        np.testing.assert_array_equal(resumed.group_version,
                                      ref.group_version)


# ---------------------------------------------------------------------------
# checkpoint format versioning (checkpoint/io.py)
# ---------------------------------------------------------------------------
class TestCheckpointFormat:
    def _write_archive(self, path, meta: dict):
        with open(path, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), a=np.zeros(2))

    def test_unversioned_archive_reads_as_v1_and_fails_clearly(
            self, tmp_path):
        # archives written before versioning existed carry no format key
        path = str(tmp_path / "legacy.npz")
        self._write_archive(path, {"t": 3})
        with pytest.raises(
                ckpt_io.CheckpointFormatError,
                match=f"format version 1, expected "
                      f"{ckpt_io.CKPT_FORMAT_VERSION}"):
            ckpt_io.load_metadata(path)

    def test_version_checked_before_template_matching(self, tmp_path):
        # a v1 file with mismatched keys must fail on the VERSION, not with
        # a raw key-mismatch traceback
        path = str(tmp_path / "legacy.npz")
        self._write_archive(path, {"t": 3})
        with pytest.raises(ckpt_io.CheckpointFormatError):
            ckpt_io.load_pytree(path, {"different": np.zeros(7)})

    def test_future_version_rejected(self, tmp_path):
        path = str(tmp_path / "future.npz")
        self._write_archive(path, {ckpt_io._FORMAT_KEY: 99})
        with pytest.raises(ckpt_io.CheckpointFormatError,
                           match="format version 99"):
            ckpt_io.load_metadata(path)

    def test_current_version_roundtrips_and_strips_key(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        ckpt_io.save_pytree(path, {"a": np.ones(2)}, {"note": "x"})
        meta = ckpt_io.load_metadata(path)
        assert meta == {"note": "x"}          # format key is internal
        assert ckpt_io.CheckpointFormatError.__mro__[1] is ValueError


# ---------------------------------------------------------------------------
# bounded-retry async state writer
# ---------------------------------------------------------------------------
class TestWriterRetry:
    def test_transient_failures_recover_with_backoff(self):
        w = _AsyncStateWriter(timeout=5.0, max_retries=3, backoff=0.001,
                              backoff_cap=0.01)
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] <= 2:
                raise OSError("transient")

        w.submit(flaky, label="flaky-scatter")
        w.drain()                    # recovers — no error surfaced
        w.close()
        assert calls["n"] == 3
        assert w.retries == 2        # feeds Population.stats writer_retries

    def test_exhausted_retries_surface_in_drain(self):
        w = _AsyncStateWriter(timeout=5.0, max_retries=1, backoff=0.001)
        calls = {"n": 0}

        def broken():
            calls["n"] += 1
            raise OSError("disk gone")

        w.submit(broken)
        with pytest.raises(RuntimeError, match="write failed") as ei:
            w.drain()
        w.close()
        assert calls["n"] == 2       # original attempt + 1 retry
        assert isinstance(ei.value.__cause__, OSError)

    def test_success_without_retries_counts_zero(self):
        w = _AsyncStateWriter(timeout=5.0)
        w.submit(lambda: None)
        w.drain()
        w.close()
        assert w.retries == 0


# ---------------------------------------------------------------------------
# Population.stats lifecycle: reset per run(), checkpointed, restored
# ---------------------------------------------------------------------------
class TestStatsLifecycle:
    def test_stats_reset_between_runs(self, small_model, small_data):
        faults = FaultConfig(rounds={1: FaultSpec(kill=5)})
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(faults=faults))
        tr = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        tr.run(2)
        assert pop.stats["killed_clients"] == 5
        tr.run(2)            # rounds 2-3: no faults scripted there
        tr.close()
        assert pop.stats["killed_clients"] == 0    # fresh run, fresh stats

    def test_reset_stats_zeroes_every_counter(self, small_data):
        pop = Population(ArrayClientStore(small_data), PopulationConfig())
        pop.stats["lease_expiries"] = 7
        pop.stats["requeues"] = 3
        pop.reset_stats()
        assert all(v == 0 for v in pop.stats.values())
        pop.close()

    def test_restored_stats_survive_resume(self, small_model, small_data,
                                           tmp_path):
        faults = FaultConfig(rounds={1: FaultSpec(kill=4)})
        ck = dict(checkpoint_every=2, checkpoint_dir=str(tmp_path))
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(faults=faults))
        tr = FedAvgTrainer(small_model, None, _cfg(**ck), population=pop)
        tr.run(2)
        tr.close()

        pop2 = Population(ArrayClientStore(small_data),
                          PopulationConfig(faults=faults))
        tr2 = FedAvgTrainer(small_model, None, _cfg(**ck), population=pop2)
        assert tr2.load_checkpoint(str(tmp_path)) == 2
        assert pop2.stats["killed_clients"] == 4   # restored from the meta
        tr2.run(2)           # resumed run keeps the restored totals
        tr2.close()
        assert pop2.stats["killed_clients"] == 4


# ---------------------------------------------------------------------------
# full straggler-trace matrix — slow, opt-in (REPRO_SLOW=1)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("REPRO_SLOW"),
                    reason="full straggler matrix: set REPRO_SLOW=1")
class TestSlowStragglerMatrix:
    @pytest.mark.parametrize("depth", [2, 4])
    @pytest.mark.parametrize("cls", ALL_TRAINERS,
                             ids=lambda c: c.framework)
    def test_async_under_straggler_trace(self, cls, depth, small_model,
                                         small_data):
        faults = FaultConfig(rounds={1: FaultSpec(straggle=0.3),
                                     3: FaultSpec(kill=3),
                                     5: FaultSpec(straggle=0.3)})
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(faults=faults, **STREAM_KW))
        tr = cls(small_model, None,
                 _cfg(async_depth=depth, async_alpha=0.8, async_beta=0.5),
                 population=pop)
        h = tr.run(8)
        tr.close()
        assert len(h.rounds) == 8
        assert _tree_finite(tr.params)
        st = h.async_stats
        assert st["folds"] == 8
        assert st["max_in_flight"] <= depth
        assert sum(st["staleness_hist"].values()) == 8

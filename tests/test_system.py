"""End-to-end behaviour tests for the full system (paper-level claims)."""
import numpy as np
import pytest

from repro.core.fedgroup import FedGroupTrainer
from repro.fed.engine import FedAvgTrainer, FedConfig


class TestPaperClaims:
    def test_table1_heterogeneity_trend(self, tiny_model):
        """Table 1: more classes/client (less heterogeneity) -> higher max
        accuracy."""
        from repro.data.generators import mnist_like
        results = {}
        for cpc in (1, 5, 10):
            data = mnist_like(seed=0, n_clients=60, classes_per_client=cpc,
                              total_train=4000, dim=32)
            cfg = FedConfig(n_rounds=5, clients_per_round=10, local_epochs=5,
                            batch_size=10, lr=0.05, seed=0)
            tr = FedAvgTrainer(tiny_model, data, cfg)
            h = tr.run()
            results[cpc] = (h.max_acc,
                            float(np.var([r.discrepancy for r in h.rounds])))
        assert results[10][0] > results[1][0]          # IID best accuracy

    def test_rcc_ablation_between_random_and_full(self, tiny_model,
                                                  tiny_fed_data, fast_cfg):
        """Table 3 ablation: RCC (random centres) degrades vs full FedGroup."""
        full = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg).run(4)
        rcc_cfg = FedConfig(**{**fast_cfg.__dict__, "rcc": True})
        rcc = FedGroupTrainer(tiny_model, tiny_fed_data, rcc_cfg).run(4)
        # RCC should not beat proper clustering (allow small noise margin)
        assert rcc.max_acc <= full.max_acc + 0.05

    def test_fedgroup_converges_faster_than_fedavg(self, tiny_model,
                                                   tiny_fed_data, fast_cfg):
        """Fig. 3: FedGroup reaches a given accuracy in fewer rounds."""
        fa = FedAvgTrainer(tiny_model, tiny_fed_data, fast_cfg).run(4)
        fg = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg).run(4)
        target = 0.55
        ra = fa.rounds_to_reach(target)
        rg = fg.rounds_to_reach(target)
        assert rg is not None
        assert ra is None or rg <= ra


class TestFrameworkContracts:
    def test_all_trainers_share_interface(self, tiny_model, tiny_fed_data,
                                          fast_cfg):
        from repro.fed.fesem import FeSEMTrainer
        from repro.fed.ifca import IFCATrainer
        for cls in (FedAvgTrainer, FedGroupTrainer, IFCATrainer, FeSEMTrainer):
            tr = cls(tiny_model, tiny_fed_data, fast_cfg)
            m = tr.round(0)
            assert 0 <= m.weighted_acc <= 1
            assert m.discrepancy >= 0
            assert tr.framework

    def test_empty_group_round_survives(self, tiny_model, tiny_fed_data):
        """A round where some group has no selected clients must not crash
        (Algorithm 2 line 13: empty group keeps its parameters)."""
        cfg = FedConfig(n_rounds=1, clients_per_round=2, local_epochs=2,
                        batch_size=5, lr=0.05, n_groups=5, pretrain_scale=2,
                        seed=0)
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, cfg)
        m = tr.round(0)
        assert np.isfinite(m.weighted_acc)

"""Baseline frameworks: FedAvg/FedProx/IFCA/FeSEM behave sanely."""
import numpy as np

from repro.fed.engine import FedAvgTrainer, FedConfig, FedProxTrainer
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer


class TestFedAvg:
    def test_learns(self, tiny_model, tiny_fed_data, fast_cfg):
        tr = FedAvgTrainer(tiny_model, tiny_fed_data, fast_cfg)
        h = tr.run(4)
        assert h.max_acc > 0.3          # well above 10-class chance

    def test_history_tracks_max(self, tiny_model, tiny_fed_data, fast_cfg):
        tr = FedAvgTrainer(tiny_model, tiny_fed_data, fast_cfg)
        h = tr.run(3)
        assert h.max_acc == max(r.weighted_acc for r in h.rounds)

    def test_deterministic_given_seed(self, tiny_model, tiny_fed_data,
                                      fast_cfg):
        a = FedAvgTrainer(tiny_model, tiny_fed_data, fast_cfg).run(2)
        b = FedAvgTrainer(tiny_model, tiny_fed_data, fast_cfg).run(2)
        assert [r.weighted_acc for r in a.rounds] == \
               [r.weighted_acc for r in b.rounds]


class TestFedProx:
    def test_mu_defaults_positive(self, tiny_model, tiny_fed_data, fast_cfg):
        tr = FedProxTrainer(tiny_model, tiny_fed_data, fast_cfg)
        assert tr.cfg.mu > 0

    def test_prox_reduces_divergence(self, tiny_model, tiny_fed_data):
        """FedProx's proximal term bounds local drift (paper §2.1)."""
        base = dict(n_rounds=1, clients_per_round=10, local_epochs=20,
                    batch_size=10, lr=0.05, n_groups=3, pretrain_scale=4,
                    seed=0)
        plain = FedAvgTrainer(tiny_model, tiny_fed_data, FedConfig(**base))
        prox = FedAvgTrainer(tiny_model, tiny_fed_data,
                             FedConfig(**{**base, "mu": 0.5}))
        d_plain = plain.round(0).discrepancy
        d_prox = prox.round(0).discrepancy
        assert d_prox < d_plain


class TestIFCA:
    def test_runs_and_learns(self, tiny_model, tiny_fed_data, fast_cfg):
        tr = IFCATrainer(tiny_model, tiny_fed_data, fast_cfg)
        h = tr.run(4)
        assert h.max_acc > 0.3

    def test_broadcast_overhead_counted(self, tiny_model, tiny_fed_data,
                                        fast_cfg):
        tr = IFCATrainer(tiny_model, tiny_fed_data, fast_cfg)
        assert tr.comm_models_per_round == fast_cfg.n_groups

    def test_membership_can_change(self, tiny_model, tiny_fed_data, fast_cfg):
        """IFCA reschedules every round (unlike FedGroup's static groups)."""
        tr = IFCATrainer(tiny_model, tiny_fed_data, fast_cfg)
        tr.run(3)
        assert np.any(tr.membership >= 0)


class TestCommunicationAccounting:
    def test_ifca_broadcast_overhead_dominates(self, tiny_model,
                                               tiny_fed_data, fast_cfg):
        """Paper §5.2: IFCA broadcasts all m models per round — its cumulative
        communication exceeds FedAvg's and (after amortizing the one-time
        cold start) FedGroup's per-round cost."""
        from repro.core.fedgroup import FedGroupTrainer
        from repro.fed.ifca import IFCATrainer
        fa = FedAvgTrainer(tiny_model, tiny_fed_data, fast_cfg)
        fi = IFCATrainer(tiny_model, tiny_fed_data, fast_cfg)
        fg = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        for t in range(4):
            fa.round(t), fi.round(t), fg.round(t)
        assert fi.comm_params > fa.comm_params
        # FedGroup's marginal round cost (2 transfers/client + any newcomer
        # cold starts) stays below IFCA's (m+1 transfers/client, forever)
        fg_before, fi_before = fg.comm_params, fi.comm_params
        fg.round(4)
        fi.round(4)
        assert (fg.comm_params - fg_before) < (fi.comm_params - fi_before)


class TestFeSEM:
    def test_runs(self, tiny_model, tiny_fed_data, fast_cfg):
        tr = FeSEMTrainer(tiny_model, tiny_fed_data, fast_cfg)
        h = tr.run(3)
        assert 0.0 <= h.max_acc <= 1.0
        assert np.any(tr.membership >= 0)

"""Unified telemetry layer (PR 8): spans, metrics registry, JSONL stream,
inspector.

Covers the observability acceptance contract: spans nest and close under
the async in-flight window (depth > 1) and across lease expiry/requeue;
the Chrome-trace export validates against the trace-event schema; the
metrics registry round-trips through checkpoint metadata; the telemetry
JSONL stream is bit-stable across kill-and-resume; ``Population.stats``'s
``_STATS_ZERO`` and the ``pop.*`` registry schema never drift apart; and
``launch/inspect.py`` renders and schema-lints a real telemetry dir.
"""
import json
import os
import sys
import threading

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.fedgroup import FedGroupTrainer  # noqa: E402
from repro.data.generators import mnist_like  # noqa: E402
from repro.fed.engine import FedAvgTrainer, FedConfig  # noqa: E402
from repro.fed.fesem import FeSEMTrainer  # noqa: E402
from repro.fed.population import (Population, PopulationConfig,  # noqa: E402
                                  _STATS_ZERO, pop_metric_specs)
from repro.fed.store import ArrayClientStore  # noqa: E402
from repro.launch import inspect as inspect_cli  # noqa: E402
from repro.obs import (ASYNC_SCHEMA, COUNTER, GAUGE, HIST,  # noqa: E402
                       NULL_SPAN, JsonlSink, MetricSpec, MetricsRegistry,
                       Telemetry, Tracer, chrome_trace_doc,
                       validate_chrome_trace)
from repro.obs import telemetry as obs_telemetry  # noqa: E402

pytestmark = pytest.mark.obs

N_CLIENTS = 40


@pytest.fixture(scope="module")
def small_data():
    return mnist_like(seed=0, n_clients=N_CLIENTS, classes_per_client=2,
                      total_train=2000, dim=16)


@pytest.fixture(scope="module")
def small_model():
    from repro.models.paper_models import mclr
    return mclr(16, 10)


def _cfg(**kw):
    base = dict(n_rounds=4, clients_per_round=8, local_epochs=2,
                batch_size=5, lr=0.05, n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _fresh(cls, model, data, streamed, **cfg_kw):
    cfg = _cfg(**cfg_kw)
    if streamed:
        pop = Population(ArrayClientStore(data),
                         PopulationConfig(initial_active=30,
                                          arrival_rate=2.0, prefetch=2))
        return cls(model, None, cfg, population=pop)
    return cls(model, data, cfg)


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
class TestTracer:
    def test_disabled_is_structural_noop(self):
        tr = Tracer(enabled=False)
        assert tr.span("stage", t=0) is NULL_SPAN
        with tr.span("dispatch"):
            pass
        assert tr.records() == [] and tr.open_depth() == 0

    def test_nesting_depth_and_close(self):
        tr = Tracer(enabled=True)
        with tr.span("stage", t=0):
            with tr.span("h2d"):
                pass
        assert tr.open_depth() == 0
        by_kind = {r.kind: r for r in tr.records()}
        assert by_kind["stage"].depth == 0 and by_kind["h2d"].depth == 1
        # inner span closed first: ring order is completion order
        assert [r.kind for r in tr.records()] == ["h2d", "stage"]
        assert all(r.dur_ns >= 0 for r in tr.records())

    def test_ring_buffer_is_bounded(self):
        tr = Tracer(enabled=True, capacity=4)
        for i in range(10):
            with tr.span("eval", t=i):
                pass
        recs = tr.records()
        assert len(recs) == 4
        assert [r.attrs["t"] for r in recs] == [6, 7, 8, 9]

    def test_per_thread_stacks(self):
        tr = Tracer(enabled=True)
        seen = {}

        def worker():
            with tr.span("state-write"):
                seen["depth"] = tr.open_depth()

        with tr.span("stage"):
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        # the worker's span does not nest under the main thread's
        assert seen["depth"] == 1
        assert {r.kind: r.depth for r in tr.records()} == \
            {"state-write": 0, "stage": 0}

    def test_wrap_checks_enabled_per_call(self):
        tr = Tracer(enabled=False)
        f = tr.wrap("dispatch", lambda x: x + 1, exec="round")
        assert f(1) == 2 and tr.records() == []
        tr.enabled = True          # enabled AFTER the wrap was built
        assert f(2) == 3
        assert [r.kind for r in tr.records()] == ["dispatch"]
        assert tr.records()[0].attrs["exec"] == "round"


class TestChromeTrace:
    def test_export_validates(self):
        tr = Tracer(enabled=True)
        with tr.span("stage", t=0):
            with tr.span("fold", t=0):
                pass
        doc = chrome_trace_doc(tr.chrome_events())
        assert validate_chrome_trace(doc) == []
        names = {ev["name"] for ev in doc["traceEvents"]}
        assert names == {"stage", "fold"}
        for ev in doc["traceEvents"]:
            assert ev["ph"] == "X" and ev["ts"] >= 0 and ev["dur"] >= 0

    def test_broken_event_fails_validation(self):
        tr = Tracer(enabled=True)
        with tr.span("eval"):
            pass
        doc = chrome_trace_doc(tr.chrome_events())
        del doc["traceEvents"][0]["ts"]
        assert validate_chrome_trace(doc)
        assert validate_chrome_trace({"not": "a trace"})


# ---------------------------------------------------------------------------
# metrics registry + views
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_declare_inc_observe_snapshot_restore(self):
        reg = MetricsRegistry()
        reg.inc("async.dispatches", 3)
        reg.set("async.max_in_flight", 2)
        reg.observe("async.staleness_hist", 1)
        reg.observe("async.staleness_hist", 1)
        snap = reg.snapshot()
        assert snap["async.dispatches"] == 3
        assert snap["async.staleness_hist"] == {"1": 2}  # str buckets

        reg2 = MetricsRegistry()
        reg2.restore(snap)
        assert reg2.snapshot() == snap
        # restore into a registry with prior state overwrites, not merges
        reg2.inc("async.dispatches")
        reg2.restore(snap)
        assert reg2.get("async.dispatches") == 3

    def test_kind_conflict_is_an_error(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="redeclared"):
            reg.declare([MetricSpec("async.dispatches", GAUGE)])
        # idempotent re-declaration is fine
        reg.declare([MetricSpec("async.dispatches", COUNTER)])

    def test_unknown_metric_raises(self):
        reg = MetricsRegistry()
        with pytest.raises(KeyError):
            reg.inc("nope.nothing")

    def test_view_is_live_and_fixed_keyset(self):
        reg = MetricsRegistry()
        view = reg.view({"dispatches": "async.dispatches",
                         "staleness_hist": "async.staleness_hist"})
        reg.inc("async.dispatches", 2)
        assert view["dispatches"] == 2
        view["dispatches"] = 7                      # write-through
        assert reg.get("async.dispatches") == 7
        # the hist view hands back the LIVE dict: in-place mutation lands
        h = view["staleness_hist"]
        h["0"] = h.get("0", 0) + 1                  # the engine's pattern
        assert reg.get("async.staleness_hist") == {"0": 1}
        assert view == {"dispatches": 7, "staleness_hist": {"0": 1}}
        with pytest.raises(TypeError):
            del view["dispatches"]
        with pytest.raises(KeyError):
            view["unmapped"]

    def test_pop_schema_matches_stats_zero(self):
        # _STATS_ZERO is THE single source of truth for population
        # degradation counters — the registry schema is derived from it
        assert {s.name for s in pop_metric_specs()} == \
            {f"pop.{k}" for k in _STATS_ZERO}
        assert all(s.kind == COUNTER for s in pop_metric_specs())
        pop = Population(ArrayClientStore(
            mnist_like(seed=0, n_clients=8, classes_per_client=2,
                       total_train=400, dim=8)), PopulationConfig())
        assert set(pop.stats) == set(_STATS_ZERO)
        assert set(pop.obs.registry.names("pop.")) == \
            {f"pop.{k}" for k in _STATS_ZERO}
        pop.close()

    def test_async_schema_covers_legacy_async_stats_keys(self):
        legacy = {"dispatches", "folds", "max_in_flight", "lease_expiries",
                  "requeues", "staleness_hist"}
        assert {s.name.split(".", 1)[1] for s in ASYNC_SCHEMA} == legacy
        hists = [s.name for s in ASYNC_SCHEMA if s.kind == HIST]
        assert hists == ["async.staleness_hist"]


class TestFromConfig:
    def test_fresh_registry_shared_tracer(self):
        default = Telemetry(enabled=True)
        obs_telemetry.set_default(default)
        try:
            a = obs_telemetry.from_config(None)
            b = obs_telemetry.from_config(None)
            assert a.tracer is default.tracer is b.tracer
            assert a.registry is not b.registry
            assert a.registry is not default.registry
            a.registry.inc("async.dispatches")
            assert b.registry.get("async.dispatches") == 0
        finally:
            obs_telemetry.set_default(None)
        c = obs_telemetry.from_config(None)
        assert not c.enabled and c.tracer is not default.tracer


# ---------------------------------------------------------------------------
# JSONL sink
# ---------------------------------------------------------------------------
class TestJsonlSink:
    def test_deterministic_encoding_and_rotation(self, tmp_path):
        sink = JsonlSink(str(tmp_path), max_bytes=64)
        for t in range(6):
            sink.emit({"kind": "round", "t": t, "acc": 0.5})
        sink.close()
        assert len(sink.segment_paths()) > 1       # rotated at 64 bytes
        recs = sink.records()
        assert [r["t"] for r in recs] == list(range(6))
        line = JsonlSink.encode({"b": 1, "a": 2})
        assert line == '{"a":2,"b":1}'             # sorted, no spaces

    def test_truncate_from_compacts(self, tmp_path):
        sink = JsonlSink(str(tmp_path), max_bytes=64)
        for t in range(6):
            sink.emit({"kind": "round", "t": t, "acc": 0.5})
        sink.truncate_from(3)
        assert [r["t"] for r in sink.records()] == [0, 1, 2]
        assert len(sink.segment_paths()) == 1      # compacted to main file
        sink.emit({"kind": "round", "t": 3, "acc": 0.6})
        assert [r["t"] for r in sink.records()] == [0, 1, 2, 3]
        sink.close()


# ---------------------------------------------------------------------------
# spans under the async runtime
# ---------------------------------------------------------------------------
class TestAsyncSpans:
    def test_depth2_spans_balanced_and_kinds_present(self, small_model,
                                                     small_data, tmp_path):
        tr = _fresh(FedAvgTrainer, small_model, small_data, True,
                    async_depth=2,
                    telemetry_dir=str(tmp_path / "tel"))
        tr.run(6)
        tr.close()
        tracer = tr.obs.tracer
        assert tracer.open_depth() == 0            # every span closed
        kinds = {r.kind for r in tracer.records()}
        assert {"stage", "h2d", "dispatch", "fold", "eval"} <= kinds
        # the population producer nests h2d puts inside its stage spans
        assert any(r.depth > 0 for r in tracer.records())
        assert validate_chrome_trace(chrome_trace_doc(tracer.chrome_events())) == []

    def test_spans_survive_lease_expiry_requeue(self, small_model,
                                                small_data):
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    async_depth=2, async_backoff=0.01,
                    async_backoff_cap=0.02)
        tr.obs.tracer.enabled = True
        real_wait = tr._wait_ready
        kill = {"n": 1}

        def scripted(lease):
            if kill["n"] > 0 and lease.attempts == 0:
                kill["n"] -= 1                     # one scripted expiry
                return False
            return real_wait(lease)

        tr._wait_ready = scripted
        h = tr.run(4)
        tr.close()
        st = h.async_stats
        assert st["lease_expiries"] == 1 and st["requeues"] == 1
        assert tr.obs.registry.get("async.requeues") == 1
        tracer = tr.obs.tracer
        assert tracer.open_depth() == 0            # expiry leaked no span
        # the requeued cohort re-dispatched: 5 dispatch spans, 4 folds
        by_kind = {}
        for r in tracer.records():
            by_kind[r.kind] = by_kind.get(r.kind, 0) + 1
        assert by_kind["dispatch"] == 5 and by_kind["fold"] == 4


# ---------------------------------------------------------------------------
# registry through checkpoint metadata + JSONL bit-stability
# ---------------------------------------------------------------------------
class TestCheckpointRoundTrip:
    def test_registry_snapshot_rides_checkpoint_meta(self, small_model,
                                                     small_data, tmp_path):
        from repro.checkpoint import io as ckpt_io
        tr = _fresh(FedAvgTrainer, small_model, small_data, False,
                    async_depth=1, checkpoint_every=2,
                    checkpoint_dir=str(tmp_path))
        tr.run(4)
        tr.close()
        path = ckpt_io.latest_checkpoint(str(tmp_path))
        meta = ckpt_io.load_metadata(path)
        assert "obs" in meta and "async_stats" not in meta

        resumed = _fresh(FedAvgTrainer, small_model, small_data, False,
                         async_depth=1, checkpoint_every=2,
                         checkpoint_dir=str(tmp_path))
        resumed.load_checkpoint(str(tmp_path))
        snap = resumed.obs.registry.snapshot()
        # the restored registry holds exactly what the archive recorded
        # (hist buckets are string-keyed end to end, so JSON round-trips)
        for k, v in meta["obs"].items():
            assert snap[k] == v, k
        # the snapshot was taken AFTER counting its own checkpoint write
        assert snap["rounds.checkpoints"] >= 1
        resumed.close()

    def test_jsonl_bit_stable_across_kill_and_resume(self, small_model,
                                                     small_data, tmp_path):
        kw = dict(async_depth=2, checkpoint_every=3)
        ref = _fresh(FeSEMTrainer, small_model, small_data, True,
                     checkpoint_dir=str(tmp_path / "ref_ck"),
                     telemetry_dir=str(tmp_path / "ref_tel"), **kw)
        h_ref = ref.run(8)
        ref.close()

        kill_ck = str(tmp_path / "kill_ck")
        kill_tel = str(tmp_path / "kill_tel")
        killed = _fresh(FeSEMTrainer, small_model, small_data, True,
                        checkpoint_dir=kill_ck, telemetry_dir=kill_tel,
                        **kw)
        killed.run(5)                    # "killed" after 5 folded rounds
        killed.close()

        resumed = _fresh(FeSEMTrainer, small_model, small_data, True,
                         checkpoint_dir=kill_ck, telemetry_dir=kill_tel,
                         **kw)
        t = resumed.load_checkpoint(kill_ck)
        h_res = resumed.run(8 - t)
        resumed.close()

        assert h_res.rounds == h_ref.rounds
        with open(os.path.join(str(tmp_path / "ref_tel"),
                               "metrics.jsonl"), "rb") as f:
            ref_bytes = f.read()
        with open(os.path.join(kill_tel, "metrics.jsonl"), "rb") as f:
            res_bytes = f.read()
        assert ref_bytes == res_bytes    # byte-identical stream
        # cumulative counters survived the resume (restored from meta,
        # not recounted from zero)
        assert resumed.obs.registry.get("rounds.completed") == 8


# ---------------------------------------------------------------------------
# acceptance: streamed FedGroup run + inspector
# ---------------------------------------------------------------------------
class TestAcceptance:
    @pytest.fixture(scope="class")
    def run_dir(self, small_model, small_data, tmp_path_factory):
        tdir = str(tmp_path_factory.mktemp("fedgroup_tel"))
        tr = _fresh(FedGroupTrainer, small_model, small_data, True,
                    async_depth=1, checkpoint_every=2,
                    checkpoint_dir=str(tmp_path_factory.mktemp("ck")),
                    telemetry_dir=tdir)
        tr.run(4)
        tr.close()
        return tdir

    def test_streamed_fedgroup_emits_all_artifacts(self, run_dir):
        files = set(os.listdir(run_dir))
        assert {"metrics.jsonl", "trace.json", "run_summary.json"} <= files
        with open(os.path.join(run_dir, "trace.json")) as f:
            doc = json.load(f)
        assert validate_chrome_trace(doc) == []
        kinds = {ev["name"] for ev in doc["traceEvents"]}
        assert len(kinds) >= 6           # acceptance floor: 6 span kinds
        assert {"stage", "h2d", "dispatch", "fold", "eval",
                "checkpoint"} <= kinds

    def test_round_records_carry_group_series(self, run_dir):
        with open(os.path.join(run_dir, "metrics.jsonl")) as f:
            recs = [json.loads(line) for line in f if line.strip()]
        rounds = [r for r in recs if r["kind"] == "round"]
        assert [r["t"] for r in rounds] == list(range(len(rounds)))
        for r in rounds:
            assert {"acc", "loss", "disc", "quarantined", "group_sizes",
                    "group_version", "staleness", "weights", "cold",
                    "eta_g", "migrations"} <= set(r)
            assert sum(r["group_sizes"]) >= 0

    def test_summary_renders_and_checks_clean(self, run_dir):
        out = inspect_cli.render(run_dir, inspect_cli.load_dir(run_dir),
                                 spark=True)
        assert "per-stage time breakdown" in out
        assert "dispatch" in out and "rounds streamed" in out
        assert inspect_cli.check_dir(run_dir) == []
        assert inspect_cli.main([run_dir, "--check"]) == 0

    def test_check_flags_corrupt_dir(self, run_dir, tmp_path):
        import shutil
        bad = str(tmp_path / "bad")
        shutil.copytree(run_dir, bad)
        with open(os.path.join(bad, "metrics.jsonl"), "a") as f:
            # duplicate round index + an unparsable line
            f.write('{"kind":"round","t":0,"acc":1.0,"loss":0.1,'
                    '"disc":0.0,"quarantined":0}\n')
            f.write("not json\n")
        errors = inspect_cli.check_dir(bad)
        assert any("not" in e and "increasing" in e for e in errors)
        assert any("invalid JSON" in e for e in errors)
        assert inspect_cli.main([bad, "--check"]) == 1

    def test_sparkline_shapes(self):
        assert inspect_cli.sparkline([]) == "(no data)"
        assert inspect_cli.sparkline([1.0]) == inspect_cli._SPARK[0]
        line = inspect_cli.sparkline(list(range(100)), width=10)
        assert len(line) == 10
        assert line[0] == inspect_cli._SPARK[0]
        assert line[-1] == inspect_cli._SPARK[-1]

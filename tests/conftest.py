import os
import sys

# Tests must see the 1 real CPU device (NOT the dry-run's 512 placeholders):
# never import repro.launch.dryrun from tests.
os.environ.setdefault("JAX_ENABLE_X64", "0")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _no_default_telemetry_leak():
    """No test may leak a process-default telemetry: a leaked default makes
    every later trainer in the process silently record into a dead
    registry (set_default is for harnesses like benchmarks/run.py, which
    restore it)."""
    from repro.obs import telemetry
    before = telemetry.get_default()
    yield
    after = telemetry.get_default()
    assert after is before, (
        f"test leaked a process-default telemetry: {after!r} "
        f"(was {before!r}) — wrap set_default() in try/finally")


@pytest.fixture(autouse=True)
def _fleet_deadlock_backstop(request):
    """Deadlock backstop for ``fleet``-marked tests: a spawned worker and
    the coordinator's message pump can — under a real bug — wait on each
    other forever, and a hung CI job with no traceback is undebuggable.
    ``faulthandler`` dumps every thread's stack after 5 minutes (without
    killing the run, so the test still fails on its own timeout/assert)."""
    if request.node.get_closest_marker("fleet") is None:
        yield
        return
    import faulthandler
    faulthandler.dump_traceback_later(300.0, exit=False)
    try:
        yield
    finally:
        faulthandler.cancel_dump_traceback_later()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_fed_data():
    """Small label-skew dataset shared by the FL integration tests."""
    from repro.data.generators import mnist_like
    return mnist_like(seed=0, n_clients=60, classes_per_client=2,
                      total_train=4000, dim=32)


@pytest.fixture(scope="session")
def tiny_model():
    from repro.models.paper_models import mclr
    return mclr(32, 10)


@pytest.fixture(scope="session")
def fast_cfg():
    from repro.fed.engine import FedConfig
    return FedConfig(n_rounds=4, clients_per_round=10, local_epochs=5,
                     batch_size=10, lr=0.05, n_groups=3, pretrain_scale=4,
                     seed=0)


def assert_finite(tree, name=""):
    for leaf in jax.tree_util.tree_leaves(tree):
        assert np.all(np.isfinite(np.asarray(leaf))), f"non-finite in {name}"

"""§Perf optimization variants must be numerically equivalent to baselines.

Every hillclimb lever is a selectable config/flag; these tests pin the
baseline == optimized contract (same math, different schedule/sharding).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.fed import parallel as fp
from repro.models import moe as moe_lib
from repro.models import xlstm as xl
from repro.models import zoo


class TestGroupedMoE:
    @pytest.mark.parametrize("B,S,D,E,k", [(2, 32, 16, 4, 2), (3, 16, 8, 8, 3)])
    def test_equals_scatter_dispatch(self, B, S, D, E, k):
        key = jax.random.PRNGKey(B * S + E)
        p = moe_lib.init_moe(key, D, 32, E, n_shared=1)
        x = jax.random.normal(key, (B, S, D))
        y1, a1 = moe_lib.moe_apply(p, x, top_k=k, capacity_factor=100.0)
        y2, a2 = moe_lib.moe_apply_grouped(p, x, top_k=k, capacity_factor=100.0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-4)
        assert float(a1.load_balance_loss) == pytest.approx(
            float(a2.load_balance_loss), rel=1e-5)

    def test_grouped_respects_capacity(self):
        key = jax.random.PRNGKey(0)
        p = moe_lib.init_moe(key, 8, 16, 4)
        x = jax.random.normal(key, (2, 32, 8))
        y, aux = moe_lib.moe_apply_grouped(p, x, top_k=2, capacity_factor=0.25)
        assert np.all(np.isfinite(np.asarray(y)))

    def test_moe_arch_trains_with_grouped(self):
        cfg = registry.smoke_variant(registry.get("granite-moe-1b-a400m"))
        cfg = cfg.replace(moe_impl="grouped")
        key = jax.random.PRNGKey(1)
        state = zoo.init_train_state(key, cfg)
        batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
        state2, m = zoo.train_step(state, batch, cfg)
        assert np.isfinite(float(m["loss"]))


class TestChunkwiseMLSTM:
    @pytest.mark.parametrize("B,S,H,P,Q", [(2, 32, 2, 16, 8), (1, 64, 4, 32, 16)])
    def test_equals_recurrent(self, B, S, H, P, Q):
        key = jax.random.PRNGKey(S + P)
        ks = jax.random.split(key, 5)
        q = jax.random.normal(ks[0], (B, S, H, P))
        k = jax.random.normal(ks[1], (B, S, H, P))
        v = jax.random.normal(ks[2], (B, S, H, P))
        i_r = jax.random.normal(ks[3], (B, S, H))
        f_r = jax.random.normal(ks[4], (B, S, H)) * 2 + 3
        init = (jnp.zeros((B, H, P, P)), jnp.zeros((B, H, P)),
                jnp.zeros((B, H)) - 30.0)

        def step(c, t):
            return xl.mlstm_cell(c, (q[:, t], k[:, t], v[:, t],
                                     i_r[:, t], f_r[:, t]))
        _, hs = jax.lax.scan(step, init, jnp.arange(S))
        h_chk, _ = xl.mlstm_chunkwise(q, k, v, i_r, f_r, Q)
        np.testing.assert_allclose(np.asarray(hs.transpose(1, 0, 2, 3)),
                                   np.asarray(h_chk), atol=5e-4, rtol=5e-4)

    def test_block_fwd_impl_agreement(self):
        key = jax.random.PRNGKey(3)
        p = xl.init_mlstm(key, 16, 2)
        x = jax.random.normal(key, (2, 16, 16))
        a = xl.mlstm_block_fwd(p, x, n_heads=2, chunk=4, impl="recurrent")
        b = xl.mlstm_block_fwd(p, x, n_heads=2, chunk=4, impl="chunkwise")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


class TestXLSTMUnitScan:
    def test_forward_equals_python_loop(self):
        base = registry.smoke_variant(registry.get("xlstm-350m"))
        key = jax.random.PRNGKey(4)
        params = zoo.init_params(key, base)
        batch = {"tokens": jax.random.randint(key, (2, 32), 0, base.vocab_size),
                 "labels": jax.random.randint(key, (2, 32), 0, base.vocab_size)}
        la, _ = zoo.forward(params, base, batch)
        lb, _ = zoo.forward(params, base.replace(xlstm_scan_units=True), batch)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=2e-4, rtol=2e-4)

    def test_pattern_period(self):
        assert zoo._pattern_period(("m", "m", "s") * 4) == 3
        assert zoo._pattern_period(("m",) * 6) == 1
        assert zoo._pattern_period(("m", "s", "m")) == 3


class TestChunkedMLAAttention:
    def test_q_chunk_equals_full(self):
        cfg = registry.smoke_variant(registry.get("deepseek-v3-671b"))
        key = jax.random.PRNGKey(5)
        params = zoo.init_params(key, cfg)
        batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
        la, _ = zoo.forward(params, cfg.replace(capacity_factor=100.0), batch)
        lb, _ = zoo.forward(params, cfg.replace(capacity_factor=100.0,
                                                attn_q_chunk=8), batch)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=2e-3, rtol=2e-3)


class TestCholeskyQR:
    def test_cqr2_orthonormal(self):
        key = jax.random.PRNGKey(6)
        Y = jax.random.normal(key, (500, 12))
        Q, R = fp.cholesky_qr2(Y)
        np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(12), atol=1e-4)
        np.testing.assert_allclose(np.asarray(Q @ R), np.asarray(Y),
                                   atol=1e-3, rtol=1e-3)

    def test_rsvd_qr_impls_agree(self):
        key = jax.random.PRNGKey(7)
        # decaying spectrum (the FedGroup regime). Decay kept moderate: CQR2
        # squares the condition number, so cond(Y) must stay << sqrt(1/eps32).
        U = jnp.linalg.qr(jax.random.normal(key, (300, 20)))[0]
        s = 10.0 * 0.8 ** jnp.arange(20)
        dW = ((U * s) @ jax.random.normal(jax.random.fold_in(key, 1),
                                          (20, 20))).T    # (20, 300)
        V1 = fp.rsvd_sharded(dW, 4, qr_impl="householder")
        V2 = fp.rsvd_sharded(dW, 4, qr_impl="cholesky")
        # same subspace up to rotation/sign
        S = np.abs(np.asarray(V1.T @ V2))
        np.testing.assert_allclose(np.linalg.svd(S)[1], 1.0, atol=1e-3)

    def test_edc_embedding_distributed_matches_core(self):
        from repro.core import measures
        key = jax.random.PRNGKey(8)
        dW = jax.random.normal(key, (16, 400))
        E1, _ = measures.edc_embed(dW, 3, key=key)
        E2, _ = fp.edc_embedding_distributed(dW, 3, key=key,
                                             qr_impl="cholesky")
        # embeddings live in the same subspace: pairwise distances agree
        d1 = np.asarray(jnp.linalg.norm(E1[:, None] - E1[None], axis=-1))
        d2 = np.asarray(jnp.linalg.norm(E2[:, None] - E2[None], axis=-1))
        np.testing.assert_allclose(d1, d2, atol=5e-3, rtol=5e-2)


class TestCacheSeqShardSpec:
    def test_seq_shard_rule(self):
        from jax.sharding import PartitionSpec as P
        from repro.sharding import specs as sh
        cfg = registry.get("nemotron-4-15b")          # kv=8 < 16

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        cache = jax.eval_shape(lambda: zoo.init_cache(cfg, 128, 32768))
        base = sh.cache_specs(cache, cfg, FakeMesh())
        opt = sh.cache_specs(cache, cfg, FakeMesh(), seq_shard=True)
        assert tuple(base["k"]) [2] is None            # replicated seq
        assert tuple(opt["k"])[2] == "model"           # sharded seq
        # glm4 kv=2: same story
        cfg2 = registry.get("glm4-9b")
        cache2 = jax.eval_shape(lambda: zoo.init_cache(cfg2, 128, 1024))
        opt2 = sh.cache_specs(cache2, cfg2, FakeMesh(), seq_shard=True)
        assert tuple(opt2["k"])[2] == "model"
        # hubert-style kv=16 would shard heads instead (divisible)
        cfg3 = registry.get("zamba2-1.2b")             # kv=32 divisible
        cache3 = jax.eval_shape(lambda: zoo.init_cache(cfg3, 128, 1024))
        spec3 = sh.cache_specs(cache3, cfg3, FakeMesh(), seq_shard=True)
        assert tuple(spec3["shared_attn"]["k"])[3] == "model"

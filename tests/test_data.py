"""Data pipeline: generators, partitioners, padding containers."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import generators as gen
from repro.data.federated import power_law_sizes


class TestMnistLike:
    def test_shapes_and_ranges(self):
        d = gen.mnist_like(seed=0, n_clients=50, classes_per_client=2,
                           total_train=3000, dim=64)
        assert d.n_clients == 50
        assert d.x_train.shape[0] == 50 and d.x_train.shape[2] == 64
        assert d.y_train.max() < 10 and d.y_train.min() >= 0
        assert np.all(d.n_train > 0)

    def test_label_skew(self):
        d = gen.mnist_like(seed=0, n_clients=40, classes_per_client=2,
                           total_train=3000, dim=32)
        for i in range(d.n_clients):
            c = d.client(i)
            classes = np.unique(np.concatenate([c["y"], c["y_test"]]))
            assert len(classes) <= 2

    def test_iid_when_all_classes(self):
        d = gen.mnist_like(seed=0, n_clients=20, classes_per_client=10,
                           total_train=4000, dim=32)
        more_than_5 = sum(len(np.unique(d.client(i)["y"])) > 5
                          for i in range(20))
        assert more_than_5 > 10

    def test_deterministic(self):
        a = gen.mnist_like(seed=3, n_clients=10, total_train=500, dim=16)
        b = gen.mnist_like(seed=3, n_clients=10, total_train=500, dim=16)
        np.testing.assert_array_equal(a.x_train, b.x_train)
        c = gen.mnist_like(seed=4, n_clients=10, total_train=500, dim=16)
        assert not np.array_equal(a.x_train, c.x_train)


class TestSynthetic:
    def test_paper_dims(self):
        d = gen.synthetic(1.0, 1.0, seed=0, n_clients=30)
        assert d.x_train.shape[2] == 60 and d.n_classes == 10

    def test_alpha_increases_heterogeneity(self):
        """Larger alpha -> client optima differ more -> labels differ more
        across clients for the same x region (proxy: per-client label hists)."""
        lo = gen.synthetic(0.0, 0.0, seed=0, n_clients=30)
        hi = gen.synthetic(2.0, 2.0, seed=0, n_clients=30)

        def hist_spread(d):
            hists = []
            for i in range(d.n_clients):
                y = d.client(i)["y"]
                h = np.bincount(y, minlength=10) / max(len(y), 1)
                hists.append(h)
            return np.std(np.stack(hists), axis=0).mean()
        assert hist_spread(hi) > hist_spread(lo)


class TestSent140Like:
    def test_shapes(self):
        d = gen.sent140_like(seed=0, n_clients=30, total_train=2000)
        assert d.n_classes == 2
        assert d.x_train.shape[2] == 25
        assert set(np.unique(d.y_train)) <= {0, 1}

    def test_lexicon_signal_exists(self):
        """A linear probe on token counts should beat chance, i.e. the
        synthetic sentiment labels are learnable."""
        d = gen.sent140_like(seed=0, n_clients=50, total_train=4000, vocab=200)
        X, Y = [], []
        for i in range(d.n_clients):
            c = d.client(i)
            for x, y in zip(c["x"], c["y"]):
                bow = np.bincount(x.astype(int), minlength=200)
                X.append(bow)
                Y.append(y)
        X, Y = np.stack(X).astype(float), np.asarray(Y)
        X -= X.mean(0)
        w = np.linalg.lstsq(X.T @ X + 10 * np.eye(200), X.T @ (Y * 2 - 1),
                            rcond=None)[0]
        acc = (((X @ w) > 0) == Y).mean()
        assert acc > 0.7


class TestFemnistLike:
    def test_writer_styles(self):
        d = gen.femnist_like(seed=0, n_clients=40, total_train=3000, dim=64,
                             n_styles=3)
        assert "style_of" in d.meta
        assert d.n_classes == 62


class TestPowerLaw:
    @given(st.integers(0, 500))
    @settings(max_examples=10, deadline=None)
    def test_bounds(self, seed):
        rng = np.random.default_rng(seed)
        s = power_law_sizes(rng, 100, 10000, min_size=10, max_size=512)
        assert s.min() >= 10 and s.max() <= 512 and len(s) == 100

    def test_skewed(self):
        rng = np.random.default_rng(0)
        s = power_law_sizes(rng, 1000, 100000)
        assert np.median(s) < s.mean()   # heavy right tail

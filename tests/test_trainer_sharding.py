"""Sharded client axis in the serial trainers (fed/parallel.py helpers).

The executor's mesh path (client axis sharded over "data") must agree with
the 1-device jit path. Multi-device coverage runs in a subprocess with
forced host devices — the main test process must keep seeing the single
real CPU device (see conftest.py).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np

from repro.fed import parallel as fp

_DRIVER = r"""
import json, jax
from repro.data.generators import mnist_like
from repro.models.paper_models import mclr
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer

data = mnist_like(seed=0, n_clients=16, classes_per_client=2,
                  total_train=1200, dim=16)
model = mclr(16, 10)
cfg = FedConfig(n_rounds=2, clients_per_round=8, local_epochs=3,
                batch_size=10, lr=0.05, n_groups=2, pretrain_scale=2, seed=0)
out = {"devices": jax.device_count()}
for cls in (FedAvgTrainer, IFCATrainer, FeSEMTrainer):
    tr = cls(model, data, cfg)
    out[cls.framework + "_meshed"] = tr.mesh is not None
    h = tr.run(2)
    out[cls.framework] = [[r.weighted_acc, r.discrepancy] for r in h.rounds]
print(json.dumps(out))
"""


def _run_driver(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


class TestShardingHelpers:
    def test_default_mesh_is_none_on_single_device(self):
        assert jax.device_count() == 1      # conftest contract
        assert fp.default_data_mesh() is None

    def test_sharded_executor_single_device_is_plain_jit(self, tiny_model,
                                                         tiny_fed_data,
                                                         fast_cfg):
        from repro.fed.engine import FedAvgTrainer
        tr = FedAvgTrainer(tiny_model, tiny_fed_data, fast_cfg)
        assert tr.mesh is None
        m = tr.round(0)
        assert np.isfinite(m.weighted_acc)


class TestMultiDeviceEquivalence:
    def test_sharded_trainers_match_single_device(self):
        """4-way client-axis sharding reproduces the 1-device trajectories
        for the static (FedAvg) and dynamic (IFCA/FeSEM) executors."""
        single = _run_driver(1)
        sharded = _run_driver(4)
        assert single["devices"] == 1 and sharded["devices"] == 4
        for fw in ("fedavg", "ifca", "fesem"):
            assert not single[fw + "_meshed"]
            assert sharded[fw + "_meshed"]
            np.testing.assert_allclose(np.asarray(single[fw]),
                                       np.asarray(sharded[fw]),
                                       atol=2e-3,
                                       err_msg=f"{fw} diverged under mesh")

"""Single-dispatch round executor vs the seed per-group loop (fed/rounds.py).

The fused round (one vmapped solve + segment-sum aggregation) must reproduce
the seed implementation's group parameters, update directions, and
discrepancy metric to fp tolerance when both draw the same per-client keys.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed import client as client_lib
from repro.fed import rounds, server as server_lib
from repro.models.paper_models import mclr


def _setup(m=3, K=12, max_n=20, dim=6, seed=0):
    key = jax.random.PRNGKey(seed)
    model = mclr(dim, 4)
    params = model.init(key)
    gp_list = [jax.tree_util.tree_map(lambda l, j=j: l + 0.02 * j, params)
               for j in range(m)]
    ks = jax.random.split(key, 4)
    X = jax.random.normal(ks[0], (K, max_n, dim))
    Y = jax.random.randint(ks[1], (K, max_n), 0, 4)
    n = jnp.asarray(np.full(K, max_n, np.int32))
    membership = np.asarray([i % m for i in range(K)])
    keys = jax.random.split(ks[2], K)
    return model, gp_list, membership, X, Y, n, keys


def _run_both(model, gp_list, membership, X, Y, n, keys, *, eta_g=0.0,
              epochs=2, batch=5, mu=0.0):
    m = len(gp_list)
    max_n = X.shape[1]
    exec_fn = jax.jit(rounds.make_round_executor(
        model, epochs=epochs, batch_size=batch, lr=0.05, mu=mu, n_groups=m,
        max_samples=max_n, eta_g=eta_g))
    out = exec_fn(rounds.stack_trees(gp_list),
                  jnp.asarray(membership, jnp.int32), X, Y, n, keys)

    solver = client_lib.make_batch_solver(
        model, epochs=epochs, batch_size=batch, lr=0.05, mu=mu,
        max_samples=max_n)
    ref = rounds.serial_reference_round(
        solver, gp_list, membership, X, Y, n, keys, eta_g=eta_g)
    return out, ref


class TestSingleDispatchEquivalence:
    @pytest.mark.parametrize("eta_g", [0.0, 0.05])
    def test_matches_seed_loop(self, eta_g):
        args = _setup()
        out, (ref_groups, ref_global, ref_delta, ref_disc) = _run_both(
            *args, eta_g=eta_g)
        m = len(ref_groups)
        for j in range(m):
            got = server_lib.tree_index(out.group_params, j)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(ref_groups[j])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5, rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(out.global_params),
                        jax.tree_util.tree_leaves(ref_global)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out.group_delta_flat),
                                   np.asarray(ref_delta), atol=1e-5)
        assert float(out.discrepancy) == pytest.approx(ref_disc, abs=1e-4)

    def test_matches_seed_loop_with_prox(self):
        args = _setup(seed=3)
        out, (ref_groups, _, _, ref_disc) = _run_both(*args, mu=0.1)
        for j in range(len(ref_groups)):
            got = server_lib.tree_index(out.group_params, j)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(ref_groups[j])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5, rtol=1e-5)
        assert float(out.discrepancy) == pytest.approx(ref_disc, abs=1e-4)

    def test_empty_group_stays_put(self):
        model, gp_list, membership, X, Y, n, keys = _setup(m=4)
        membership = np.zeros_like(membership)        # group 1..3 empty
        out, (ref_groups, _, ref_delta, _) = _run_both(
            model, gp_list, membership, X, Y, n, keys)
        for j in (1, 2, 3):
            got = server_lib.tree_index(out.group_params, j)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(gp_list[j])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(np.asarray(out.group_delta_flat[1:]), 0.0)
        np.testing.assert_allclose(np.asarray(out.group_delta_flat),
                                   np.asarray(ref_delta), atol=1e-5)

    def test_mean_loss_is_weighted_final_loss(self):
        """RoundOutput.mean_loss == n_i-weighted mean of each client's
        final-model train loss (recomputed out-of-program)."""
        model, gp_list, membership, X, Y, n, keys = _setup()
        out, _ = _run_both(model, gp_list, membership, X, Y, n, keys)
        solver = client_lib.make_batch_solver(
            model, epochs=2, batch_size=5, lr=0.05, mu=0.0,
            max_samples=X.shape[1])
        my = [gp_list[g] for g in membership]
        finals = []
        for i in range(X.shape[0]):
            _, f = solver(my[i], X[i:i+1], Y[i:i+1], n[i:i+1], keys[i:i+1])
            finals.append(jax.tree_util.tree_map(lambda l: l[0], f))
        loss_one = client_lib.client_mean_loss(model)
        losses = np.array([float(loss_one(f, X[i], Y[i], n[i]))
                           for i, f in enumerate(finals)])
        w = np.asarray(n, np.float64)
        expect = float((losses * w).sum() / w.sum())
        assert float(out.mean_loss) == pytest.approx(expect, rel=1e-4)

    def test_single_group_is_fedavg(self):
        """m=1 executor ≡ plain FedAvg aggregation (the consensus path)."""
        model, gp_list, membership, X, Y, n, keys = _setup(m=1, K=8)
        out, (ref_groups, ref_global, _, ref_disc) = _run_both(
            model, gp_list, np.zeros(8, np.int64), X, Y, n, keys)
        for a, b in zip(jax.tree_util.tree_leaves(out.global_params),
                        jax.tree_util.tree_leaves(ref_groups[0])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
        assert float(out.discrepancy) == pytest.approx(ref_disc, abs=1e-4)


class TestTrainerIntegration:
    def test_fedgroup_round_is_one_executor_dispatch(self, tiny_model,
                                                     tiny_fed_data, fast_cfg):
        """The trainer's round goes through the shared executor exactly once."""
        from repro.core.fedgroup import FedGroupTrainer
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        tr.group_cold_start()
        calls = []
        real = tr._round_executor()

        def spy(*args, **kw):
            calls.append(1)
            return real(*args, **kw)

        tr._round_exec = spy
        tr.round(0)
        assert len(calls) == 1

    def test_fedgroup_stacked_state_shapes(self, tiny_model, tiny_fed_data,
                                           fast_cfg):
        from repro.core.fedgroup import FedGroupTrainer
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        tr.round(0)
        for leaf in jax.tree_util.tree_leaves(tr.group_params):
            assert leaf.shape[0] == tr.m
        assert tr.group_delta.shape[0] == tr.m
        assert np.all(np.isfinite(np.asarray(tr.group_delta)))

"""Fused dynamic-assignment rounds (IFCA argmin-loss, FeSEM ℓ2 E-step) vs
the retired estimate-then-loop baselines (fed/rounds.py serial oracles).

The executor's in-program assignment stage must reproduce the host-side
per-group loop on membership, group parameters, persistent state, and the
discrepancy metric — including rounds where a cluster gets zero members.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed import client as client_lib
from repro.fed import rounds, server as server_lib
from repro.fed.fesem import FeSEMTrainer, fesem_state_update, make_fesem_assign
from repro.fed.ifca import IFCATrainer, make_ifca_assign
from repro.models.modules import flatten_updates
from repro.models.paper_models import mclr


def _setup(m=3, K=12, max_n=20, dim=6, n_classes=4, seed=0, spread=0.3):
    """Group models far apart + each client's labels drawn from one group's
    predictions, so argmin-loss/argmin-ℓ2 spread clients across clusters."""
    key = jax.random.PRNGKey(seed)
    model = mclr(dim, n_classes)
    params = model.init(key)
    ks = jax.random.split(key, m + 3)
    gp_list = [jax.tree_util.tree_map(
        lambda l, k=ks[j]: l + spread * jax.random.normal(k, l.shape),
        params) for j in range(m)]
    X = jax.random.normal(ks[m], (K, max_n, dim))
    # client i's labels come from group (i % m)'s model -> that group's CE
    # is lowest, giving every cluster members under IFCA's estimate
    Y = jnp.stack([
        jnp.argmax(model.apply(gp_list[i % m], X[i]), -1)
        for i in range(K)])
    n = jnp.full((K,), max_n, jnp.int32)
    keys = jax.random.split(ks[m + 1], K)
    return model, gp_list, X, Y, n, keys


def _assert_groups_close(stacked, ref_list, atol=1e-5):
    for j in range(len(ref_list)):
        got = server_lib.tree_index(stacked, j)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(ref_list[j])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=atol, rtol=atol)


class TestFusedIFCA:
    def _run_both(self, model, gp_list, X, Y, n, keys, *, epochs=2, batch=5):
        m, max_n = len(gp_list), X.shape[1]
        fused = jax.jit(rounds.make_round_executor(
            model, epochs=epochs, batch_size=batch, lr=0.05, mu=0.0,
            n_groups=m, max_samples=max_n,
            assign_fn=make_ifca_assign(model)))
        out = fused(rounds.stack_trees(gp_list), None, X, Y, n, keys)
        solver = client_lib.make_batch_solver(
            model, epochs=epochs, batch_size=batch, lr=0.05, mu=0.0,
            max_samples=max_n)
        loss_fn = client_lib.make_loss_eval_fn(model)
        ref = rounds.serial_ifca_round(solver, loss_fn, gp_list, X, Y, n,
                                       keys)
        return out, ref

    def test_matches_serial_oracle(self):
        args = _setup()
        out, (ref_groups, ref_mem, ref_disc) = self._run_both(*args)
        assert np.array_equal(np.asarray(out.membership), ref_mem)
        assert len(np.unique(ref_mem)) == 3      # every cluster estimated
        _assert_groups_close(out.group_params, ref_groups)
        assert float(out.discrepancy) == pytest.approx(ref_disc, abs=1e-4)

    def test_zero_member_cluster(self):
        """A cluster no client picks keeps its parameters unchanged."""
        model, gp_list, X, Y, n, keys = _setup(m=4, K=6)
        # labels from groups 0..2 only -> cluster 3 attracts nobody
        Y = jnp.stack([
            jnp.argmax(model.apply(gp_list[i % 3], X[i]), -1)
            for i in range(6)])
        out, (ref_groups, ref_mem, _) = self._run_both(
            model, gp_list, X, Y, n, keys)
        assert np.array_equal(np.asarray(out.membership), ref_mem)
        assert 3 not in ref_mem
        _assert_groups_close(out.group_params, ref_groups)
        for a, b in zip(
                jax.tree_util.tree_leaves(
                    server_lib.tree_index(out.group_params, 3)),
                jax.tree_util.tree_leaves(gp_list[3])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))


class TestFusedFeSEM:
    def _run_both(self, model, gp_list, local_flat, X, Y, n, keys, *,
                  epochs=2, batch=5):
        m, max_n = len(gp_list), X.shape[1]
        K = X.shape[0]
        fused = jax.jit(rounds.make_round_executor(
            model, epochs=epochs, batch_size=batch, lr=0.05, mu=0.0,
            n_groups=m, max_samples=max_n, assign_fn=make_fesem_assign(),
            state_update_fn=fesem_state_update))
        state = {"local_flat": jnp.asarray(local_flat),
                 "idx": jnp.arange(K, dtype=jnp.int32)}
        out = fused(rounds.stack_trees(gp_list), state, X, Y, n, keys)
        solver = client_lib.make_batch_solver(
            model, epochs=epochs, batch_size=batch, lr=0.05, mu=0.0,
            max_samples=max_n)
        ref = rounds.serial_fesem_round(solver, gp_list, local_flat, X, Y,
                                        n, keys)
        return out, ref

    def _local_flat(self, gp_list, K):
        """Each client's last local model near group (i % m)'s center."""
        m = len(gp_list)
        centers = np.stack([np.asarray(flatten_updates(p)) for p in gp_list])
        return np.stack([centers[i % m] + 1e-3 for i in range(K)])

    def test_matches_serial_oracle(self):
        model, gp_list, X, Y, n, keys = _setup()
        lf = self._local_flat(gp_list, X.shape[0])
        out, (ref_groups, ref_mem, ref_local, ref_disc) = self._run_both(
            model, gp_list, lf, X, Y, n, keys)
        assert np.array_equal(np.asarray(out.membership), ref_mem)
        assert len(np.unique(ref_mem)) == 3
        _assert_groups_close(out.group_params, ref_groups)
        np.testing.assert_allclose(
            np.asarray(out.assign_state["local_flat"]), ref_local, atol=1e-5)
        assert float(out.discrepancy) == pytest.approx(ref_disc, abs=1e-4)

    def test_zero_member_cluster_keeps_center(self):
        model, gp_list, X, Y, n, keys = _setup(m=4, K=6)
        centers = np.stack([np.asarray(flatten_updates(p)) for p in gp_list])
        lf = np.stack([centers[i % 3] + 1e-3 for i in range(6)])  # skip 3
        out, (ref_groups, ref_mem, _, _) = self._run_both(
            model, gp_list, lf, X, Y, n, keys)
        assert np.array_equal(np.asarray(out.membership), ref_mem)
        assert 3 not in ref_mem
        _assert_groups_close(out.group_params, ref_groups)
        for a, b in zip(
                jax.tree_util.tree_leaves(
                    server_lib.tree_index(out.group_params, 3)),
                jax.tree_util.tree_leaves(gp_list[3])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_scatter_only_touches_selected_rows(self):
        """The in-program scatter updates exactly the selected clients'
        rows of the persistent local_flat matrix."""
        model, gp_list, X, Y, n, keys = _setup(K=4)
        N = 10
        centers = np.stack([np.asarray(flatten_updates(p)) for p in gp_list])
        lf_all = np.tile(centers[0], (N, 1)).astype(np.float32)
        idx = np.asarray([1, 4, 7, 9])
        fused = jax.jit(rounds.make_round_executor(
            model, epochs=1, batch_size=5, lr=0.05, mu=0.0, n_groups=3,
            max_samples=X.shape[1], assign_fn=make_fesem_assign(),
            state_update_fn=fesem_state_update))
        state = {"local_flat": jnp.asarray(lf_all),
                 "idx": jnp.asarray(idx, jnp.int32)}
        out = fused(rounds.stack_trees(gp_list), state, X, Y, n, keys)
        new_lf = np.asarray(out.assign_state["local_flat"])
        untouched = np.setdiff1d(np.arange(N), idx)
        np.testing.assert_allclose(new_lf[untouched], lf_all[untouched])
        assert not np.allclose(new_lf[idx], lf_all[idx])


class TestTrainerDispatch:
    @pytest.mark.parametrize("cls", [IFCATrainer, FeSEMTrainer])
    def test_round_is_one_executor_dispatch(self, cls, tiny_model,
                                            tiny_fed_data, fast_cfg):
        """IFCA/FeSEM rounds go through the fused executor exactly once —
        no per-group Python loop, no separate estimation dispatch."""
        tr = cls(tiny_model, tiny_fed_data, fast_cfg)
        calls = []
        real = tr._round_executor()

        def spy(*args, **kw):
            calls.append(1)
            return real(*args, **kw)

        tr._round_exec = spy
        tr.round(0)
        assert len(calls) == 1

    def test_fesem_local_flat_stays_on_device(self, tiny_model,
                                              tiny_fed_data, fast_cfg):
        tr = FeSEMTrainer(tiny_model, tiny_fed_data, fast_cfg)
        assert isinstance(tr.local_flat, jax.Array)
        tr.round(0)
        assert isinstance(tr.local_flat, jax.Array)
        assert tr.local_flat.shape[0] == tiny_fed_data.n_clients

    def test_ifca_membership_synced_from_round_output(self, tiny_model,
                                                      tiny_fed_data,
                                                      fast_cfg):
        tr = IFCATrainer(tiny_model, tiny_fed_data, fast_cfg)
        tr.round(0)
        assert np.any(tr.membership >= 0)
        assert np.all(tr.membership[tr.membership >= 0] < fast_cfg.n_groups)

"""Docs stay executable and unbroken (PR 4 satellites).

Runs the same two checks as the ``docs`` gate entry
(benchmarks/docs_check.py) under pytest: every doctest embedded in the
documented module docstrings passes, and every repo path referenced from
README.md / docs/*.md exists — so a renamed file or a stale example fails
tier-1 before it fails CI.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import docs_check  # noqa: E402

_REPO = os.path.join(os.path.dirname(__file__), "..")


class TestDoctests:
    def test_documented_modules_doctests_pass(self):
        tested = docs_check.run_doctests()
        assert set(tested) == set(docs_check.DOCUMENTED_MODULES)
        # the docstrings actually carry executable examples
        assert sum(tested.values()) >= 2

    def test_doctest_failure_is_detected(self, monkeypatch):
        """The checker reports failures instead of counting attempts."""
        import types
        bad = types.ModuleType("bad_doc_mod")
        bad.__doc__ = ">>> 1 + 1\n3\n"
        monkeypatch.setitem(sys.modules, "bad_doc_mod", bad)
        monkeypatch.setattr(docs_check, "DOCUMENTED_MODULES",
                            ("bad_doc_mod",))
        with pytest.raises(RuntimeError, match="doctest failure"):
            docs_check.run_doctests()


class TestDocLinks:
    def test_all_referenced_paths_exist(self):
        links = docs_check.check_doc_links()
        assert links["files"] == len(docs_check.DOC_FILES)
        assert links["refs"] > 10           # the docs actually cross-link

    def test_required_docs_exist(self):
        for doc in ("README.md", "docs/architecture.md", "docs/scaling.md",
                    "docs/benchmarks.md", "docs/observability.md"):
            assert os.path.exists(os.path.join(_REPO, doc)), doc

    def test_reference_extraction(self):
        md = ("see [the roadmap](ROADMAP.md) and `src/repro/fed/rounds.py`; "
              "`fed/store.py` resolves under src/repro; prose like "
              "`m=5/K=50` or `a + b` is not a path; `BENCH_*.json` globs.")
        refs = docs_check.referenced_paths(md)
        assert "ROADMAP.md" in refs
        assert "src/repro/fed/rounds.py" in refs
        assert "fed/store.py" in refs
        assert "BENCH_*.json" in refs
        assert not any("m=5" in r or "+" in r for r in refs)

    def test_missing_reference_trips(self, tmp_path, monkeypatch):
        doc = tmp_path / "README.md"
        doc.write_text("points at `src/repro/fed/gone_forever.py`")
        monkeypatch.setattr(docs_check, "_REPO", str(tmp_path))
        monkeypatch.setattr(docs_check, "DOC_FILES", ("README.md",))
        with pytest.raises(RuntimeError, match="gone_forever"):
            docs_check.check_doc_links()

    def test_readme_names_the_bench_files(self):
        with open(os.path.join(_REPO, "README.md")) as f:
            readme = f.read()
        for bench in ("BENCH_round_exec.json", "BENCH_clustering.json",
                      "BENCH_population.json"):
            assert bench in readme, f"README must link {bench}"

"""Round-block execution: scan-fused multi-round dispatch vs per-round.

The load-bearing property (same style as the streamed==pinned proofs in
tests/test_population.py): a run with ``FedConfig.block_size > 1`` stages
cohorts + keys on the host and dispatches B rounds as ONE compiled scan
with a donated carry — and must reproduce the per-round path bit for bit
(identical History metrics, params, membership, persistent state) for the
static (FedAvg, FedGroup) and dynamic (IFCA, FeSEM) frameworks alike,
since block and per-round paths share the same round core and the same
fused grouped-eval program.

Also covers the satellites: ``FedConfig.eval_every`` cadence,
``History.rounds_to_reach``/``max_acc`` NaN handling, the ``dropout_rate``
zero-weight padding path (padded cohort == variable-size cohort), and the
single-dispatch grouped eval.
"""
import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig, History, RoundMetrics
from repro.fed.fesem import FeSEMTrainer
from repro.fed.ifca import IFCATrainer


@pytest.fixture(scope="module")
def small_data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


@pytest.fixture(scope="module")
def small_model():
    from repro.models.paper_models import mclr
    return mclr(16, 10)


def _cfg(**kw):
    base = dict(n_rounds=6, clients_per_round=8, local_epochs=2,
                batch_size=5, lr=0.05, n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _run_both(cls, model, data, rounds=6, **cfg_kw):
    """Same seed, same config — only block_size differs."""
    per_round = cls(model, data, _cfg(**cfg_kw))
    h_pr = per_round.run(rounds)
    blocked = cls(model, data, _cfg(block_size=4, **cfg_kw))
    h_bl = blocked.run(rounds)
    return per_round, h_pr, blocked, h_bl


class TestBlockBitIdentity:
    """block_size=4 over 6 rounds: a full block, a partial tail, and (for
    FedGroup) per-round breaks on cold-start host events in between."""

    def test_fedavg(self, small_model, small_data):
        a, ha, b, hb = _run_both(FedAvgTrainer, small_model, small_data)
        assert ha.rounds == hb.rounds
        _assert_tree_equal(a.params, b.params)
        assert a.comm_params == b.comm_params

    def test_fedgroup(self, small_model, small_data):
        a, ha, b, hb = _run_both(FedGroupTrainer, small_model, small_data)
        assert ha.rounds == hb.rounds
        _assert_tree_equal(a.group_params, b.group_params)
        _assert_tree_equal(a.params, b.params)
        np.testing.assert_array_equal(a.membership, b.membership)
        # eq.-9 cold start keeps working between blocks: the latest update
        # directions came out of the block carry
        np.testing.assert_array_equal(np.asarray(a.group_delta),
                                      np.asarray(b.group_delta))
        assert a.comm_params == b.comm_params

    def test_ifca(self, small_model, small_data):
        a, ha, b, hb = _run_both(IFCATrainer, small_model, small_data)
        assert ha.rounds == hb.rounds
        _assert_tree_equal(a.group_params, b.group_params)
        np.testing.assert_array_equal(a.membership, b.membership)
        assert a.comm_params == b.comm_params     # m× broadcast accounting

    def test_fesem(self, small_model, small_data):
        a, ha, b, hb = _run_both(FeSEMTrainer, small_model, small_data)
        assert ha.rounds == hb.rounds
        _assert_tree_equal(a.group_params, b.group_params)
        np.testing.assert_array_equal(a.membership, b.membership)
        # the carried (N, d_w) local-model matrix round-trips the block
        np.testing.assert_array_equal(np.asarray(a.local_flat),
                                      np.asarray(b.local_flat))

    def test_single_block_dispatch(self, small_model, small_data):
        """4 staged rounds go through the block executor exactly once."""
        tr = FedAvgTrainer(small_model, small_data, _cfg(block_size=4))
        calls = []
        real = tr._block_executor()
        tr._block_exec = lambda *a, **k: (calls.append(1), real(*a, **k))[1]
        tr.run(4)
        assert len(calls) == 1
        assert len(tr.history.rounds) == 4


class TestDropoutPadding:
    """dropout_rate cohorts pad to K with zero-weight clients so the scan
    shapes stay static — the padded cohort must equal the per-round path's
    variable-size cohort (same keys for the alive prefix, weight-0 lanes
    contribute nothing to aggregation, metrics, or state scatters)."""

    @pytest.mark.parametrize("cls", [FedAvgTrainer, FedGroupTrainer])
    def test_padded_equals_variable_size(self, cls, small_model, small_data):
        a, ha, b, hb = _run_both(cls, small_model, small_data,
                                 dropout_rate=0.3)
        assert [r.weighted_acc for r in ha.rounds] == \
            [r.weighted_acc for r in hb.rounds]
        np.testing.assert_allclose(
            [r.mean_loss for r in ha.rounds],
            [r.mean_loss for r in hb.rounds], rtol=1e-6)
        np.testing.assert_allclose(
            [r.discrepancy for r in ha.rounds],
            [r.discrepancy for r in hb.rounds], rtol=1e-6)
        for la, lb in zip(jax.tree_util.tree_leaves(a.params),
                          jax.tree_util.tree_leaves(b.params)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-6)
        # comm accounting counts only the alive clients
        assert a.comm_params == b.comm_params

    def test_fesem_padded_scatter_hits_trash_row_only(self, small_model,
                                                      small_data):
        """Zero-weight lanes scatter to the carry's trash row: the real
        rows of local_flat match the per-round path."""
        a, _, b, _ = _run_both(FeSEMTrainer, small_model, small_data,
                               dropout_rate=0.3)
        np.testing.assert_allclose(np.asarray(a.local_flat),
                                   np.asarray(b.local_flat), atol=1e-6)
        np.testing.assert_array_equal(a.membership, b.membership)


class TestEvalCadence:
    def test_eval_every_records_nan_off_cadence(self, small_model,
                                                small_data):
        tr = FedAvgTrainer(small_model, small_data, _cfg(eval_every=2))
        h = tr.run(4)
        pattern = [math.isnan(r.weighted_acc) for r in h.rounds]
        assert pattern == [True, False, True, False]
        assert all(np.isfinite(r.mean_loss) for r in h.rounds)

    def test_block_cadence_matches_per_round(self, small_model, small_data):
        a, ha, b, hb = _run_both(FedAvgTrainer, small_model, small_data,
                                 eval_every=3)
        assert [math.isnan(r.weighted_acc) for r in ha.rounds] == \
            [math.isnan(r.weighted_acc) for r in hb.rounds]
        evals_a = [r.weighted_acc for r in ha.rounds
                   if not math.isnan(r.weighted_acc)]
        evals_b = [r.weighted_acc for r in hb.rounds
                   if not math.isnan(r.weighted_acc)]
        assert evals_a == evals_b and len(evals_a) == 2

    def test_default_cadence_unchanged(self, small_model, small_data):
        """eval_every=1 (the paper tables) evaluates every round."""
        tr = FedAvgTrainer(small_model, small_data, _cfg())
        h = tr.run(2)
        assert all(not math.isnan(r.weighted_acc) for r in h.rounds)


class TestHistoryAggregates:
    def test_rounds_to_reach(self):
        h = History()
        for t, acc in enumerate([0.1, 0.4, 0.35, 0.6]):
            h.add(RoundMetrics(t, acc, 1.0, 0.0))
        assert h.rounds_to_reach(0.4) == 1
        assert h.rounds_to_reach(0.5) == 3
        assert h.rounds_to_reach(0.9) is None

    def test_nan_rounds_are_ignored(self):
        h = History()
        h.add(RoundMetrics(0, float("nan"), 1.0, 0.0))
        h.add(RoundMetrics(1, 0.7, 1.0, 0.0))
        h.add(RoundMetrics(2, float("nan"), 1.0, 0.0))
        assert h.max_acc == 0.7
        assert h.rounds_to_reach(0.5) == 1

    def test_empty_history(self):
        assert History().max_acc == 0.0
        assert History().rounds_to_reach(0.1) is None


class TestFusedGroupedEval:
    def test_single_dispatch_regardless_of_m(self, small_model, small_data):
        """evaluate_groups is ONE call into the fused grouped-eval program
        (the retired path was m dispatches + host accumulation)."""
        tr = FedGroupTrainer(small_model, small_data, _cfg(n_groups=3))
        tr.round(0)
        calls = []
        real = tr._grouped_eval_fn()
        tr._grouped_eval = lambda *a: (calls.append(1), real(*a))[1]
        tr.evaluate_groups()
        assert len(calls) == 1

    def test_matches_per_group_loop(self, small_model, small_data):
        """The fused integer counts reproduce the retired m-dispatch host
        loop exactly (clients with membership -1 excluded from both)."""
        tr = FedGroupTrainer(small_model, small_data, _cfg())
        tr.round(0)
        got = tr.evaluate_groups()
        total_correct, total_n = 0, 0
        xt, yt, nt = tr._test_stack
        for j in range(tr.m):
            members = np.where(tr.membership == j)[0]
            if len(members) == 0:
                continue
            sel = jnp.asarray(members.astype(np.int32))
            correct = tr.eval_fn(tr.group_param(j), xt[sel], yt[sel],
                                 nt[sel])
            total_correct += int(np.sum(np.asarray(correct)))
            total_n += int(tr.data.n_test[members].sum())
        assert got == total_correct / max(total_n, 1)

    def test_cold_clients_excluded(self, small_model, small_data):
        """membership -1 contributes to neither numerator nor denominator."""
        from repro.fed.client import grouped_eval_correct
        fn = jax.jit(grouped_eval_correct(small_model))
        tr = FedGroupTrainer(small_model, small_data, _cfg())
        tr.round(0)
        xt, yt, nt = tr._test_stack
        mem = np.full(tr.n_clients, -1, np.int32)
        c, tot = fn(tr.group_params, jnp.asarray(mem), xt, yt, nt)
        assert int(c) == 0 and int(tot) == 0


_MESH_DRIVER = r"""
import json, sys
import jax
import numpy as np
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.launch.mesh import make_fed_mesh
from repro.models.paper_models import mclr

data_ax, model_ax = json.loads(sys.argv[1])
data = mnist_like(seed=0, n_clients=16, classes_per_client=2,
                  total_train=1200, dim=16)
model = mclr(16, 10)
mesh = make_fed_mesh(data_ax, model_ax)
base = dict(n_rounds=4, clients_per_round=8, local_epochs=2,
            batch_size=10, lr=0.05, n_groups=2, pretrain_scale=8, seed=0)
out = {"devices": jax.device_count()}
for cls in (FedAvgTrainer, FedGroupTrainer):
    pr = cls(model, data, FedConfig(**base), mesh=mesh)
    h_pr = pr.run(4)
    bl = cls(model, data, FedConfig(**base, block_size=4), mesh=mesh)
    h_bl = bl.run(4)
    fw = cls.framework
    a = np.asarray([[r.weighted_acc, r.mean_loss, r.discrepancy]
                    for r in h_pr.rounds])
    b = np.asarray([[r.weighted_acc, r.mean_loss, r.discrepancy]
                    for r in h_bl.rounds])
    out[fw + "_metric_diff"] = float(np.abs(a - b).max())
    pa = pr.group_params if fw == "fedgroup" else pr.params
    pb = bl.group_params if fw == "fedgroup" else bl.params
    out[fw + "_param_diff"] = max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(pa),
                        jax.tree_util.tree_leaves(pb)))
    if fw == "fedgroup":
        out["membership_equal"] = bool(
            np.array_equal(pr.membership, bl.membership))
print(json.dumps(out))
"""


class TestBlockOnMesh:
    """The block executor rides the same mesh placement as the per-round
    executor (pattern of tests/test_mesh2d.py: forced host devices in a
    subprocess). Block vs per-round on the SAME mesh compare within
    reduction-order tolerance — the two compiled programs may schedule
    collectives differently."""

    @pytest.mark.parametrize("axes", [(4, 1), (2, 2)],
                             ids=["1d_data", "2d_data_model"])
    def test_blocked_matches_per_round_on_mesh(self, axes):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_force_host_platform_device_count=4")
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        proc = subprocess.run(
            [sys.executable, "-c", _MESH_DRIVER, json.dumps(list(axes))],
            env=env, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr[-2000:]
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        assert out["devices"] == 4
        for fw in ("fedavg", "fedgroup"):
            assert out[fw + "_metric_diff"] < 2e-3, (fw, out)
            assert out[fw + "_param_diff"] < 2e-3, (fw, out)
        assert out["membership_equal"]

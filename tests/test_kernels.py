"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import measures
from repro.kernels import ops, ref
from repro.kernels.edc_cosine import edc_cosine
from repro.kernels.madc import madc_block
from repro.kernels.swa_attention import swa_attention


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


class TestEDCCosineKernel:
    @pytest.mark.parametrize("n,d,m", [
        (60, 7850, 3),      # MNIST-MCLR scale (paper Table 2)
        (100, 101770 // 10, 5),
        (7, 129, 2),        # unaligned everything
        (128, 2048, 16),
        (1, 64, 1),         # degenerate
        (33, 4097, 11),
    ])
    def test_shapes_vs_oracle(self, n, d, m):
        k1, k2 = jax.random.split(jax.random.PRNGKey(n * 7 + d))
        dW = jax.random.normal(k1, (n, d))
        V = jax.random.normal(k2, (d, m))
        got = ops.cosine_block(dW, V)
        np.testing.assert_allclose(got, ref.cosine_block_ref(dW, V),
                                   atol=3e-5, rtol=3e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        dW = jax.random.normal(k1, (32, 1024)).astype(dtype)
        V = jax.random.normal(k2, (1024, 4)).astype(dtype)
        got = edc_cosine(dW, V, interpret=True)
        want = ref.cosine_block_ref(dW, V)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_block_shape_invariance(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        dW = jax.random.normal(k1, (70, 3000))
        V = jax.random.normal(k2, (3000, 5))
        a = edc_cosine(dW, V, block_n=128, block_d=512, interpret=True)
        b = edc_cosine(dW, V, block_n=64, block_d=1024, interpret=True)
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_output_in_cosine_range(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        got = np.asarray(ops.cosine_block(jax.random.normal(k1, (16, 500)),
                                          jax.random.normal(k2, (500, 3))))
        assert np.all(got <= 1 + 1e-5) and np.all(got >= -1 - 1e-5)


class TestMADCBlockKernel:
    @staticmethod
    def _cosine(n, seed=0, d=64):
        dW = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
        return measures.cosine_similarity_matrix(dW)

    @pytest.mark.parametrize("n", [
        5,          # smaller than any block
        7,          # odd, degenerate n-2
        60,         # paper pre-training scale (alpha*m)
        100,        # not a multiple of 128
        130,        # crosses a block boundary -> 2x2x2 grid
    ])
    def test_shapes_vs_reference(self, n):
        M = self._cosine(n, seed=n)
        got = ops.madc_block(M)
        want = measures.madc(M)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_block_shape_invariance(self):
        M = self._cosine(100, seed=1)
        a = madc_block(M, block_n=128, block_z=128, interpret=True)
        b = madc_block(M, block_n=64, block_z=128, interpret=True)
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_measures_delegation(self):
        """measures.madc(use_kernel=True, min_kernel_n=0) forces the Pallas
        path and matches the reference."""
        M = self._cosine(33, seed=2)
        np.testing.assert_allclose(
            measures.madc(M, use_kernel=True, min_kernel_n=0),
            measures.madc(M), atol=2e-5, rtol=2e-5)

    def test_small_n_falls_back_below_crossover(self):
        """Below the measured crossover the dispatch uses the reference —
        use_kernel=True must never be slower there (it IS the reference)."""
        from repro.kernels import madc as madc_mod
        M = self._cosine(33, seed=2)
        calls = []
        real = ops.madc_block
        ops.madc_block = lambda *a, **k: calls.append(1) or real(*a, **k)
        try:
            out = measures.madc(M, use_kernel=True)
        finally:
            ops.madc_block = real
        assert calls == []                  # n=33 < crossover -> no kernel
        np.testing.assert_allclose(out, measures.madc(M), atol=1e-6)
        assert ops.madc_crossover_n() >= madc_mod.madc_tiles(33)[1]

    def test_tiles_follow_n(self):
        from repro.kernels.madc import madc_tiles
        assert madc_tiles(32) == (32, 128)      # no padding to 128 rows
        assert madc_tiles(33) == (40, 128)      # 8-row sublane granule
        assert madc_tiles(200) == (128, 256)    # 128-lane z granule
        assert madc_tiles(1000) == (128, 512)   # caps
        for n in (8, 60, 100, 130):
            bn, bz = madc_tiles(n)
            assert bn % 8 == 0 and bz % 128 == 0

    def test_symmetric_zero_diag(self):
        D = np.asarray(ops.madc_block(self._cosine(40, seed=3)))
        np.testing.assert_allclose(D, D.T, atol=1e-5)
        np.testing.assert_allclose(np.diag(D), 0.0, atol=1e-5)
        assert np.all(D >= -1e-5)


class TestSSDChunkKernel:
    @pytest.mark.parametrize("BH,NC,Q,P,N", [
        (2, 2, 16, 8, 4),
        (3, 1, 32, 64, 64),     # zamba2 dims (P=64, N=64)
        (1, 4, 64, 32, 16),
        (2, 1, 128, 64, 64),    # production chunk size
    ])
    def test_vs_recurrence_oracle(self, BH, NC, Q, P, N):
        key = jax.random.PRNGKey(BH * Q + P)
        ks = jax.random.split(key, 4)
        X = jax.random.normal(ks[0], (BH, NC, Q, P))
        dtA = -jax.nn.softplus(jax.random.normal(ks[1], (BH, NC, Q)))
        A_cs = jnp.cumsum(dtA, axis=-1)
        B = jax.random.normal(ks[2], (BH, NC, Q, N))
        C = jax.random.normal(ks[3], (BH, NC, Q, N))
        Yk, Stk = ops.ssd_chunk_block(X, A_cs, B, C)
        Yr, Str = ref.ssd_chunk_ref(
            X.reshape(BH * NC, Q, 1, P), dtA.reshape(BH * NC, Q, 1),
            B.reshape(BH * NC, Q, 1, N), C.reshape(BH * NC, Q, 1, N))
        np.testing.assert_allclose(
            Yk, Yr[:, :, 0].reshape(BH, NC, Q, P), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(
            Stk, Str[:, 0].reshape(BH, NC, P, N).transpose(0, 1, 3, 2),
            atol=2e-4, rtol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        key = jax.random.PRNGKey(42)
        ks = jax.random.split(key, 4)
        X = jax.random.normal(ks[0], (2, 2, 16, 8)).astype(dtype)
        dtA = -jax.nn.softplus(jax.random.normal(ks[1], (2, 2, 16)))
        A_cs = jnp.cumsum(dtA, -1)
        B = jax.random.normal(ks[2], (2, 2, 16, 4)).astype(dtype)
        C = jax.random.normal(ks[3], (2, 2, 16, 4)).astype(dtype)
        Yk, _ = ops.ssd_chunk_block(X, A_cs, B, C)
        Yr, _ = ref.ssd_chunk_ref(
            X.reshape(4, 16, 1, 8), dtA.reshape(4, 16, 1),
            B.reshape(4, 16, 1, 4), C.reshape(4, 16, 1, 4))
        tol = dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 \
            else dict(atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(Yk, Yr[:, :, 0].reshape(2, 2, 16, 8), **tol)

    def test_matches_model_ssd_path(self):
        """Kernel's Y_diag+states compose to the same result as the model's
        jnp ssd_chunked (first chunk, zero init)."""
        from repro.models.ssm import ssd_chunked
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 4)
        b, l, h, p, n, Q = 2, 32, 2, 8, 4, 32         # single chunk
        X = jax.random.normal(ks[0], (b, l, h, p))
        dtA = -jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        B = jax.random.normal(ks[2], (b, l, h, n))
        C = jax.random.normal(ks[3], (b, l, h, n))
        Y, fin = ssd_chunked(X, dtA, B, C, Q)
        A_cs = jnp.cumsum(dtA.transpose(0, 2, 1).reshape(b * h, 1, l), -1)
        Yk, Stk = ops.ssd_chunk_block(
            X.transpose(0, 2, 1, 3).reshape(b * h, 1, l, p), A_cs,
            B.transpose(0, 2, 1, 3).reshape(b * h, 1, l, n),
            C.transpose(0, 2, 1, 3).reshape(b * h, 1, l, n))
        np.testing.assert_allclose(
            Yk.reshape(b, h, l, p).transpose(0, 2, 1, 3), Y,
            atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(
            Stk.reshape(b, h, n, p).transpose(0, 1, 3, 2), fin,
            atol=2e-4, rtol=2e-4)


class TestSWAAttentionKernel:
    @pytest.mark.parametrize("B,Sq,Sk,H,hd,window,causal", [
        (2, 64, 64, 2, 64, None, True),
        (1, 128, 128, 4, 64, 32, True),
        (2, 1, 256, 2, 128, 64, True),      # decode tail: 1 query vs cache
        (1, 96, 96, 2, 80, None, False),    # encoder (bidirectional)
        (1, 256, 256, 1, 128, 128, True),
        (2, 33, 65, 2, 40, 16, True),       # nothing aligned
    ])
    def test_shapes_vs_oracle(self, B, Sq, Sk, H, hd, window, causal):
        ks = jax.random.split(jax.random.PRNGKey(B * Sq + Sk), 3)
        q = jax.random.normal(ks[0], (B, Sq, H, hd))
        k = jax.random.normal(ks[1], (B, Sk, H, hd))
        v = jax.random.normal(ks[2], (B, Sk, H, hd))
        got = ops.sliding_window_attention(q, k, v, window=window,
                                           causal=causal, block_q=32,
                                           block_k=32)
        want = ref.swa_attention_ref(q, k, v, window=window, causal=causal)
        np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(ks[0], (1, 64, 2, 64)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 64, 2, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 64, 2, 64)).astype(dtype)
        got = swa_attention(q, k, v, window=16, interpret=True)
        want = ref.swa_attention_ref(q, k, v, window=16)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_window_equals_full_when_large(self):
        """window >= S must equal unwindowed causal attention."""
        ks = jax.random.split(jax.random.PRNGKey(10), 3)
        q = jax.random.normal(ks[0], (1, 64, 2, 64))
        k = jax.random.normal(ks[1], (1, 64, 2, 64))
        v = jax.random.normal(ks[2], (1, 64, 2, 64))
        a = ops.sliding_window_attention(q, k, v, window=None, block_q=32, block_k=32)
        b = ops.sliding_window_attention(q, k, v, window=4096, block_q=32, block_k=32)
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_matches_model_attention_path(self):
        """Kernel agrees with the zoo's jnp attention on the same inputs."""
        from repro.models.attention import make_mask_bias, sdpa
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        q = jax.random.normal(ks[0], (2, 32, 2, 64))
        k = jax.random.normal(ks[1], (2, 32, 2, 64))
        v = jax.random.normal(ks[2], (2, 32, 2, 64))
        bias = make_mask_bias(32, 32, causal=True, window=8)
        want = sdpa(q, k, v, bias, 1 / 8.0)
        got = ops.sliding_window_attention(q, k, v, window=8, block_q=32,
                                           block_k=32)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

"""Population engine: streamed cohorts vs the pinned path, store/state-table
semantics, scheduler availability/arrivals, and the mean_loss surfacing.

The load-bearing property: a population run through the ClientStore cohort
path (host-resident store + prefetched device cohorts + per-cohort state
gather/scatter) must reproduce the pinned path bit-for-bit — same params,
same History metrics — for the static (FedAvg/FedGroup) and dynamic
(IFCA/FeSEM) frameworks alike, since both feed the identical compiled
round executor.
"""
import jax
import numpy as np
import pytest

from repro.data.generators import mnist_like, virtual_mnist_like, \
    virtual_synthetic
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.fed.population import Cohort, Population, PopulationConfig, \
    Scheduler
from repro.fed.store import ArrayClientStore, ClientStateTable


@pytest.fixture(scope="module")
def small_data():
    return mnist_like(seed=0, n_clients=40, classes_per_client=2,
                      total_train=2000, dim=16)


@pytest.fixture(scope="module")
def small_model():
    from repro.models.paper_models import mclr
    return mclr(16, 10)


def _cfg(**kw):
    base = dict(n_rounds=3, clients_per_round=8, local_epochs=2,
                batch_size=5, lr=0.05, n_groups=3, pretrain_scale=4, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _run_both(cls, model, data, cfg, rounds=3, pop_kw=None):
    pinned = cls(model, data, cfg)
    h_pin = pinned.run(rounds)
    pop = Population(ArrayClientStore(data),
                     PopulationConfig(**(pop_kw or {})))
    streamed = cls(model, None, cfg, population=pop)
    h_st = streamed.run(rounds)
    streamed.close()
    return pinned, h_pin, streamed, h_st


class TestStore:
    def test_array_store_gather_matches_data(self, small_data):
        store = ArrayClientStore(small_data)
        idx = np.array([3, 17, 0])
        x, y, n = store.gather_train(idx)
        np.testing.assert_array_equal(x, small_data.x_train[idx])
        np.testing.assert_array_equal(y, small_data.y_train[idx])
        np.testing.assert_array_equal(n, small_data.n_train[idx])
        xe, ye, ne = store.gather_test(idx)
        np.testing.assert_array_equal(xe, small_data.x_test[idx])
        np.testing.assert_array_equal(ne, small_data.n_test[idx])

    def test_virtual_store_is_lazy_and_deterministic(self):
        store = virtual_synthetic(n_clients=100_000, mean_size=20,
                                  max_size=40)
        assert store.generated_clients == 0
        idx = np.array([1, 99_999, 54_321])
        x1, y1, n1 = store.gather_train(idx)
        assert store.generated_clients == 3          # only the cohort
        # access order / repetition does not change a client's data
        x2, y2, n2 = store.gather_train(idx[::-1])
        np.testing.assert_array_equal(x1, x2[::-1])
        np.testing.assert_array_equal(y1, y2[::-1])
        assert x1.shape == (3, store.max_train, 60)
        assert (n1 <= store.max_train).all()

    def test_virtual_store_memmap_shards(self, tmp_path):
        mem = virtual_mnist_like(seed=3, n_clients=300, dim=8,
                                 mean_size=15, max_size=30,
                                 memmap_dir=str(tmp_path), shard_clients=16)
        ram = virtual_mnist_like(seed=3, n_clients=300, dim=8,
                                 mean_size=15, max_size=30)
        idx = np.array([0, 17, 255, 18])
        xtrain_mem = None
        for split in ("gather_train", "gather_test"):
            xm, ym, nm = getattr(mem, split)(idx)
            xr, yr, nr = getattr(ram, split)(idx)
            np.testing.assert_array_equal(xm, xr)
            np.testing.assert_array_equal(ym, yr)
            np.testing.assert_array_equal(nm, nr)
            if split == "gather_train":
                xtrain_mem = xm
        assert list(tmp_path.glob("xt_*.npy"))       # shards hit disk
        # a fresh store over the same dir reads shards without regenerating
        reread = virtual_mnist_like(seed=3, n_clients=300, dim=8,
                                    mean_size=15, max_size=30,
                                    memmap_dir=str(tmp_path),
                                    shard_clients=16)
        xm2, _, _ = reread.gather_train(idx)
        np.testing.assert_array_equal(xm2, xtrain_mem)
        assert reread.generated_clients == 0
        # a shard without its completion marker (killed mid-fill) is
        # regenerated instead of served as zero-filled rows
        marker = sorted(tmp_path.glob("done_*"))[0]
        marker.unlink()
        again = virtual_mnist_like(seed=3, n_clients=300, dim=8,
                                   mean_size=15, max_size=30,
                                   memmap_dir=str(tmp_path),
                                   shard_clients=16)
        xa, _, _ = again.gather_train(idx)
        np.testing.assert_array_equal(xa, xtrain_mem)
        assert again.generated_clients > 0

    def test_materialize_round_trips(self):
        store = virtual_synthetic(n_clients=25, mean_size=15, max_size=30)
        data = store.materialize()
        back = ArrayClientStore(data)
        idx = np.arange(25)
        for a, b in zip(store.gather_train(idx), back.gather_train(idx)):
            np.testing.assert_array_equal(a, b)


class TestStateTable:
    def test_membership_and_cold_flags(self):
        st = ClientStateTable(10)
        assert st.cold_mask().all()
        st.membership[[2, 5]] = 1
        np.testing.assert_array_equal(st.cold_ids(np.array([1, 2, 3, 5])),
                                      [1, 3])

    def test_lazy_local_flat_rows(self):
        st = ClientStateTable(1000)
        st.init_local_flat(np.full(4, 7.0, np.float32))
        rows = st.gather_local_flat(np.array([0, 999]))
        np.testing.assert_array_equal(rows, np.full((2, 4), 7.0))
        st.scatter_local_flat(np.array([999]), np.ones((1, 4)))
        rows = st.gather_local_flat(np.array([0, 999]))
        np.testing.assert_array_equal(rows[0], np.full(4, 7.0))
        np.testing.assert_array_equal(rows[1], np.ones(4))
        assert st.touched_rows() == 1                # memory ∝ touched

    def test_pretrain_dir_cache(self):
        st = ClientStateTable(50)
        assert st.get_pretrain_dir(np.array([3])) is None
        st.set_pretrain_dir(np.array([3, 4]), np.ones((2, 6)))
        np.testing.assert_array_equal(
            st.get_pretrain_dir(np.array([4]))[0], np.ones(6))


class TestScheduler:
    def test_uniform_matches_pinned_selection(self, small_data):
        """Same-seed scheduler replays the pinned trainers' select stream
        (the derived [seed, SELECT_STREAM] rng, decorrelated from the
        cold-start stream)."""
        from repro.fed.store import SELECT_STREAM
        store = ArrayClientStore(small_data)
        sched = Scheduler(store, PopulationConfig(), seed=0)
        rng = np.random.default_rng([0, SELECT_STREAM])
        for t in range(4):
            idx, _ = sched.select(t, 8)
            np.testing.assert_array_equal(
                idx, rng.choice(40, 8, replace=False))
        # ... and it is NOT the cold-start stream (the old correlated bug)
        assert not np.array_equal(
            Scheduler(store, PopulationConfig(), seed=0).select(0, 8)[0],
            np.random.default_rng(0).choice(40, 8, replace=False))

    def test_diurnal_availability_restricts_cohort(self, small_data):
        store = ArrayClientStore(small_data)
        cfg = PopulationConfig(availability="diurnal", period=8, duty=0.25)
        sched = Scheduler(store, cfg, seed=0)
        for t in range(8):
            avail = sched.available_mask(t)
            assert 0 < avail.sum() < store.n_clients
            idx, _ = sched.select(t, 50)
            assert avail[idx].all()                  # only awake clients
        # every client is awake at some hour of the day
        union = np.zeros(store.n_clients, bool)
        for t in range(8):
            union |= sched.available_mask(t)
        assert union.all()

    def test_arrival_process_activates_newcomers(self, small_data):
        store = ArrayClientStore(small_data)
        cfg = PopulationConfig(initial_active=10, arrival_rate=5.0, seed=1)
        sched = Scheduler(store, cfg, seed=1)
        assert sched.active.sum() == 10
        seen_new = 0
        for t in range(12):
            idx, n_new = sched.select(t, 6)
            seen_new += n_new
            # newcomers join their arrival round's cohort
            assert np.isin(sched.last_arrivals[:6], idx).all()
        assert seen_new > 0
        assert sched.active.sum() == 10 + seen_new

    def test_size_weighted_sampler_prefers_large_clients(self, small_data):
        store = ArrayClientStore(small_data)
        sched = Scheduler(store, PopulationConfig(sampler="size",
                                                  initial_active=40),
                          seed=0)
        counts = np.zeros(store.n_clients)
        for t in range(150):
            idx, _ = sched.select(t, 5)
            counts[idx] += 1
        big = np.argsort(store.n_train)[-10:]
        small = np.argsort(store.n_train)[:10]
        assert counts[big].mean() > counts[small].mean()

    def test_all_asleep_round_still_schedules_one_client(self, small_data):
        """A diurnal trough (every active client asleep) must not produce
        an empty cohort — the round executor needs >= 1 client."""
        store = ArrayClientStore(small_data)
        cfg = PopulationConfig(availability="diurnal", period=10, duty=0.1,
                               initial_active=2, seed=5)
        sched = Scheduler(store, cfg, seed=5)
        for t in range(10):
            idx, _ = sched.select(t, 6)
            assert len(idx) >= 1
            assert sched.active[idx].all()

    def test_no_active_clients_is_an_error(self, small_data):
        sched = Scheduler(ArrayClientStore(small_data),
                          PopulationConfig(initial_active=0), seed=0)
        sched.active[:] = False
        with pytest.raises(RuntimeError, match="no active clients"):
            sched.select(0, 5)

    def test_scripted_replay(self, small_data):
        store = ArrayClientStore(small_data)
        script = [np.array([1, 2, 3]), np.array([4, 5, 6])]
        sched = Scheduler(store, PopulationConfig(sampler="scripted",
                                                  script=script), seed=0)
        np.testing.assert_array_equal(sched.select(0, 3)[0], [1, 2, 3])
        np.testing.assert_array_equal(sched.select(1, 3)[0], [4, 5, 6])


class TestStreamedPinnedEquivalence:
    def test_fedavg(self, small_model, small_data):
        pinned, h_pin, streamed, h_st = _run_both(
            FedAvgTrainer, small_model, small_data, _cfg())
        assert h_pin.rounds == h_st.rounds
        _assert_tree_equal(pinned.params, streamed.params)

    def test_fedavg_prefetch_disabled(self, small_model, small_data):
        _, h_pin, _, h_st = _run_both(
            FedAvgTrainer, small_model, small_data, _cfg(), rounds=2,
            pop_kw={"prefetch": 0})
        assert h_pin.rounds == h_st.rounds

    def test_fedgroup(self, small_model, small_data):
        from repro.core.fedgroup import FedGroupTrainer
        pinned, h_pin, streamed, h_st = _run_both(
            FedGroupTrainer, small_model, small_data, _cfg())
        assert h_pin.rounds == h_st.rounds
        _assert_tree_equal(pinned.group_params, streamed.group_params)
        np.testing.assert_array_equal(pinned.membership, streamed.membership)
        # cold-started clients left their eq.-9 direction in the table
        assigned = np.where(streamed.membership >= 0)[0]
        dirs = streamed.population.state.get_pretrain_dir(assigned[:1])
        assert dirs is not None and np.isfinite(dirs).all()

    def test_ifca(self, small_model, small_data):
        from repro.fed.ifca import IFCATrainer
        pinned, h_pin, streamed, h_st = _run_both(
            IFCATrainer, small_model, small_data, _cfg())
        assert h_pin.rounds == h_st.rounds
        _assert_tree_equal(pinned.group_params, streamed.group_params)
        np.testing.assert_array_equal(pinned.membership, streamed.membership)

    def test_fesem_state_table_gather_scatter(self, small_model, small_data):
        from repro.fed.fesem import FeSEMTrainer
        pinned, h_pin, streamed, h_st = _run_both(
            FeSEMTrainer, small_model, small_data, _cfg())
        assert h_pin.rounds == h_st.rounds
        _assert_tree_equal(pinned.group_params, streamed.group_params)
        np.testing.assert_array_equal(pinned.membership, streamed.membership)
        # the host state table holds exactly the touched clients' rows, and
        # they equal the pinned device matrix's rows
        touched = np.where(streamed.membership >= 0)[0]
        rows = streamed.population.state.gather_local_flat(touched)
        np.testing.assert_array_equal(
            rows, np.asarray(pinned.local_flat)[touched])

    def test_zero_newcomer_round(self, small_model, small_data):
        """A round whose cohort holds no cold clients exercises the
        cold-start no-op path (len(cold)==0 -> early return)."""
        from repro.core.fedgroup import FedGroupTrainer
        cfg = _cfg(pretrain_scale=20)       # 20*3 >= 40: pre-train everyone
        pop = Population(ArrayClientStore(small_data), PopulationConfig())
        tr = FedGroupTrainer(small_model, None, cfg, population=pop)
        m = tr.round(0)
        assert tr.last_cold == 0
        assert (tr.membership >= 0).all()
        assert np.isfinite(m.weighted_acc)
        tr.close()

    def test_arrival_driven_cold_start(self, small_model, small_data):
        """Newcomers arriving mid-training are routed through eq. 9 the
        round they first appear — cold start runs every round, not once."""
        from repro.core.fedgroup import FedGroupTrainer
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(initial_active=15,
                                          arrival_rate=4.0, seed=2))
        tr = FedGroupTrainer(small_model, None, _cfg(seed=2), population=pop)
        cold_counts = []
        for t in range(4):
            tr.round(t)
            cold_counts.append(tr.last_cold)
        tr.close()
        assert sum(cold_counts[1:]) > 0              # later-round cold starts
        arrived = pop.scheduler.active_ids()
        assert (tr.membership[~np.isin(np.arange(40), arrived)] < 0).all()

    def test_streamed_eval_matches_pinned(self, small_model, small_data):
        pinned = FedAvgTrainer(small_model, small_data, _cfg())
        pop = Population(ArrayClientStore(small_data),
                         PopulationConfig(eval_batch=7))
        streamed = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        assert streamed.evaluate() == pinned.evaluate()
        sub = np.array([1, 5, 9])
        assert streamed.evaluate(client_idx=sub) == \
            pinned.evaluate(client_idx=sub)
        streamed.close()


class TestPopulationPlumbing:
    def test_cohort_subset_is_sliced_not_regathered(self, small_data):
        store = ArrayClientStore(small_data)
        pop = Population(store, PopulationConfig(prefetch=0))
        pop.attach(_cfg())
        c = pop.next_cohort()
        x, y, n = pop.device_batch(c.idx[[2, 0]])
        np.testing.assert_array_equal(np.asarray(x),
                                      np.asarray(c.x)[[2, 0]])
        np.testing.assert_array_equal(np.asarray(n),
                                      np.asarray(c.n)[[2, 0]])

    def test_cohort_positions(self):
        c = Cohort(0, np.array([7, 3, 11]), None, None, None)
        np.testing.assert_array_equal(c.positions([11, 7]), [2, 0])
        assert c.positions([5]) is None

    def test_population_single_attach(self, small_model, small_data):
        pop = Population(ArrayClientStore(small_data), PopulationConfig())
        tr = FedAvgTrainer(small_model, None, _cfg(), population=pop)
        with pytest.raises(RuntimeError):
            FedAvgTrainer(small_model, None, _cfg(), population=pop)
        tr.close()

    def test_producer_failure_raises_instead_of_hanging(self, small_data):
        """A crash in the prefetch thread surfaces on next_cohort()."""
        store = ArrayClientStore(small_data)

        def boom(split, idx):
            raise OSError("disk gone")

        store._gather = boom
        pop = Population(store, PopulationConfig(prefetch=1))
        pop.attach(_cfg())
        with pytest.raises(RuntimeError, match="prefetch thread failed"):
            pop.next_cohort()
        pop.close()
        # and a closed population refuses new cohorts instead of hanging
        with pytest.raises(RuntimeError, match="close"):
            pop.next_cohort()

    def test_mean_loss_surfaced(self, small_model, small_data):
        """History reports the executor's actual weighted local train loss
        (satellite: RoundMetrics.mean_loss was hard-coded 0.0)."""
        tr = FedAvgTrainer(small_model, small_data, _cfg())
        m0 = tr.round(0)
        m1 = tr.round(1)
        assert m0.mean_loss > 0.0 and np.isfinite(m0.mean_loss)
        assert m1.mean_loss != m0.mean_loss

"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=512,
<=4 experts), one forward + one train step + one decode step on CPU,
asserting output shapes and no NaNs. The FULL configs are exercised only by
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import zoo

ARCHS = sorted(registry.ARCHS)
B, S = 2, 32


def _smoke_batch(cfg, key):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, S, cfg.frontend_dim)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        St = S - cfg.n_patches
        return {"tokens": jax.random.randint(key, (B, St), 0, cfg.vocab_size),
                "patch_embeds": jax.random.normal(
                    key, (B, cfg.n_patches, cfg.frontend_dim)),
                "labels": jax.random.randint(key, (B, St), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_reduced_config_limits(self, arch):
        cfg = registry.smoke_variant(registry.get(arch))
        assert cfg.n_layers == 2
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4

    def test_forward_shapes_no_nans(self, arch):
        cfg = registry.smoke_variant(registry.get(arch))
        key = jax.random.PRNGKey(1)
        params = zoo.init_params(key, cfg)
        batch = _smoke_batch(cfg, key)
        logits, aux = zoo.forward(params, cfg, batch)
        exp_s = S if cfg.family != "vlm" else S
        assert logits.shape == (B, exp_s, cfg.padded_vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    def test_train_step_no_nans(self, arch):
        cfg = registry.smoke_variant(registry.get(arch))
        key = jax.random.PRNGKey(2)
        state = zoo.init_train_state(key, cfg)
        batch = _smoke_batch(cfg, key)
        state2, metrics = zoo.train_step(state, batch, cfg)
        assert np.isfinite(float(metrics["loss"]))
        for leaf in jax.tree_util.tree_leaves(state2["params"]):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
        assert int(state2["step"]) == 1

    def test_train_step_changes_params(self, arch):
        cfg = registry.smoke_variant(registry.get(arch))
        key = jax.random.PRNGKey(3)
        state = zoo.init_train_state(key, cfg)
        batch = _smoke_batch(cfg, key)
        state2, _ = zoo.train_step(state, batch, cfg)
        before = jax.tree_util.tree_leaves(state["params"])
        after = jax.tree_util.tree_leaves(state2["params"])
        assert any(not np.allclose(a, b) for a, b in zip(before, after))

    def test_decode_step(self, arch):
        cfg = registry.smoke_variant(registry.get(arch))
        if not cfg.decode_supported:
            pytest.skip("encoder-only: no decode step (hubert)")
        key = jax.random.PRNGKey(4)
        params = zoo.init_params(key, cfg)
        cache = zoo.init_cache(cfg, B, 16)
        logits, cache2 = zoo.serve_step(
            params, cfg, cache, jnp.zeros((B, 1), jnp.int32),
            jnp.zeros((B,), jnp.int32))
        assert logits.shape == (B, cfg.padded_vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    def test_full_config_matches_assignment(self, arch):
        """The full (non-smoke) config carries the assigned dimensions."""
        cfg = registry.get(arch)
        expected = {
            "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
            "granite-20b": (52, 6144, 48, 1, 24576, 49152),
            "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
            "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
            "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
            "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
            "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
            "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
            "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
            "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expected
        assert cfg.source != ""

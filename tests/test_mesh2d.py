"""2-D (data × model) mesh + host-sharded client store (PR 4 tentpole).

Two layers of coverage:

  * in-process (1 device): ``ShardedClientStore`` gather decomposition /
    round-trip against the inner store, per-shard cohort slices, the
    federated-round PartitionSpecs, and the async per-shard state scatter
    (drain-before-gather determinism).
  * subprocess (forced host devices, pattern of tests/test_fed_parallel.py):
    a 2×2 ``(data, model)`` mesh run of FedAvg and FedGroup must reproduce
    the 1-device pinned run — same metrics trajectory, same final params
    (allclose: model-axis contractions reorder float reductions), same
    membership (exact) — and a streamed run over ``ShardedClientStore`` +
    per-shard prefetch must be *bit-identical* to the pinned 2×2 run
    (same compiled program, only the feeding differs; docs/scaling.md).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.generators import mnist_like
from repro.fed.population import Population, PopulationConfig
from repro.fed.store import (ArrayClientStore, ShardedClientStore,
                             shard_cohort_slices)
from repro.sharding.specs import cohort_pspec, group_param_pspec


@pytest.fixture(scope="module")
def small_data():
    return mnist_like(seed=0, n_clients=16, classes_per_client=2,
                      total_train=1200, dim=16)


class TestShardCohortSlices:
    def test_contiguous_equal_blocks(self):
        assert shard_cohort_slices(8, 4) == [(0, 2), (2, 4), (4, 6), (6, 8)]
        assert shard_cohort_slices(6, 1) == [(0, 6)]

    def test_non_divisible_returns_none(self):
        assert shard_cohort_slices(7, 4) is None
        assert shard_cohort_slices(4, 0) is None


class TestShardedStore:
    def test_gather_round_trips_inner_store(self, small_data):
        inner = ArrayClientStore(small_data)
        sharded = ShardedClientStore(inner, n_shards=4)
        idx = np.array([3, 11, 0, 7, 9, 1, 15, 2])
        for split in ("gather_train", "gather_test"):
            for a, b in zip(getattr(sharded, split)(idx),
                            getattr(inner, split)(idx)):
                np.testing.assert_array_equal(a, b)

    def test_shard_gathers_cover_cohort_slices(self, small_data):
        inner = ArrayClientStore(small_data)
        sharded = ShardedClientStore(inner, n_shards=2)
        idx = np.array([5, 2, 9, 14])
        parts = sharded.gather_train_shards(idx)
        assert len(parts) == 2
        x_full, y_full, n_full = inner.gather_train(idx)
        for s, (lo, hi) in enumerate(shard_cohort_slices(4, 2)):
            np.testing.assert_array_equal(parts[s][0], x_full[lo:hi])
            np.testing.assert_array_equal(parts[s][1], y_full[lo:hi])
            np.testing.assert_array_equal(parts[s][2], n_full[lo:hi])

    def test_non_divisible_cohort_falls_back(self, small_data):
        sharded = ShardedClientStore(ArrayClientStore(small_data), 4)
        idx = np.array([1, 2, 3])                 # 3 % 4 != 0
        assert sharded.gather_train_shards(idx) is None
        x, _, n = sharded.gather_train(idx)       # still serves the cohort
        np.testing.assert_array_equal(x, small_data.x_train[idx])
        np.testing.assert_array_equal(n, small_data.n_train[idx])

    def test_metadata_mirrors_inner(self, small_data):
        inner = ArrayClientStore(small_data)
        sharded = ShardedClientStore(inner, 2)
        assert sharded.n_clients == inner.n_clients
        assert sharded.max_train == inner.max_train
        np.testing.assert_array_equal(sharded.n_train, inner.n_train)
        with pytest.raises(ValueError):
            ShardedClientStore(inner, 0)

    def test_streamed_cohorts_match_array_store(self, small_data):
        """Same seed -> the sharded store's prefetched cohort stream is
        identical to the ArrayClientStore's (scheduler rng is shared)."""
        from repro.fed.engine import FedConfig
        cfg = FedConfig(clients_per_round=8, seed=0)
        cohorts = []
        for store in (ArrayClientStore(small_data),
                      ShardedClientStore(ArrayClientStore(small_data), 2)):
            pop = Population(store, PopulationConfig(prefetch=2))
            pop.attach(cfg)
            cohorts.append([pop.next_cohort() for _ in range(3)])
            pop.close()
        for ca, cs in zip(*cohorts):
            np.testing.assert_array_equal(ca.idx, cs.idx)
            np.testing.assert_array_equal(np.asarray(ca.x), np.asarray(cs.x))
            np.testing.assert_array_equal(np.asarray(ca.n), np.asarray(cs.n))


class TestAsyncStateScatter:
    def test_scatter_then_gather_is_ordered(self, small_data):
        """Per-shard async writes are drained before any gather — a
        reader can never observe a stale row."""
        from repro.fed.engine import FedConfig
        pop = Population(ShardedClientStore(ArrayClientStore(small_data), 2),
                         PopulationConfig())
        pop.attach(FedConfig(clients_per_round=8, seed=0))
        pop.state.init_local_flat(np.zeros(4, np.float32))
        idx = np.arange(8)
        for step in range(1, 4):                 # FIFO across rounds
            pop.scatter_local_flat(idx, np.full((8, 4), float(step)))
        rows = pop.gather_local_flat(idx)
        np.testing.assert_array_equal(rows, np.full((8, 4), 3.0))
        pop.close()

    def test_writer_error_surfaces_on_drain(self, small_data):
        from repro.fed.engine import FedConfig
        pop = Population(ArrayClientStore(small_data), PopulationConfig())
        pop.attach(FedConfig(clients_per_round=8, seed=0))
        pop._writer.submit(lambda: (_ for _ in ()).throw(OSError("disk")))
        with pytest.raises(RuntimeError, match="state-table write failed"):
            pop.gather_local_flat(np.arange(2))
        pop.close()


class TestFedRoundSpecs:
    def test_cohort_pspec_shards_client_axis_only(self):
        spec = cohort_pspec(3, data_axes=("data",))
        assert tuple(spec) == (("data",), None, None)

    def test_group_param_pspec_picks_largest_divisible_dim(self):
        # (m, d, C): d=16 divides 2, C=10 does not -> shard d over "model"
        assert tuple(group_param_pspec((3, 16, 10), 2)) == \
            (None, "model", None)
        # nothing divisible, or model axis 1 -> fully replicated
        assert tuple(group_param_pspec((3, 7, 9), 2)) == (None, None, None)
        assert tuple(group_param_pspec((3, 16, 10), 1)) == (None, None, None)
        # 1-D leaves (biases stacked over m) stay replicated
        assert tuple(group_param_pspec((3,), 2)) == (None,)


_DRIVER = r"""
import json, sys
import jax
import numpy as np
from repro.core.fedgroup import FedGroupTrainer
from repro.data.generators import mnist_like
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.models.paper_models import mclr

mode = sys.argv[1]                      # "1dev" | "2x2"
data = mnist_like(seed=0, n_clients=16, classes_per_client=2,
                  total_train=1200, dim=16)
model = mclr(16, 10)
cfg = FedConfig(n_rounds=3, clients_per_round=8, local_epochs=3,
                batch_size=10, lr=0.05, n_groups=2, pretrain_scale=3, seed=0)
mesh = None
if mode == "2x2":
    from repro.launch.mesh import make_fed_mesh
    mesh = make_fed_mesh(2, 2)
out = {"devices": jax.device_count()}
for cls in (FedAvgTrainer, FedGroupTrainer):
    tr = cls(model, data, cfg, mesh=mesh)
    h = tr.run(cfg.n_rounds)
    fw = cls.framework
    out[fw] = [[r.weighted_acc, r.mean_loss, r.discrepancy]
               for r in h.rounds]
    params = tr.group_params if fw == "fedgroup" else tr.params
    out[fw + "_params"] = {k: np.asarray(v).tolist()
                           for k, v in params.items()}
    if fw == "fedgroup":
        out["membership"] = tr.membership.tolist()
if mode == "2x2":
    # streamed over ShardedClientStore + per-shard prefetch must be
    # BIT-identical to the pinned 2x2 run just recorded in out["fedavg"]
    from repro.fed.population import Population, PopulationConfig
    from repro.fed.store import ArrayClientStore, ShardedClientStore
    pop = Population(ShardedClientStore(ArrayClientStore(data), 2),
                     PopulationConfig())
    st = FedAvgTrainer(model, None, cfg, mesh=mesh, population=pop)
    hs = st.run(cfg.n_rounds)
    st.close()
    stream = [[r.weighted_acc, r.mean_loss, r.discrepancy]
              for r in hs.rounds]
    out["stream_bit_identical"] = stream == out["fedavg"] and all(
        np.array_equal(np.asarray(st.params[k]),
                       np.asarray(out["fedavg_params"][k]))
        for k in st.params)
print(json.dumps(out))
"""


def _run_driver(n_devices: int, mode: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DRIVER, mode], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


class TestMesh2DEquivalence:
    def test_2x2_mesh_matches_single_device(self):
        """A 2×2 (data, model) mesh reproduces the 1-device pinned run for
        FedAvg and FedGroup: metrics + params within reduction-order
        tolerance, membership exactly; and the sharded-store streamed run
        is bit-identical to the pinned run on the same mesh."""
        one = _run_driver(1, "1dev")
        two = _run_driver(4, "2x2")
        assert one["devices"] == 1 and two["devices"] == 4
        for fw in ("fedavg", "fedgroup"):
            np.testing.assert_allclose(
                np.asarray(one[fw]), np.asarray(two[fw]), atol=2e-3,
                err_msg=f"{fw} metrics diverged under the 2-D mesh")
            for k in one[fw + "_params"]:
                np.testing.assert_allclose(
                    np.asarray(one[fw + "_params"][k]),
                    np.asarray(two[fw + "_params"][k]), atol=2e-3,
                    err_msg=f"{fw} params[{k}] diverged under the 2-D mesh")
        assert one["membership"] == two["membership"]
        assert two["stream_bit_identical"]

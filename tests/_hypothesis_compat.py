"""Property-testing shim: real hypothesis when installed, else a tiny
deterministic fallback.

The fallback implements just the surface the suite uses —
``@given(st.integers(lo, hi))`` (possibly several strategies) and
``@settings(max_examples=..., deadline=...)`` — by running the test body on a
fixed-seed sample of the strategy ranges (boundaries + pseudo-random interior
points). That keeps the property tests exercised on machines without the
dependency instead of skipping whole modules at collection time.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random

    _DEFAULT_EXAMPLES = 10

    class _IntStrategy:
        def __init__(self, lo: int, hi: int):
            if lo > hi:
                raise ValueError(f"empty integer range [{lo}, {hi}]")
            self.lo, self.hi = lo, hi

        def draw(self, rng: random.Random) -> int:
            return rng.randint(self.lo, self.hi)

    class st:  # noqa: N801 — mirrors ``hypothesis.strategies``
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

    def settings(**kw):
        def deco(fn):
            fn._max_examples = kw.get("max_examples", _DEFAULT_EXAMPLES)
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps — exposing the wrapped signature via
            # __wrapped__ would make pytest treat strategy-filled parameters
            # as fixtures.
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_max_examples",
                            getattr(wrapper, "_max_examples",
                                    _DEFAULT_EXAMPLES))
                rng = random.Random(0)
                # boundary case first, then fixed-seed interior samples
                fn(*args, *(s.lo for s in strategies), **kwargs)
                for _ in range(max(n - 1, 0)):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            wrapper.__name__ = getattr(fn, "__name__", "wrapped")
            wrapper.__doc__ = getattr(fn, "__doc__", None)
            return wrapper
        return deco

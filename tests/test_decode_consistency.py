"""End-to-end decode consistency: token-by-token serve_step must reproduce
the teacher-forced forward logits for every decoding family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import zoo

# one representative per decode path
FAMS = ["gemma-2b",              # dense, tied embeddings, GeGLU
        "glm4-9b",               # dense + qkv bias GQA
        "deepseek-v3-671b",      # MLA + MoE
        "granite-moe-1b-a400m",  # GQA + MoE
        "zamba2-1.2b",           # hybrid mamba + shared attn
        "xlstm-350m"]            # sLSTM/mLSTM


@pytest.mark.parametrize("arch", FAMS)
def test_serve_matches_forward(arch):
    cfg = registry.smoke_variant(registry.get(arch))
    if cfg.family == "moe":
        # make routing deterministic-ish and capacity ample so no drops
        cfg = cfg.replace(capacity_factor=100.0)
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(key, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    full_logits, _ = zoo.forward(params, cfg, batch)

    cache = zoo.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = zoo.serve_step(params, cfg, cache, tokens[:, t:t + 1],
                                   jnp.full((B,), t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(dec_logits), atol=2e-3, rtol=2e-3)


def test_windowed_dense_serve_matches_windowed_forward():
    """Ring-buffer sliding-window decode == windowed forward (gemma)."""
    cfg = registry.smoke_variant(registry.get("gemma-2b")).with_window(6)
    key = jax.random.PRNGKey(1)
    params = zoo.init_params(key, cfg)
    B, S = 1, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = zoo.forward(params, cfg, {"tokens": tokens,
                                               "labels": tokens})
    cache = zoo.init_cache(cfg, B, 6)        # ring buffer = window slots
    outs = []
    for t in range(S):
        lg, cache = zoo.serve_step(params, cfg, cache, tokens[:, t:t + 1],
                                   jnp.full((B,), t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(dec_logits), atol=2e-3, rtol=2e-3)

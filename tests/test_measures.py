"""Unit + property tests for the paper's measures (eq. 5-9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import measures
from repro.core.svd import randomized_truncated_svd, truncated_svd_values


def _rand(key, n, d):
    return jax.random.normal(jax.random.PRNGKey(key), (n, d))


class TestCosineMatrix:
    def test_matches_numpy(self):
        dW = np.asarray(_rand(0, 12, 50))
        M = np.asarray(measures.cosine_similarity_matrix(jnp.asarray(dW)))
        nrm = dW / np.linalg.norm(dW, axis=1, keepdims=True)
        np.testing.assert_allclose(M, np.clip(nrm @ nrm.T, -1, 1), atol=1e-5)

    def test_diag_ones(self):
        M = measures.cosine_similarity_matrix(_rand(1, 8, 30))
        np.testing.assert_allclose(np.diag(np.asarray(M)), 1.0, atol=1e-5)

    @given(st.integers(3, 16), st.integers(4, 40), st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_bounded_symmetric(self, n, d, seed):
        M = np.asarray(measures.cosine_similarity_matrix(_rand(seed, n, d)))
        assert np.all(M <= 1.0 + 1e-5) and np.all(M >= -1.0 - 1e-5)
        np.testing.assert_allclose(M, M.T, atol=1e-5)


class TestMADC:
    def test_symmetric_zero_diag(self):
        M = measures.cosine_similarity_matrix(_rand(2, 10, 64))
        D = np.asarray(measures.madc(M))
        np.testing.assert_allclose(D, D.T, atol=1e-6)
        np.testing.assert_allclose(np.diag(D), 0.0, atol=1e-6)
        assert np.all(D >= -1e-6)

    def test_separates_clusters(self):
        """Two groups of identical directions: MADC within << across."""
        key = jax.random.PRNGKey(3)
        a = jax.random.normal(key, (1, 40))
        b = jax.random.normal(jax.random.fold_in(key, 1), (1, 40))
        dW = jnp.concatenate([jnp.tile(a, (5, 1)), jnp.tile(b, (5, 1))])
        dW = dW + 0.01 * jax.random.normal(jax.random.fold_in(key, 2), dW.shape)
        D = np.asarray(measures.madc(measures.cosine_similarity_matrix(dW)))
        within = (D[:5, :5].sum() + D[5:, 5:].sum()) / (2 * 5 * 4)
        across = D[:5, 5:].mean()
        assert across > 5 * within


class TestEDC:
    def test_metric_properties(self):
        """EDC is a true metric (Euclidean on embeddings): triangle ineq."""
        dW = _rand(4, 9, 100)
        D = np.asarray(measures.edc(dW, m=3))
        np.testing.assert_allclose(D, D.T, atol=1e-5)
        np.testing.assert_allclose(np.diag(D), 0.0, atol=1e-5)
        n = D.shape[0]
        for i in range(n):
            for j in range(n):
                for k in range(n):
                    assert D[i, j] <= D[i, k] + D[k, j] + 1e-5

    def test_approximates_madc_linearly(self):
        """Paper Fig. 5: the MADC -> EDC map is approximately linear.
        Check rank correlation > 0.75 on clustered data."""
        key = jax.random.PRNGKey(5)
        centers = jax.random.normal(key, (3, 200))
        dW = jnp.concatenate([
            centers[i] + 0.3 * jax.random.normal(
                jax.random.fold_in(key, i), (8, 200)) for i in range(3)])
        M = measures.cosine_similarity_matrix(dW)
        madc_d = np.asarray(measures.madc(M))
        edc_d = np.asarray(measures.edc(dW, m=3))
        iu = np.triu_indices(24, 1)
        a, b = madc_d[iu], edc_d[iu]
        ra = np.argsort(np.argsort(a)).astype(float)
        rb = np.argsort(np.argsort(b)).astype(float)
        rho = np.corrcoef(ra, rb)[0, 1]
        assert rho > 0.75, rho

    def test_embedding_shape(self):
        E, V = measures.edc_embed(_rand(6, 10, 333), m=4)
        assert E.shape == (10, 4) and V.shape == (333, 4)
        assert np.all(np.abs(np.asarray(E)) <= 1 + 1e-5)


class TestSVD:
    @staticmethod
    def _decaying(seed, d, n):
        """Matrix with a decaying spectrum (the FedGroup regime: client
        updates span a few dominant directions). A flat random spectrum is
        adversarial for ANY randomized SVD — not the use case."""
        rng = np.random.default_rng(seed)
        U, _ = np.linalg.qr(rng.normal(size=(d, n)))
        V, _ = np.linalg.qr(rng.normal(size=(n, n)))
        s = 10.0 * 0.6 ** np.arange(n)
        return (U * s) @ V.T

    def test_matches_numpy_svd(self):
        A = self._decaying(7, 80, 20)
        V = np.asarray(randomized_truncated_svd(jnp.asarray(A), 4))
        U_np = np.linalg.svd(A, full_matrices=False)[0][:, :4]
        # subspace angle: |V^T U| ~ identity up to sign/rotation
        S = np.abs(V.T @ U_np)
        np.testing.assert_allclose(np.linalg.svd(S)[1], 1.0, atol=1e-3)

    def test_singular_values(self):
        A = self._decaying(8, 200, 30)
        got = np.sort(np.asarray(truncated_svd_values(jnp.asarray(A), 5)))[::-1]
        want = np.linalg.svd(A, compute_uv=False)[:5]
        np.testing.assert_allclose(got, want, rtol=1e-3)

    def test_orthonormal_columns(self):
        V = randomized_truncated_svd(_rand(9, 500, 16).T, 6)
        G = np.asarray(V.T @ V)
        np.testing.assert_allclose(G, np.eye(6), atol=1e-4)


class TestColdStartMeasure:
    def test_cosine_dissimilarity_range(self):
        a, b = _rand(10, 1, 64)[0], _rand(11, 1, 64)[0]
        d = float(measures.cosine_dissimilarity(a, b))
        assert 0.0 - 1e-6 <= d <= 1.0 + 1e-6
        assert float(measures.cosine_dissimilarity(a, a)) == pytest.approx(0.0, abs=1e-6)
        assert float(measures.cosine_dissimilarity(a, -a)) == pytest.approx(1.0, abs=1e-6)

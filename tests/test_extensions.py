"""Beyond-paper extensions: gate-network mixing, client dropout, MTP head."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import gating
from repro.core.fedgroup import FedGroupTrainer
from repro.fed.engine import FedAvgTrainer, FedConfig
from repro.models import zoo


class TestGateNetwork:
    def test_weights_are_distribution(self):
        key = jax.random.PRNGKey(0)
        dpre = jax.random.normal(key, (5, 40))
        G = jax.random.normal(jax.random.fold_in(key, 1), (3, 40))
        w = np.asarray(gating.gate_weights(dpre, G, temperature=0.1))
        np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-5)
        assert np.all(w >= 0)

    def test_low_temperature_is_hard_assignment(self):
        key = jax.random.PRNGKey(1)
        G = jax.random.normal(key, (3, 40))
        dpre = G[1:2] + 0.01 * jax.random.normal(key, (1, 40))
        w = np.asarray(gating.gate_weights(dpre, G, temperature=1e-3))
        assert w[0, 1] > 0.99

    def test_gated_eval_close_to_hard_at_low_tau(self, tiny_model,
                                                 tiny_fed_data, fast_cfg):
        tr = FedGroupTrainer(tiny_model, tiny_fed_data, fast_cfg)
        tr.run(3)
        hard = tr.evaluate_groups()
        gated = gating.evaluate_gated(tr, temperature=0.02)
        # low τ approaches hard assignment, but only eq.-9-routed clients
        # share the gate's argmax-similarity rule — the pre-trained pool's
        # labels come from Algorithm-3 clustering and may disagree per
        # client, so the bound is loose (seed-sensitive)
        assert abs(gated - hard) < 0.2
        assert 0.0 <= gated <= 1.0


class TestClientDropout:
    def test_dropout_shrinks_round(self, tiny_model, tiny_fed_data):
        cfg = FedConfig(n_rounds=1, clients_per_round=20, local_epochs=2,
                        batch_size=5, lr=0.05, seed=0, dropout_rate=0.5)
        tr = FedAvgTrainer(tiny_model, tiny_fed_data, cfg)
        sizes = [len(tr._select()) for _ in range(20)]
        assert min(sizes) >= 1
        assert np.mean(sizes) < 16      # ~half of 20 survive

    def test_training_survives_dropout(self, tiny_model, tiny_fed_data):
        cfg = FedConfig(n_rounds=3, clients_per_round=10, local_epochs=3,
                        batch_size=10, lr=0.05, n_groups=3, pretrain_scale=4,
                        seed=0, dropout_rate=0.4)
        h = FedGroupTrainer(tiny_model, tiny_fed_data, cfg).run()
        assert np.isfinite(h.max_acc) and h.max_acc > 0.2


class TestMTP:
    def test_mtp_head_trains(self):
        cfg = registry.smoke_variant(registry.get("deepseek-v3-671b"))
        cfg = cfg.replace(mtp=True)
        key = jax.random.PRNGKey(0)
        state = zoo.init_train_state(key, cfg)
        batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
        state2, m = zoo.train_step(state, batch, cfg)
        assert np.isfinite(float(m["loss"]))
        assert "mtp_ce" in m and np.isfinite(float(m["mtp_ce"]))
        assert "mtp" in state2["params"]

    def test_mtp_increases_total_loss_not_ce(self):
        cfg = registry.smoke_variant(registry.get("deepseek-v3-671b"))
        key = jax.random.PRNGKey(1)
        params = zoo.init_params(key, cfg.replace(mtp=True))
        batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
        total_mtp, m1 = zoo.loss_fn(params, cfg.replace(mtp=True), batch)
        # base ce computed from same params without the mtp term
        total_base, m0 = zoo.loss_fn(params, cfg, batch)
        assert float(m1["ce"]) == pytest.approx(float(m0["ce"]), rel=1e-5)
        assert float(total_mtp) > float(total_base)

    def test_mtp_logits_shape(self):
        cfg = registry.smoke_variant(registry.get("deepseek-v3-671b"))
        cfg = cfg.replace(mtp=True)
        key = jax.random.PRNGKey(2)
        params = zoo.init_params(key, cfg)
        batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
        _, aux = zoo.forward(params, cfg, batch, return_hidden=True)
        lg = zoo.mtp_logits(params, cfg, aux["hidden"], batch["tokens"])
        assert lg.shape == (2, 15, cfg.padded_vocab)
